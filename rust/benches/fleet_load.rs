//! Closed-loop fleet serving load bench: sustained req/s and per-tenant
//! latency percentiles through one shared `FleetService` — the serving
//! companion to `speedup_tables` (which measures training).
//!
//! Two tenants with different shapes are trained in-process, published
//! to a temp registry, and hammered by closed-loop clients for a fixed
//! wall-clock window. Percentiles come straight from the fleet's own
//! `akda_fleet_latency_seconds{tenant=...}` histograms, so the bench
//! exercises the exact instruments operators see live.
//!
//! With `--connect HOST:PORT` (or `AKDA_CONNECT=HOST:PORT`) the bench
//! instead drives an already-running `akda serve --fleet --listen` over
//! TCP speaking akda-wire/1 — same closed-loop clients, same output
//! schema, latencies measured client-side (so they include the wire) and
//! `"transport": "tcp"` recorded in the document. Every TCP request is
//! traced, so the server-timing echo yields a per-stage breakdown
//! (`net/read` … `net/write`) recorded as a `stages` object and the
//! schema bumps to `akda-bench-serve/2` (an old server without the echo
//! degrades the document back to v1).
//!
//! Env: AKDA_FAST=1 → 2 s of load (CI smoke; default 8 s)
//!      AKDA_SERVE_SECS=S → explicit load window
//!      AKDA_SERVE_WORKERS=N → closed-loop clients per tenant (default 4)
//!      AKDA_CONNECT=ADDR → drive a remote fleet instead of in-process
//! Run: cargo bench --bench fleet_load [-- --connect HOST:PORT]
//!
//! Writes `BENCH_serve.json` (schema `akda-bench-serve/1`, or `/2` with
//! the stage breakdown; validated in CI via `akda metrics --validate`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use akda::coordinator::net::{NetClient, NetReply};
use akda::coordinator::{DetectorBank, FleetOptions, FleetService};
use akda::da::akda::Akda;
use akda::da::{DrMethod, Projection};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::update::train_svm_bank;
use akda::model::{encode_bank, ModelArtifact, ModelManifest, ModelRegistry};
use akda::obs::trace::stage_name;
use akda::obs::TraceIdGen;
use akda::util::json::Json;
use akda::util::rng::Rng;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Train one tenant's detector bank; returns its data (request rows) and
/// the publishable artifact.
fn tenant(dim: usize, n_classes: usize, seed: u64) -> (Mat, ModelArtifact) {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes,
        n_per_class: vec![16; n_classes],
        dim,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    });
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let proj = akda_cfg.fit(&x, &labels, n_classes).expect("fit");
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, n_classes);
    let bank = DetectorBank { projection: proj, svms };
    let art = encode_bank(&bank, "akda").expect("encode");
    (x, art)
}

/// Nearest-rank quantile over an ascending-sorted latency sample.
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// `--connect` mode: hammer a remote fleet over TCP. Request rows are
/// synthetic (seeded, shaped by each tenant's advertised input dim), and
/// latency percentiles are measured client-side per call — the served
/// numbers therefore include framing + kernel + wire, which is exactly
/// what a remote caller experiences.
fn run_connect(addr: &str, secs: f64, workers: usize) {
    let timeout = Duration::from_secs(30);
    let mut probe = NetClient::connect(addr, timeout).expect("connect to fleet");
    let roster = probe.models().expect("tenant roster");
    assert!(!roster.is_empty(), "server at {addr} serves no models");
    eprintln!(
        "fleet load (tcp): {} tenants at {addr}, {workers} clients each, {secs}s window",
        roster.len()
    );

    struct TenantLoad {
        requests: AtomicUsize,
        rejected: AtomicUsize,
        latencies: Mutex<Vec<f64>>,
    }
    let stats: BTreeMap<String, TenantLoad> = roster
        .iter()
        .map(|m| {
            let load = TenantLoad {
                requests: AtomicUsize::new(0),
                rejected: AtomicUsize::new(0),
                latencies: Mutex::new(Vec::new()),
            };
            (m.name.clone(), load)
        })
        .collect();
    // per-stage samples (seconds) aggregated from every traced response's
    // server-timing echo, keyed by wire stage id
    let stage_lat: Mutex<BTreeMap<u8, Vec<f64>>> = Mutex::new(BTreeMap::new());
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (t, m) in roster.iter().enumerate() {
            for w in 0..workers {
                let (stop, stats, stage_lat) = (&stop, &stats, &stage_lat);
                let (name, dim) = (m.name.clone(), m.input_dim as usize);
                s.spawn(move || {
                    let mut conn =
                        NetClient::connect(addr, timeout).expect("connect load client");
                    let mut rng = Rng::new(0xF1EE7 ^ ((t as u64) << 32) ^ w as u64);
                    let mut ids = TraceIdGen::new(0x7712_ACED ^ ((t as u64) << 32) ^ w as u64);
                    let mut lat = Vec::new();
                    let mut stages: BTreeMap<u8, Vec<f64>> = BTreeMap::new();
                    let tenant = &stats[&name];
                    while !stop.load(Ordering::Relaxed) {
                        let row: Vec<f64> = (0..dim).map(|_| rng.range(-1.0, 1.0)).collect();
                        let traced = conn
                            .score_traced(&name, &row, ids.next_id())
                            .expect("score over tcp");
                        match traced.reply {
                            NetReply::Scores(_) => {
                                tenant.requests.fetch_add(1, Ordering::Relaxed);
                            }
                            NetReply::Rejected { .. } => {
                                tenant.rejected.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        for &(id, nanos) in &traced.timings {
                            stages.entry(id).or_default().push(nanos as f64 * 1e-9);
                        }
                        lat.push(traced.rtt.as_secs_f64());
                    }
                    tenant.latencies.lock().expect("latency sink").extend(lat);
                    let mut sink = stage_lat.lock().expect("stage sink");
                    for (id, sample) in stages {
                        sink.entry(id).or_default().extend(sample);
                    }
                });
            }
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let total_requests: usize =
        stats.values().map(|t| t.requests.load(Ordering::Relaxed)).sum();
    let tenants_json: Vec<Json> = roster
        .iter()
        .map(|m| {
            let t = &stats[&m.name];
            let n = t.requests.load(Ordering::Relaxed);
            let rejected = t.rejected.load(Ordering::Relaxed);
            let mut lat = t.latencies.lock().expect("latency sink").clone();
            lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
            let p50_ms = quantile_sorted(&lat, 0.5) * 1e3;
            let p99_ms = quantile_sorted(&lat, 0.99) * 1e3;
            eprintln!(
                "   {}: {n} requests ({:.0} req/s), p50 {p50_ms:.3} ms, p99 {p99_ms:.3} ms",
                m.name,
                n as f64 / elapsed
            );
            obj(vec![
                ("model", Json::Str(m.name.clone())),
                ("requests", Json::Num(n as f64)),
                ("rejected", Json::Num(rejected as f64)),
                ("req_per_s", Json::Num(n as f64 / elapsed)),
                ("p50_ms", Json::Num(p50_ms)),
                ("p99_ms", Json::Num(p99_ms)),
            ])
        })
        .collect();
    let total = obj(vec![
        ("requests", Json::Num(total_requests as f64)),
        ("req_per_s", Json::Num(total_requests as f64 / elapsed)),
    ]);

    // where the server-side wall clock went, stage by stage
    let stage_lat = stage_lat.into_inner().expect("stage sink");
    let all_stage_s: f64 = stage_lat.values().flat_map(|v| v.iter()).sum();
    let mut stages_map: BTreeMap<String, Json> = BTreeMap::new();
    for (id, mut sample) in stage_lat {
        sample.sort_by(|a, b| a.partial_cmp(b).expect("finite stage time"));
        let sum: f64 = sample.iter().sum();
        let (p50_ms, p99_ms) =
            (quantile_sorted(&sample, 0.5) * 1e3, quantile_sorted(&sample, 0.99) * 1e3);
        let share = if all_stage_s > 0.0 { sum / all_stage_s } else { 0.0 };
        let name =
            stage_name(id).map(str::to_string).unwrap_or_else(|| format!("stage/{id}"));
        eprintln!(
            "   stage {name:<18} p50 {p50_ms:.3} ms, p99 {p99_ms:.3} ms, share {:.1}%",
            share * 100.0
        );
        stages_map.insert(
            name,
            obj(vec![
                ("p50_ms", Json::Num(p50_ms)),
                ("p99_ms", Json::Num(p99_ms)),
                ("share", Json::Num(share)),
            ]),
        );
    }

    // a server without the timing echo leaves no stage samples — degrade
    // the document to v1 rather than emit an invalid empty v2
    let schema =
        if stages_map.is_empty() { "akda-bench-serve/1" } else { "akda-bench-serve/2" };
    let mut fields = vec![
        ("schema", Json::Str(schema.into())),
        ("transport", Json::Str("tcp".into())),
        ("duration_s", Json::Num(elapsed)),
        ("tenants", Json::Arr(tenants_json)),
        ("total", total),
    ];
    if !stages_map.is_empty() {
        fields.push(("stages", Json::Obj(stages_map)));
    }
    let bench = obj(fields);
    println!(
        "fleet load (tcp): {total_requests} requests in {elapsed:.2}s ({:.0} req/s sustained)",
        total_requests as f64 / elapsed
    );
    std::fs::write("BENCH_serve.json", format!("{bench}\n")).expect("write BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json");
}

fn main() {
    let fast = std::env::var("AKDA_FAST").is_ok();
    let secs: f64 = std::env::var("AKDA_SERVE_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 2.0 } else { 8.0 });
    let workers: usize = std::env::var("AKDA_SERVE_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let connect = argv
        .windows(2)
        .find(|w| w[0] == "--connect")
        .map(|w| w[1].clone())
        .or_else(|| std::env::var("AKDA_CONNECT").ok());
    if let Some(addr) = connect {
        run_connect(&addr, secs, workers);
        return;
    }

    let root = std::env::temp_dir().join(format!("akda_fleet_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("tmp registry dir");
    let registry = ModelRegistry::open(&root);
    let mut rows: BTreeMap<String, Mat> = BTreeMap::new();
    for (name, dim, classes, seed) in [("fa", 6usize, 3usize, 21u64), ("fb", 5, 2, 22)] {
        let (x, art) = tenant(dim, classes, seed);
        let mf = ModelManifest {
            method: "akda".into(),
            n_classes: classes,
            input_dim: dim,
            ..Default::default()
        };
        registry.publish(name, &art, &mf).expect("publish");
        rows.insert(name.to_string(), x);
    }

    let svc = FleetService::start(&registry, FleetOptions::default()).expect("fleet start");
    let client = svc.client();
    eprintln!("fleet load: {} tenants, {workers} clients each, {secs}s window", rows.len());

    let stop = AtomicBool::new(false);
    let counts: BTreeMap<String, AtomicUsize> =
        rows.keys().map(|n| (n.clone(), AtomicUsize::new(0))).collect();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (name, x) in &rows {
            for w in 0..workers {
                let client = client.clone();
                let (stop, counts) = (&stop, &counts);
                s.spawn(move || {
                    let mut i = w;
                    while !stop.load(Ordering::Relaxed) {
                        let row = x.row(i % x.rows()).to_vec();
                        client.score(name, row).expect("score");
                        counts[name].fetch_add(1, Ordering::Relaxed);
                        i += 1;
                    }
                });
            }
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let total_requests: usize = counts.values().map(|c| c.load(Ordering::Relaxed)).sum();
    let tenants_json: Vec<Json> = rows
        .keys()
        .map(|name| {
            let n = counts[name].load(Ordering::Relaxed);
            let hist =
                akda::obs::histogram_with("akda_fleet_latency_seconds", &[("tenant", name)]);
            let rejected = akda::obs::counter_with(
                "akda_fleet_rejects_total",
                &[("kind", "wrong_dim"), ("tenant", name)],
            )
            .get();
            let (p50_ms, p99_ms) = (hist.quantile(0.5) * 1e3, hist.quantile(0.99) * 1e3);
            eprintln!(
                "   {name}: {n} requests ({:.0} req/s), p50 {p50_ms:.3} ms, p99 {p99_ms:.3} ms",
                n as f64 / elapsed
            );
            obj(vec![
                ("model", Json::Str(name.clone())),
                ("requests", Json::Num(n as f64)),
                ("rejected", Json::Num(rejected as f64)),
                ("req_per_s", Json::Num(n as f64 / elapsed)),
                ("p50_ms", Json::Num(p50_ms)),
                ("p99_ms", Json::Num(p99_ms)),
            ])
        })
        .collect();
    let total = obj(vec![
        ("requests", Json::Num(total_requests as f64)),
        ("req_per_s", Json::Num(total_requests as f64 / elapsed)),
    ]);
    let bench = obj(vec![
        ("schema", Json::Str("akda-bench-serve/1".into())),
        ("transport", Json::Str("in_process".into())),
        ("duration_s", Json::Num(elapsed)),
        ("tenants", Json::Arr(tenants_json)),
        ("total", total),
    ]);
    println!(
        "fleet load: {total_requests} requests in {elapsed:.2}s ({:.0} req/s sustained)",
        total_requests as f64 / elapsed
    );
    std::fs::write("BENCH_serve.json", format!("{bench}\n")).expect("write BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json");

    drop(client); // all clients must go first: the dispatcher drains on close
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}
