//! Complexity-scaling bench (Sec. 4.5): training time of AKDA vs KDA vs
//! SRKDA vs the PJRT-accelerated AKDA as N grows, binary problem.
//!
//! The paper's claims this regenerates:
//!   * AKDA ≈ 40× fewer flops than KDA (13.3 N³ vs N³/3 + low-order) —
//!     the measured ratio should grow with N toward the flop ratio;
//!   * AKDA vs SRKDA differ only in low-order terms (O(C³) vs O(N²)), so
//!     AKDA ≥ SRKDA with the gap visible at larger N.
//!
//! Run: cargo bench --bench scaling

use std::sync::Arc;
use std::time::Instant;

use akda::coordinator::MethodId;
use akda::coordinator::{evaluate_ovr, Hyper};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::data::Split;
use akda::runtime::PjrtEngine;

fn problem(n: usize, dim: usize, seed: u64) -> Split {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n / 8, n - n / 8], // imbalanced, like OvR
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed,
    });
    let (x_test, y_test) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![32, 224],
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed: seed + 1,
    });
    Split { x_train: x, y_train: labels, x_test, y_test, n_classes: 2 }
}

fn time_method(
    split: &Split,
    id: MethodId,
    engine: Option<&Arc<PjrtEngine>>,
) -> (f64, f64) {
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    // warm-up for the PJRT path (executable compile is one-time)
    if matches!(id, MethodId::AkdaPjrt) {
        let _ = evaluate_ovr(split, id, hp, 1e-3, engine, None);
    }
    let t0 = Instant::now();
    let res = evaluate_ovr(split, id, hp, 1e-3, engine, None).expect("eval");
    let _wall = t0.elapsed().as_secs_f64();
    (res.train_s, res.map)
}

fn main() {
    let artifacts = std::env::var("AKDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = PjrtEngine::from_dir(std::path::Path::new(&artifacts)).ok().map(Arc::new);
    let dim = 64;
    println!("# scaling bench (binary OvR, L={dim}) — Sec. 4.5 complexity claims");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "N", "kda_s", "srkda_s", "akda_s", "akda_pjrt_s", "kda/akda", "srkda/akda"
    );
    for &n in &[128usize, 256, 512, 1024] {
        let split = problem(n, dim, n as u64);
        let (kda_t, _) = time_method(&split, MethodId::Kda, None);
        let (sr_t, _) = time_method(&split, MethodId::Srkda, None);
        let (ak_t, _) = time_method(&split, MethodId::Akda, None);
        let pj_t = engine
            .as_ref()
            .map(|e| time_method(&split, MethodId::AkdaPjrt, Some(e)).0);
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4} {:>12} {:>10.1} {:>10.2}",
            n,
            kda_t,
            sr_t,
            ak_t,
            pj_t.map(|t| format!("{t:.4}")).unwrap_or_else(|| "-".into()),
            kda_t / ak_t,
            sr_t / ak_t
        );
    }
    println!("# expectation: kda/akda grows with N (→ ~40x asymptotically);");
    println!("# srkda/akda ≥ 1 and grows slowly (O(N²) centering vs O(C³)).");
}
