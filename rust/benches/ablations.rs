//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A1  binary analytic θ (Eq. 50) vs the generic C×C EVD route
//!   A2  Cholesky block size (the L1/L3 tiling knob)
//!   A3  k-means vs NN-chain subclass partitioning (AKSDA vs KSDA's choice)
//!   A4  shape-bucket padding overhead (problem at 60%/95% of a bucket)
//!
//! Run: cargo bench --bench ablations

use std::time::Instant;

use akda::cluster::kmeans::{nn_partition, partition_classes};
use akda::da::core;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::{gram, Kernel};
use akda::linalg::{chol, Mat};

fn timeit<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn problem(n: usize, dim: usize) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n / 4, n - n / 4],
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed: 9,
    })
}

fn main() {
    // --- A1: binary analytic theta vs EVD route -------------------------
    let labels: Vec<usize> = vec![0; 100].into_iter().chain(vec![1; 5000]).collect();
    let t_ana = timeit(200, || core::theta_binary(&labels));
    let t_evd = timeit(200, || core::theta(&labels, 2));
    println!("# A1 binary theta: analytic {:.1}us vs EVD {:.1}us ({:.1}x)",
             t_ana * 1e6, t_evd * 1e6, t_evd / t_ana);

    // --- A2: Cholesky block size ----------------------------------------
    let (x, _) = problem(1024, 64);
    let mut k = gram(&x, Kernel::Rbf { rho: 0.1 });
    k.add_ridge(1e-3);
    println!("# A2 native blocked Cholesky, N=1024:");
    for &b in &[16usize, 32, 64, 128, 256] {
        let t = timeit(3, || chol::cholesky(&k, b).unwrap());
        println!("    block={b:<4} {:.3}s", t);
    }

    // --- A3: subclass partitioning --------------------------------------
    let (x, labels) = problem(600, 16);
    let t_km = timeit(5, || partition_classes(&x, &labels, 2, 3, 1));
    let t_nn = timeit(5, || {
        // NN partition per class (what KSDA uses)
        for cls in 0..2 {
            let idx: Vec<usize> =
                (0..labels.len()).filter(|&i| labels[i] == cls).collect();
            std::hint::black_box(nn_partition(&x.select_rows(&idx), 3));
        }
    });
    println!("# A3 partitioning, N=600 H=3: kmeans {:.1}ms vs nn-chain {:.1}ms",
             t_km * 1e3, t_nn * 1e3);

    // --- A4: bucket padding overhead (PJRT path) ------------------------
    let artifacts = std::env::var("AKDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if let Ok(engine) = akda::runtime::PjrtEngine::from_dir(std::path::Path::new(&artifacts)) {
        println!("# A4 bucket padding overhead (fit through the 512 bucket):");
        for &n in &[300usize, 480] {
            let (x, labels) = problem(n, 16);
            let theta = core::theta_binary(&labels);
            let _ = engine.fit(&x, &theta, Kernel::Rbf { rho: 0.1 }); // warm
            let t = timeit(5, || engine.fit(&x, &theta, Kernel::Rbf { rho: 0.1 }).unwrap());
            println!("    n={n:<4} ({:.0}% of bucket)  {:.3}s", 100.0 * n as f64 / 512.0, t);
        }
        println!("#    → cost is bucket-shaped, not n-shaped: padding is the price");
        println!("#      of AOT fixed shapes; pick bucket grids to bound waste.");
    } else {
        println!("# A4 skipped (no artifacts; run `make artifacts`)");
    }
}
