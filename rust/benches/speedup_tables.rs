//! Speedup tables bench (Tables 5, 6, 7): training/testing time of every
//! method relative to KDA, per dataset — the paper's headline exhibit.
//!
//! Env: AKDA_SUITE=med|cross10|cross100 (default med — Table 5; the full
//!      cross100 sweep regenerates Table 7 but costs ~30+ min of KDA time)
//!      AKDA_FAST=1 → subset (CI smoke)
//! Run: cargo bench --bench speedup_tables
//!
//! Besides the console table and per-suite CSV, this writes
//! `BENCH_train.json` (schema `akda-bench-train/1`, validated in CI via
//! `akda metrics --validate`) — the machine-readable training benchmark.

use std::collections::BTreeMap;

use akda::coordinator::{evaluate_ovr, Hyper, MethodId, WorkPool};
use akda::data::{cross_dataset_collection, med_datasets, Condition};
use akda::eval::tables::{results_csv, speedup_table, DatasetRow};
use akda::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// `BENCH_train.json` document: every (dataset, method) measurement,
/// with speedups over exact KDA wherever the KDA column ran.
fn bench_train_json(suite: &str, fast: bool, rows: &[DatasetRow]) -> Json {
    let datasets: Vec<Json> = rows
        .iter()
        .map(|row| {
            let kda = row.get("kda");
            let methods: Vec<Json> = row
                .results
                .iter()
                .map(|r| {
                    let mut m = vec![
                        ("method", Json::Str(r.method.clone())),
                        ("map", Json::Num(r.map)),
                        ("train_s", Json::Num(r.train_s)),
                        ("test_s", Json::Num(r.test_s)),
                    ];
                    if let Some(kda) = kda {
                        let (speedup_train, speedup_test) = r.speedup_over(kda);
                        m.push(("speedup_train", Json::Num(speedup_train)));
                        m.push(("speedup_test", Json::Num(speedup_test)));
                    }
                    obj(m)
                })
                .collect();
            obj(vec![
                ("name", Json::Str(row.dataset.clone())),
                ("methods", Json::Arr(methods)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("akda-bench-train/1".into())),
        ("suite", Json::Str(suite.into())),
        ("fast", Json::Bool(fast)),
        ("datasets", Json::Arr(datasets)),
    ])
}

fn main() {
    let suite = std::env::var("AKDA_SUITE").unwrap_or_else(|_| "med".into());
    let fast = std::env::var("AKDA_FAST").is_ok();
    let (mut datasets, cond, tag) = match suite.as_str() {
        "med" => (med_datasets(), Condition::Ex100, "Table 5 (MED)"),
        "cross10" => (cross_dataset_collection(), Condition::Ex10, "Table 6 (10Ex)"),
        _ => (cross_dataset_collection(), Condition::Ex100, "Table 7 (100Ex)"),
    };
    // on small machines KDA at N≳1000 costs minutes/class — cap the
    // per-dataset training-set size unless AKDA_FULL=1 asks for everything
    if std::env::var("AKDA_FULL").is_err() {
        datasets.retain(|d| d.n_classes * cond.per_class() <= 800);
    }
    let mut methods = MethodId::table_columns();
    if fast {
        datasets.truncate(3);
        methods = vec![MethodId::Kda, MethodId::Srkda, MethodId::Akda, MethodId::Ksda,
                       MethodId::Aksda];
    }
    // per-class jobs run on the pool; ϑ_m sums per-job stopwatch times, so
    // the ratios stay comparable (all methods see the same oversubscription)
    let pool = WorkPool::new((akda::util::threads::available() / 2).max(1));
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let results = methods
            .iter()
            .map(|&id| {
                let r = evaluate_ovr(&split, id, hp, 1e-3, None, Some(&pool)).expect("eval");
                eprintln!(
                    "   {:<8} train={:.3}s test={:.3}s",
                    r.method, r.train_s, r.test_s
                );
                r
            })
            .collect();
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }
    println!("{}", speedup_table(&format!("train/test speedup over KDA — {tag}"), &rows));
    let out = format!("bench_results_speedup_{suite}.csv");
    std::fs::write(&out, results_csv(&rows)).expect("write csv");
    eprintln!("wrote {out}");
    let bench = bench_train_json(&suite, fast, &rows);
    std::fs::write("BENCH_train.json", format!("{bench}\n")).expect("write BENCH_train.json");
    eprintln!("wrote BENCH_train.json");
}
