//! Speedup tables bench (Tables 5, 6, 7): training/testing time of every
//! method relative to KDA, per dataset — the paper's headline exhibit.
//!
//! Env: AKDA_SUITE=med|cross10|cross100 (default med — Table 5; the full
//!      cross100 sweep regenerates Table 7 but costs ~30+ min of KDA time)
//!      AKDA_FAST=1 → subset (CI smoke)
//!      AKDA_BACKENDS=scalar,parallel → rerun the suite once per linalg
//!      backend (`--backend` kinds) with the per-class worker pool OFF,
//!      so backend tile parallelism is the only concurrency dimension
//!      being timed; emits schema akda-bench-train/2 (every method row
//!      tagged with its backend) and a `BACKEND_GATE` line CI asserts on
//! Run: cargo bench --bench speedup_tables
//!
//! Besides the console table and per-suite CSV, this writes
//! `BENCH_train.json` (schema `akda-bench-train/1`, or `/2` under a
//! backend sweep; validated in CI via `akda metrics --validate`) — the
//! machine-readable training benchmark.

use std::collections::BTreeMap;

use akda::coordinator::{evaluate_ovr, Hyper, MethodId, WorkPool};
use akda::data::{cross_dataset_collection, med_datasets, Condition, DatasetSpec};
use akda::eval::tables::{results_csv, speedup_table, DatasetRow};
use akda::eval::MethodResult;
use akda::linalg::{backend, BackendKind};
use akda::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// One (dataset, method) measurement row; `backend` tags v2 documents.
fn method_json(r: &MethodResult, kda: Option<&MethodResult>, backend: Option<&str>) -> Json {
    let mut m = vec![
        ("method", Json::Str(r.method.clone())),
        ("map", Json::Num(r.map)),
        ("train_s", Json::Num(r.train_s)),
        ("test_s", Json::Num(r.test_s)),
    ];
    if let Some(b) = backend {
        m.push(("backend", Json::Str(b.to_string())));
    }
    if let Some(kda) = kda {
        let (speedup_train, speedup_test) = r.speedup_over(kda);
        m.push(("speedup_train", Json::Num(speedup_train)));
        m.push(("speedup_test", Json::Num(speedup_test)));
    }
    obj(m)
}

/// `BENCH_train.json` v1 document: every (dataset, method) measurement,
/// with speedups over exact KDA wherever the KDA column ran.
fn bench_train_json(suite: &str, fast: bool, rows: &[DatasetRow]) -> Json {
    let datasets: Vec<Json> = rows
        .iter()
        .map(|row| {
            let kda = row.get("kda");
            let methods: Vec<Json> =
                row.results.iter().map(|r| method_json(r, kda, None)).collect();
            obj(vec![
                ("name", Json::Str(row.dataset.clone())),
                ("methods", Json::Arr(methods)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("akda-bench-train/1".into())),
        ("suite", Json::Str(suite.into())),
        ("fast", Json::Bool(fast)),
        ("datasets", Json::Arr(datasets)),
    ])
}

/// `BENCH_train.json` v2 document: the same suite measured once per
/// linalg backend; each dataset's `methods` array concatenates the
/// per-backend sweeps, every row tagged with its `backend`. Speedups
/// stay within-backend (each sweep's own KDA column) so the KDA
/// baseline and the method it normalizes share a backend.
fn bench_train_json_v2(suite: &str, fast: bool, sweeps: &[(BackendKind, Vec<DatasetRow>)]) -> Json {
    let (_, first) = &sweeps[0];
    let datasets: Vec<Json> = first
        .iter()
        .map(|lead| {
            let mut methods = Vec::new();
            for (kind, rows) in sweeps {
                let Some(row) = rows.iter().find(|r| r.dataset == lead.dataset) else {
                    continue;
                };
                let kda = row.get("kda");
                methods.extend(
                    row.results.iter().map(|r| method_json(r, kda, Some(kind.name()))),
                );
            }
            obj(vec![
                ("name", Json::Str(lead.dataset.clone())),
                ("methods", Json::Arr(methods)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("akda-bench-train/2".into())),
        ("suite", Json::Str(suite.into())),
        ("fast", Json::Bool(fast)),
        ("datasets", Json::Arr(datasets)),
    ])
}

/// The CI speedup gate: on the largest dataset of the sweep, compare
/// akda training time under the scalar and parallel backends and print
/// one greppable line. CI fails the build when the ratio drops below
/// its floor — a regression in the parallel backend's scheduling would
/// otherwise land silently (numerics are covered by backend_equiv.rs;
/// this guards the speed that justifies the seam).
fn print_backend_gate(
    datasets: &[DatasetSpec],
    cond: Condition,
    sweeps: &[(BackendKind, Vec<DatasetRow>)],
) {
    let Some(largest) = datasets.iter().max_by_key(|d| d.n_classes * cond.per_class()) else {
        return;
    };
    let train_s = |kind: BackendKind| -> Option<f64> {
        let (_, rows) = sweeps.iter().find(|(k, _)| *k == kind)?;
        let row = rows.iter().find(|r| r.dataset == largest.name)?;
        Some(row.get("akda")?.train_s)
    };
    if let (Some(s), Some(p)) = (train_s(BackendKind::Scalar), train_s(BackendKind::Parallel)) {
        let ratio = if p > 0.0 { s / p } else { f64::INFINITY };
        println!(
            "BACKEND_GATE dataset={} scalar_train_s={s:.4} parallel_train_s={p:.4} \
             ratio={ratio:.3}",
            largest.name
        );
    }
}

fn main() {
    let suite = std::env::var("AKDA_SUITE").unwrap_or_else(|_| "med".into());
    let fast = std::env::var("AKDA_FAST").is_ok();
    let (mut datasets, cond, tag) = match suite.as_str() {
        "med" => (med_datasets(), Condition::Ex100, "Table 5 (MED)"),
        "cross10" => (cross_dataset_collection(), Condition::Ex10, "Table 6 (10Ex)"),
        _ => (cross_dataset_collection(), Condition::Ex100, "Table 7 (100Ex)"),
    };
    // on small machines KDA at N≳1000 costs minutes/class — cap the
    // per-dataset training-set size unless AKDA_FULL=1 asks for everything
    if std::env::var("AKDA_FULL").is_err() {
        datasets.retain(|d| d.n_classes * cond.per_class() <= 800);
    }
    let mut methods = MethodId::table_columns();
    if fast {
        datasets.truncate(3);
        methods = vec![MethodId::Kda, MethodId::Srkda, MethodId::Akda, MethodId::Ksda,
                       MethodId::Aksda];
    }
    let backends: Vec<BackendKind> = match std::env::var("AKDA_BACKENDS") {
        Ok(csv) => csv
            .split(',')
            .map(|s| {
                BackendKind::from_name(s.trim()).unwrap_or_else(|| {
                    panic!("AKDA_BACKENDS: unknown backend {s:?} (scalar|blocked|parallel|auto)")
                })
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };

    let run_suite = |pool: Option<&WorkPool>| -> Vec<DatasetRow> {
        let mut rows = Vec::new();
        for spec in &datasets {
            eprintln!("== {} [{}]", spec.name, cond.name());
            let split = spec.split(cond);
            let results = methods
                .iter()
                .map(|&id| {
                    let r = evaluate_ovr(&split, id, hp, 1e-3, None, pool).expect("eval");
                    eprintln!(
                        "   {:<8} train={:.3}s test={:.3}s",
                        r.method, r.train_s, r.test_s
                    );
                    r
                })
                .collect();
            rows.push(DatasetRow { dataset: spec.name.to_string(), results });
        }
        rows
    };

    if backends.is_empty() {
        // per-class jobs run on the pool; ϑ_m sums per-job stopwatch times,
        // so the ratios stay comparable (all methods see the same
        // oversubscription)
        let pool = WorkPool::new((akda::util::threads::available() / 2).max(1));
        let rows = run_suite(Some(&pool));
        println!("{}", speedup_table(&format!("train/test speedup over KDA — {tag}"), &rows));
        let out = format!("bench_results_speedup_{suite}.csv");
        std::fs::write(&out, results_csv(&rows)).expect("write csv");
        eprintln!("wrote {out}");
        let bench = bench_train_json(&suite, fast, &rows);
        std::fs::write("BENCH_train.json", format!("{bench}\n"))
            .expect("write BENCH_train.json");
        eprintln!("wrote BENCH_train.json");
        return;
    }

    // backend sweep: one full pass per backend, per-class pool OFF so the
    // only parallelism in the timing is the backend's own tile fan-out
    let mut sweeps: Vec<(BackendKind, Vec<DatasetRow>)> = Vec::new();
    for &kind in &backends {
        eprintln!("==== backend {} ====", kind.name());
        backend::set_global(kind);
        let rows = run_suite(None);
        println!(
            "{}",
            speedup_table(
                &format!("train/test speedup over KDA — {tag} [backend {}]", kind.name()),
                &rows
            )
        );
        sweeps.push((kind, rows));
    }
    let bench = bench_train_json_v2(&suite, fast, &sweeps);
    std::fs::write("BENCH_train.json", format!("{bench}\n")).expect("write BENCH_train.json");
    eprintln!("wrote BENCH_train.json (backend sweep: akda-bench-train/2)");
    print_backend_gate(&datasets, cond, &sweeps);
}
