//! Speedup tables bench (Tables 5, 6, 7): training/testing time of every
//! method relative to KDA, per dataset — the paper's headline exhibit.
//!
//! Env: AKDA_SUITE=med|cross10|cross100 (default med — Table 5; the full
//!      cross100 sweep regenerates Table 7 but costs ~30+ min of KDA time)
//!      AKDA_FAST=1 → subset (CI smoke)
//! Run: cargo bench --bench speedup_tables

use akda::coordinator::{evaluate_ovr, Hyper, MethodId, WorkPool};
use akda::data::{cross_dataset_collection, med_datasets, Condition};
use akda::eval::tables::{results_csv, speedup_table, DatasetRow};

fn main() {
    let suite = std::env::var("AKDA_SUITE").unwrap_or_else(|_| "med".into());
    let fast = std::env::var("AKDA_FAST").is_ok();
    let (mut datasets, cond, tag) = match suite.as_str() {
        "med" => (med_datasets(), Condition::Ex100, "Table 5 (MED)"),
        "cross10" => (cross_dataset_collection(), Condition::Ex10, "Table 6 (10Ex)"),
        _ => (cross_dataset_collection(), Condition::Ex100, "Table 7 (100Ex)"),
    };
    // on small machines KDA at N≳1000 costs minutes/class — cap the
    // per-dataset training-set size unless AKDA_FULL=1 asks for everything
    if std::env::var("AKDA_FULL").is_err() {
        datasets.retain(|d| d.n_classes * cond.per_class() <= 800);
    }
    let mut methods = MethodId::table_columns();
    if fast {
        datasets.truncate(3);
        methods = vec![MethodId::Kda, MethodId::Srkda, MethodId::Akda, MethodId::Ksda,
                       MethodId::Aksda];
    }
    // per-class jobs run on the pool; ϑ_m sums per-job stopwatch times, so
    // the ratios stay comparable (all methods see the same oversubscription)
    let pool = WorkPool::new((akda::util::threads::available() / 2).max(1));
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let results = methods
            .iter()
            .map(|&id| {
                let r = evaluate_ovr(&split, id, hp, 1e-3, None, Some(&pool)).expect("eval");
                eprintln!(
                    "   {:<8} train={:.3}s test={:.3}s",
                    r.method, r.train_s, r.test_s
                );
                r
            })
            .collect();
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }
    println!("{}", speedup_table(&format!("train/test speedup over KDA — {tag}"), &rows));
    let out = format!("bench_results_speedup_{suite}.csv");
    std::fs::write(&out, results_csv(&rows)).expect("write csv");
    eprintln!("wrote {out}");
}
