//! Sec. 6.2 toy-example timing: AKDA's learn time decomposition (kernel
//! matrix vs linear-system solve) and the AKDA-vs-KDA gap on the
//! rgbd-apple-shaped binary problem (paper: 2.25 s vs 140.96 s at
//! N=5100; here scaled to the 2048 bucket — the *ratio* is the claim).
//!
//! Run: cargo bench --bench toy_timing

use std::time::Instant;

use akda::da::core;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::{gram, Kernel};
use akda::linalg::{chol, sym_eig_desc, Mat};

fn main() {
    let (n1, n2, dim) = (40usize, 2000usize, 64usize);
    let n = n1 + n2;
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n1, n2],
        dim,
        class_sep: 2.2,
        noise: 1.0,
        modes_per_class: 6,
        seed: 42,
    });
    println!("# toy timing (Sec. 6.2): N={n}, L={dim}, linear kernel");

    // --- AKDA: K + Cholesky solve --------------------------------------
    let theta = core::theta_binary(&labels);
    let t0 = Instant::now();
    let mut k = gram(&x, Kernel::Linear);
    let t_k = t0.elapsed().as_secs_f64();
    k.add_ridge(1e-3);
    let t0 = Instant::now();
    let psi = chol::spd_solve(&k, &theta, 64).expect("SPD");
    let t_solve = t0.elapsed().as_secs_f64();
    let akda_total = t_k + t_solve;
    println!("akda: total={akda_total:.2}s  (K: {t_k:.2}s, solve: {t_solve:.2}s)");
    assert!(psi.is_finite());

    // --- KDA: scatter matrices + Cholesky + full EVD --------------------
    let t0 = Instant::now();
    let cb = core::central_factor_b(&labels, 2);
    let cw = core::central_factor_w(&labels, 2);
    let sb = k.matmul(&cb.matmul(&k));
    let mut sw = k.matmul(&cw.matmul(&k));
    sw.add_ridge(1e-3 * (1.0 + sw.max_abs()));
    let t_scatter = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let l = chol::cholesky(&sw, 64).expect("SPD");
    let y = chol::solve_lower(&l, &sb);
    let m = chol::solve_lower(&l, &y.transpose());
    let m = m.add(&m.transpose()).scale(0.5);
    let t_whiten = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let eig = sym_eig_desc(&m).expect("EVD");
    let t_evd = t0.elapsed().as_secs_f64();
    let mut u = Mat::zeros(n, 1);
    for r in 0..n {
        u[(r, 0)] = eig.vectors[(r, 0)];
    }
    let _psi_kda = chol::solve_upper_from_lower(&l, &u);
    let kda_total = t_scatter + t_whiten + t_evd;
    println!(
        "kda:  total={kda_total:.2}s  (scatter: {t_scatter:.2}s, whiten: {t_whiten:.2}s, EVD: {t_evd:.2}s)"
    );
    println!("speedup akda over kda: {:.1}x  (paper: ~63x at N=5100)", kda_total / akda_total);
    println!("# the EVD term (9N³) dominates KDA exactly as Sec. 4.5 predicts");
}
