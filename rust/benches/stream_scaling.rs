//! Streaming-AKDA scaling bench: in-memory approximate training (full
//! N×m Φ resident) vs the out-of-core tiled pipeline (`da::akda_stream`,
//! peak O(B·m + m²)) as N grows — time, accumulator residency, and the
//! solve equivalence gap.
//!
//! Variants per N:
//!   mem     — `AkdaApprox::prepare` + `PreparedFeatures::fit` (dense Φ)
//!   tile    — `PreparedStream::accumulate` with the *same* feature map over
//!             an in-memory block source: isolates the tiling itself; the
//!             acceptance gate requires its solution within 1e-10 of mem
//!   shard-k — the stream split into k ∈ {1,2,4} stride shards, each
//!             accumulated into its own `TiledAccumulator`, then merged
//!             (`TiledAccumulator::merge`) and factorized; the timed region
//!             includes the merge, and every k must hit the same 1e-10 gate
//!   csv     — fully out-of-core `prepare_stream` from a CSV on disk
//!             (reservoir-sampled landmarks, file never loaded whole)
//!
//! Residency columns are the exact f64 counts the two paths keep live
//! during accumulation (`StreamStats::{dense,peak}_resident_f64`) — the
//! B-independent m² core vs the N-proportional Φ.
//!
//! Env: AKDA_STREAM_MAX_N (default 8192), AKDA_LANDMARKS (default 64),
//!      AKDA_BLOCK (default 512)
//! Run: cargo bench --bench stream_scaling
//!
//! Emits `BENCH_train.json` (`akda-bench-train/1`) with one dataset entry
//! per N and one method row per variant, so the sharded-training perf
//! trajectory is machine-readable (`akda metrics --validate BENCH_train.json`).

use std::collections::BTreeMap;
use std::time::Instant;

use akda::da::akda_approx::AkdaApprox;
use akda::da::akda_stream::{PreparedStream, TiledAccumulator};
use akda::data::stream::{BlockSource, CsvBlockSource, MemBlockSource, StridedBlockSource};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::util::json::Json;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

fn problem(n: usize, dim: usize, seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n / 8, n - n / 8], // imbalanced, like OvR
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed,
    })
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn mb(f64s: usize) -> f64 {
    f64s as f64 * 8.0 / 1e6
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn method_row(method: &str, train_s: f64) -> Json {
    obj(vec![
        ("method", Json::Str(method.to_string())),
        ("map", Json::Num(0.0)),
        ("train_s", Json::Num(train_s)),
        ("test_s", Json::Num(0.0)),
    ])
}

fn main() {
    let dim = 32;
    let max_n = env_usize("AKDA_STREAM_MAX_N", 8192);
    let m = env_usize("AKDA_LANDMARKS", 64);
    let block = env_usize("AKDA_BLOCK", 512);
    let kernel = Kernel::Rbf { rho: 0.05 };

    println!("# stream scaling bench (binary, F={dim}, m={m}, B={block})");
    println!(
        "{:>7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10} {:>12}",
        "N", "mem_s", "tile_s", "shard1_s", "shard2_s", "shard4_s", "csv_s", "mem_MB", "tile_MB",
        "gap"
    );

    let csv_dir = std::env::temp_dir().join("akda_stream_bench");
    std::fs::create_dir_all(&csv_dir).expect("temp dir");

    let mut sizes = Vec::new();
    let mut n = 1024usize;
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    let mut worst_gap = 0.0_f64;
    let mut last_ratio = 1.0_f64;
    let mut datasets = Vec::new();
    for &n in &sizes {
        let (x, labels) = problem(n, dim, n as u64);
        let cfg = AkdaApprox::nystrom(kernel, m);

        // in-memory: full Φ resident
        let t0 = Instant::now();
        let prep = cfg.prepare(&x).expect("dense prepare");
        let w_mem = prep.fit(&labels, 2).expect("dense fit").w;
        let t_mem = t0.elapsed().as_secs_f64();

        // tiled, same map: isolates the out-of-core accumulation
        let t0 = Instant::now();
        let mut src = MemBlockSource::new(&x, &labels, block);
        let ps = PreparedStream::accumulate(&cfg, prep.map.clone(), &mut src)
            .expect("tiled accumulate");
        let w_tile = ps.solve_w_class(0).expect("tiled solve");
        let t_tile = t0.elapsed().as_secs_f64();
        let gap = w_tile.sub(&w_mem).max_abs();
        worst_gap = worst_gap.max(gap);

        // sharded: split the stream into k stride shards, accumulate each
        // into its own TiledAccumulator, then merge — the distributed map
        // side in one process; merge time is inside the timed region
        let mut t_shard = Vec::with_capacity(SHARD_COUNTS.len());
        for &k in &SHARD_COUNTS {
            let t0 = Instant::now();
            let mut merged: Option<TiledAccumulator> = None;
            for index in 0..k {
                let mut src = StridedBlockSource::new(
                    MemBlockSource::new(&x, &labels, block),
                    index,
                    k,
                )
                .expect("stride source");
                let mut acc = TiledAccumulator::new(prep.map.dim());
                src.reset().expect("reset");
                while let Some(b) = src.next_block().expect("next block") {
                    let phi = prep.map.transform(&b.x);
                    acc.absorb(&phi, &b.labels).expect("absorb");
                }
                merged = Some(match merged {
                    None => acc,
                    Some(mut left) => {
                        left.merge(&acc).expect("shard merge");
                        left
                    }
                });
            }
            let agg = merged.expect("k >= 1").into_aggregates(2).expect("aggregates");
            let ps_k = PreparedStream::from_aggregates(
                prep.map.clone(),
                agg,
                cfg.eps,
                akda::linalg::chol::DEFAULT_BLOCK,
            )
            .expect("merged factorize");
            let w_k = ps_k.solve_w_class(0).expect("merged solve");
            t_shard.push(t0.elapsed().as_secs_f64());
            // every shard count must land on the same solution as mem:
            // the accumulator merge is exact elementwise addition
            let gap_k = w_k.sub(&w_mem).max_abs();
            worst_gap = worst_gap.max(gap_k);
        }

        // fully out-of-core: stream the CSV from disk, landmarks from a
        // reservoir sample — N ≫ RAM shape (only correctness-checked
        // above; landmarks differ from the in-memory fit by design)
        let path = csv_dir.join(format!("train_{n}.csv"));
        akda::data::csv::save_labeled(&path, &x, &labels).expect("write csv");
        drop(x);
        let t0 = Instant::now();
        let mut csv_src = CsvBlockSource::open(&path, block).expect("open csv");
        let ps_csv = cfg.prepare_stream(&mut csv_src).expect("csv prepare");
        let _w_csv = ps_csv.solve_w_class(0).expect("csv solve");
        let t_csv = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_file(&path);

        last_ratio = mb(ps.stats.dense_resident_f64()) / mb(ps.stats.peak_resident_f64());
        println!(
            "{:>7} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>10.2} {:>10.2} {:>12.3e}",
            n,
            t_mem,
            t_tile,
            t_shard[0],
            t_shard[1],
            t_shard[2],
            t_csv,
            mb(ps.stats.dense_resident_f64()),
            mb(ps.stats.peak_resident_f64()),
            gap,
        );

        let mut methods = vec![
            method_row("mem", t_mem),
            method_row("tile", t_tile),
            method_row("csv", t_csv),
        ];
        for (i, &k) in SHARD_COUNTS.iter().enumerate() {
            methods.push(method_row(&format!("shard-k{k}"), t_shard[i]));
        }
        datasets.push(obj(vec![
            ("name", Json::Str(format!("stream-n{n}"))),
            ("methods", Json::Arr(methods)),
        ]));
    }

    let bench = obj(vec![
        ("schema", Json::Str("akda-bench-train/1".to_string())),
        ("suite", Json::Str("stream-scaling".to_string())),
        ("fast", Json::Bool(max_n <= 2048)),
        ("datasets", Json::Arr(datasets)),
    ]);
    std::fs::write("BENCH_train.json", format!("{bench}\n")).expect("write BENCH_train.json");
    println!("# wrote BENCH_train.json ({} sizes, shard counts {SHARD_COUNTS:?})", sizes.len());

    println!(
        "# worst tiling gap {worst_gap:.3e} (target <= 1e-10); residency ratio at \
         largest N: {last_ratio:.1}x (grows linearly in N at fixed B)"
    );
    println!(
        "# acceptance: {}",
        if worst_gap <= 1e-10 { "PASS" } else { "CHECK" }
    );
}
