//! Streaming-AKDA scaling bench: in-memory approximate training (full
//! N×m Φ resident) vs the out-of-core tiled pipeline (`da::akda_stream`,
//! peak O(B·m + m²)) as N grows — time, accumulator residency, and the
//! solve equivalence gap.
//!
//! Three variants per N:
//!   mem   — `AkdaApprox::prepare` + `PreparedFeatures::fit` (dense Φ)
//!   tile  — `PreparedStream::accumulate` with the *same* feature map over
//!           an in-memory block source: isolates the tiling itself; the
//!           acceptance gate requires its solution within 1e-10 of mem
//!   csv   — fully out-of-core `prepare_stream` from a CSV on disk
//!           (reservoir-sampled landmarks, file never loaded whole)
//!
//! Residency columns are the exact f64 counts the two paths keep live
//! during accumulation (`StreamStats::{dense,peak}_resident_f64`) — the
//! B-independent m² core vs the N-proportional Φ.
//!
//! Env: AKDA_STREAM_MAX_N (default 8192), AKDA_LANDMARKS (default 64),
//!      AKDA_BLOCK (default 512)
//! Run: cargo bench --bench stream_scaling

use std::time::Instant;

use akda::da::akda_approx::AkdaApprox;
use akda::da::akda_stream::PreparedStream;
use akda::data::stream::{CsvBlockSource, MemBlockSource};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;

fn problem(n: usize, dim: usize, seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n / 8, n - n / 8], // imbalanced, like OvR
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed,
    })
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn mb(f64s: usize) -> f64 {
    f64s as f64 * 8.0 / 1e6
}

fn main() {
    let dim = 32;
    let max_n = env_usize("AKDA_STREAM_MAX_N", 8192);
    let m = env_usize("AKDA_LANDMARKS", 64);
    let block = env_usize("AKDA_BLOCK", 512);
    let kernel = Kernel::Rbf { rho: 0.05 };

    println!("# stream scaling bench (binary, F={dim}, m={m}, B={block})");
    println!(
        "{:>7} {:>9} {:>9} {:>9} {:>10} {:>10} {:>12}",
        "N", "mem_s", "tile_s", "csv_s", "mem_MB", "tile_MB", "gap"
    );

    let csv_dir = std::env::temp_dir().join("akda_stream_bench");
    std::fs::create_dir_all(&csv_dir).expect("temp dir");

    let mut sizes = Vec::new();
    let mut n = 1024usize;
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    let mut worst_gap = 0.0_f64;
    let mut last_ratio = 1.0_f64;
    for &n in &sizes {
        let (x, labels) = problem(n, dim, n as u64);
        let cfg = AkdaApprox::nystrom(kernel, m);

        // in-memory: full Φ resident
        let t0 = Instant::now();
        let prep = cfg.prepare(&x).expect("dense prepare");
        let w_mem = prep.fit(&labels, 2).expect("dense fit").w;
        let t_mem = t0.elapsed().as_secs_f64();

        // tiled, same map: isolates the out-of-core accumulation
        let t0 = Instant::now();
        let mut src = MemBlockSource::new(&x, &labels, block);
        let ps = PreparedStream::accumulate(&cfg, prep.map.clone(), &mut src)
            .expect("tiled accumulate");
        let w_tile = ps.solve_w_class(0).expect("tiled solve");
        let t_tile = t0.elapsed().as_secs_f64();
        let gap = w_tile.sub(&w_mem).max_abs();
        worst_gap = worst_gap.max(gap);

        // fully out-of-core: stream the CSV from disk, landmarks from a
        // reservoir sample — N ≫ RAM shape (only correctness-checked
        // above; landmarks differ from the in-memory fit by design)
        let path = csv_dir.join(format!("train_{n}.csv"));
        akda::data::csv::save_labeled(&path, &x, &labels).expect("write csv");
        drop(x);
        let t0 = Instant::now();
        let mut csv_src = CsvBlockSource::open(&path, block).expect("open csv");
        let ps_csv = cfg.prepare_stream(&mut csv_src).expect("csv prepare");
        let _w_csv = ps_csv.solve_w_class(0).expect("csv solve");
        let t_csv = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_file(&path);

        last_ratio = mb(ps.stats.dense_resident_f64()) / mb(ps.stats.peak_resident_f64());
        println!(
            "{:>7} {:>9.4} {:>9.4} {:>9.4} {:>10.2} {:>10.2} {:>12.3e}",
            n,
            t_mem,
            t_tile,
            t_csv,
            mb(ps.stats.dense_resident_f64()),
            mb(ps.stats.peak_resident_f64()),
            gap,
        );
    }

    println!(
        "# worst tiling gap {worst_gap:.3e} (target <= 1e-10); residency ratio at \
         largest N: {last_ratio:.1}x (grows linearly in N at fixed B)"
    );
    println!(
        "# acceptance: {}",
        if worst_gap <= 1e-10 { "PASS" } else { "CHECK" }
    );
}
