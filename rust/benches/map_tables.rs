//! MAP tables bench (Tables 2, 3, 4): every method column on every
//! registry dataset, fixed hyper-parameters (run the CLI with `--cv` for
//! the full CV protocol — this bench keeps the grid fixed so the run is
//! comparable and quick).
//!
//! Env: AKDA_SUITE=med|cross10|cross100 (default cross10)
//!      AKDA_FAST=1 → subset of datasets and methods (CI smoke)
//! Run: cargo bench --bench map_tables

use akda::coordinator::{evaluate_ovr, Hyper, MethodId, WorkPool};
use akda::data::{cross_dataset_collection, med_datasets, Condition};
use akda::eval::tables::{map_table, results_csv, DatasetRow};

fn main() {
    let suite = std::env::var("AKDA_SUITE").unwrap_or_else(|_| "cross10".into());
    let fast = std::env::var("AKDA_FAST").is_ok();
    let (mut datasets, cond, tag) = match suite.as_str() {
        "med" => (med_datasets(), Condition::Ex100, "Table 2 (MED)"),
        "cross100" => (cross_dataset_collection(), Condition::Ex100, "Table 4 (100Ex)"),
        _ => (cross_dataset_collection(), Condition::Ex10, "Table 3 (10Ex)"),
    };
    let mut methods = MethodId::table_columns();
    if fast {
        datasets.truncate(3);
        methods = vec![MethodId::Lda, MethodId::Kda, MethodId::Srkda, MethodId::Akda,
                       MethodId::Aksda];
    }
    let pool = WorkPool::new(akda::util::threads::available());
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let results = methods
            .iter()
            .map(|&id| {
                let r = evaluate_ovr(&split, id, hp, 1e-3, None, Some(&pool)).expect("eval");
                eprintln!("   {:<8} MAP={:.2}%", r.method, 100.0 * r.map);
                r
            })
            .collect();
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }
    println!("{}", map_table(&format!("MAP rates — {tag}"), &rows));
    let out = format!("bench_results_map_{suite}.csv");
    std::fs::write(&out, results_csv(&rows)).expect("write csv");
    eprintln!("wrote {out}");
}
