//! Approximate-AKDA scaling bench: exact AKDA (O(N²F) Gram + N³/3
//! Cholesky) vs the `approx` subsystem's Nyström / RFF training path
//! (O(N m F) features + O(N m²) Gram + m³/3 Cholesky) as N grows, on a
//! binary OvR-style problem.
//!
//! Acceptance probe for the subsystem: at the largest N the Nyström path
//! must train ≥5× faster than exact AKDA while its toy-example accuracy
//! stays within 2 points of exact.
//!
//! Env: AKDA_APPROX_MAX_N (default 4096), AKDA_LANDMARKS (default 96),
//!      AKDA_RFF_FEATURES (default 256)
//! Run: cargo bench --bench approx_scaling

use std::time::Instant;

use akda::da::akda::Akda;
use akda::da::akda_approx::AkdaApprox;
use akda::da::{DrMethod, Projection};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::svm::{LinearSvm, LinearSvmConfig};

fn problem(n: usize, dim: usize, seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n / 8, n - n / 8], // imbalanced, like OvR
        dim,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 2,
        seed,
    })
}

/// Train an LSVM in the projected subspace and report test accuracy.
fn accuracy(
    proj: &dyn Projection,
    x_train: &Mat,
    y_train: &[usize],
    x_test: &Mat,
    y_test: &[usize],
) -> f64 {
    let z_train = proj.project(x_train);
    let z_test = proj.project(x_test);
    let y_pm: Vec<f64> = y_train.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
    let svm = LinearSvm::train(&z_train, &y_pm, LinearSvmConfig::default());
    let scores = svm.decision_batch(&z_test);
    let correct = scores
        .iter()
        .zip(y_test.iter())
        .filter(|&(&s, &l)| (s > 0.0) == (l == 0))
        .count();
    correct as f64 / y_test.len() as f64
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let dim = 64;
    let max_n = env_usize("AKDA_APPROX_MAX_N", 4096);
    let landmarks = env_usize("AKDA_LANDMARKS", 96);
    let rff_features = env_usize("AKDA_RFF_FEATURES", 256);
    let kernel = Kernel::Rbf { rho: 0.05 };

    println!("# approx scaling bench (binary, L={dim}, m={landmarks}, rff_d={rff_features})");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "N", "akda_s", "nystrom_s", "rff_s", "nys_spd", "rff_spd", "acc_ex", "acc_nys", "acc_rff"
    );

    // 512, 1024, ... doubling up to max_n — raising AKDA_APPROX_MAX_N
    // extends the sweep, lowering it trims the tail
    let mut sizes = Vec::new();
    let mut n = 512usize;
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    let mut last: Option<(f64, f64, f64)> = None; // (nys speedup, exact acc, nys acc)
    for &n in &sizes {
        let (x, labels) = problem(n, dim, n as u64);
        let (x_test, y_test) = problem(512, dim, n as u64 + 1);

        let exact = Akda::new(kernel);
        let t0 = Instant::now();
        let p_exact = exact.fit(&x, &labels, 2).expect("exact AKDA");
        let t_exact = t0.elapsed().as_secs_f64();

        let nystrom = AkdaApprox::nystrom(kernel, landmarks);
        let t0 = Instant::now();
        let p_nys = nystrom.fit(&x, &labels, 2).expect("nystrom AKDA");
        let t_nys = t0.elapsed().as_secs_f64();

        let rff = AkdaApprox::rff(kernel, rff_features);
        let t0 = Instant::now();
        let p_rff = rff.fit(&x, &labels, 2).expect("rff AKDA");
        let t_rff = t0.elapsed().as_secs_f64();

        let acc_ex = accuracy(p_exact.as_ref(), &x, &labels, &x_test, &y_test);
        let acc_nys = accuracy(p_nys.as_ref(), &x, &labels, &x_test, &y_test);
        let acc_rff = accuracy(p_rff.as_ref(), &x, &labels, &x_test, &y_test);

        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>8.1}x {:>8.1}x {:>8.1}% {:>8.1}% {:>8.1}%",
            n,
            t_exact,
            t_nys,
            t_rff,
            t_exact / t_nys.max(1e-12),
            t_exact / t_rff.max(1e-12),
            100.0 * acc_ex,
            100.0 * acc_nys,
            100.0 * acc_rff,
        );
        last = Some((t_exact / t_nys.max(1e-12), acc_ex, acc_nys));
    }

    if let Some((speedup, acc_ex, acc_nys)) = last {
        let gap = 100.0 * (acc_ex - acc_nys).abs();
        println!(
            "# largest N: nystrom speedup {speedup:.1}x (target >=5x), accuracy gap {gap:.2} \
             points (target <=2)"
        );
        println!(
            "# acceptance: {}",
            if speedup >= 5.0 && gap <= 2.0 { "PASS" } else { "CHECK" }
        );
    }
}
