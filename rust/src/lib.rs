//! # akda — Accelerated Kernel Discriminant Analysis
//!
//! Production-quality reproduction of *"Accelerated kernel discriminant
//! analysis"* (Gkalelis & Mezaris): AKDA + AKSDA with the full baseline
//! zoo (KDA, SRKDA, GDA, KSDA, GSDA, LDA, PCA, LSVM, KSVM), evaluated
//! under the paper's protocol, as a three-layer Rust + JAX + Pallas stack:
//!
//! * L1/L2 (build time, python): Pallas gram kernels + blocked Cholesky
//!   lowered to fixed-shape HLO artifacts (`artifacts/*.hlo.txt`).
//! * L3 (this crate): PJRT runtime, dataset/eval/SVM substrates, and the
//!   coordinator that runs the paper's one-vs-rest training protocol.
//! * `approx`: kernel-feature approximation subsystem (Nyström landmarks,
//!   random Fourier features) feeding `da::akda_approx` — the O(N m²)
//!   large-N training path (m ≪ N) beyond the paper's exact O(N³) regime.
//! * `model`: trained-model artifact subsystem — versioned, checksummed
//!   `.akda` persistence, a directory-backed registry, and hot-reload so
//!   `akda serve --model` never retrains.
//! * `obs`: dependency-free observability — counters/gauges/histograms
//!   behind a global registry, phase spans, Prometheus + JSONL
//!   snapshots, and the `BENCH_*.json` schema validators.
//!
//! See `DESIGN.md` for the systems inventory and the experiment index.

pub mod approx;
pub mod cluster;
pub mod coordinator;
pub mod da;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod svm;
pub mod util;
