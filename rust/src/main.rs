//! `akda` CLI — the coordinator launcher.
//!
//! Subcommands:
//!   datasets                      print the Table-1 registry (scaled)
//!   eval --suite med|cross10|cross100 [...]
//!                                 regenerate the MAP + speedup tables
//!   toy                           Sec. 6.2 toy example (Figs. 2–3 data)
//!   train --dataset NAME          fit a detector bank, evaluate it, and
//!                                 publish it to the model registry
//!   models                        list / inspect published models
//!   serve --model NAME[@V]        load a published model and serve scores
//!                                 (zero training work on this path)
//!   serve --dataset NAME          train in process, then serve scores
//!   check                         verify artifacts + PJRT round trip
//!
//! The model registry root is `--models-dir DIR`, else `$AKDA_MODELS`,
//! else `./models` (layout: `<dir>/<name>/<version>/{model.akda,MANIFEST}`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use akda::coordinator::{
    build_dr, evaluate_ovr, select_hyper, EvalConfig, Hyper, MethodId, WorkPool,
};
use akda::data::{cross_dataset_collection, med_datasets, Condition, DatasetSpec};
use akda::eval::tables::{map_table, memory_table, results_csv, speedup_table, DatasetRow};
use akda::runtime::PjrtEngine;

fn artifacts_dir() -> PathBuf {
    std::env::var("AKDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn models_dir(args: &Args) -> PathBuf {
    args.get("models-dir").map(PathBuf::from).unwrap_or_else(|| {
        std::env::var("AKDA_MODELS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("models"))
    })
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", rest[i]))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.insert(k.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn parse_landmarks(s: &str) -> Result<usize> {
    let m: usize = s.parse().context("--landmarks must be a positive integer")?;
    anyhow::ensure!(m >= 1, "--landmarks must be a positive integer, got 0");
    Ok(m)
}

/// `--stream [--block-size B]` → `Some(B)`; `--block-size` alone implies
/// `--stream`; `--stream B` is accepted as shorthand for the pair;
/// neither flag → `None` (in-memory).
fn parse_stream_flags(args: &Args) -> Result<Option<usize>> {
    let stream = args.get("stream");
    let block = args.get("block-size");
    if stream.is_none() && block.is_none() {
        return Ok(None);
    }
    // a bare `--stream` parses as "true" (see Args::parse); any other
    // attached value is a tile height, same as --block-size
    let explicit = block.or_else(|| stream.filter(|v| *v != "true"));
    match explicit {
        Some(s) => {
            let b: usize = s.parse().context("--block-size must be a positive integer")?;
            anyhow::ensure!(b >= 1, "--block-size must be a positive integer, got 0");
            Ok(Some(b))
        }
        None => Ok(Some(akda::data::stream::DEFAULT_BLOCK_ROWS)),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "eval" => cmd_eval(&args),
        "toy" => cmd_toy(&args),
        "train" => cmd_train(&args),
        "models" => cmd_models(&args),
        "serve" => cmd_serve(&args),
        "check" => cmd_check(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `akda help`"),
    }
}

fn print_help() {
    println!(
        "akda — Accelerated Kernel Discriminant Analysis (paper reproduction)\n\n\
         USAGE: akda <command> [flags]\n\n\
         COMMANDS:\n\
           datasets                         print the dataset registry (Table 1)\n\
           eval --suite med|cross10|cross100\n\
                [--methods csv] [--landmarks M] [--stream] [--block-size B]\n\
                [--cv] [--pjrt] [--config file] [--out dir]\n\
                                            regenerate MAP + speedup tables (Tables 2-7);\n\
                                            methods include akda-nystrom|akda-rff (approx\n\
                                            subsystem, --landmarks sets the budget m);\n\
                                            --stream trains them out of core in tiles of\n\
                                            B rows and adds a peak-residency table\n\
           toy [--out dir]                  Sec. 6.2 toy example (Figs. 2-3 data)\n\
           train --dataset NAME [--method akda|aksda|akda-nystrom|akda-rff|...]\n\
                 [--cond 10|100] [--landmarks M] [--stream] [--block-size B]\n\
                 [--name MODEL] [--models-dir DIR] [--pjrt]\n\
                                            fit a detector bank, evaluate it on the\n\
                                            test split, and publish it as the next\n\
                                            version of MODEL (default: dataset name)\n\
           models [--models-dir DIR] [--inspect NAME[@V]]\n\
                                            list published models, or dump one\n\
                                            version's manifest + artifact sections\n\
           serve --model NAME[@V] [--models-dir DIR] [--watch [SECS]]\n\
                 [--dataset NAME]           serve a published model: load, verify\n\
                                            checksums, score — zero training work;\n\
                                            --watch hot-reloads newly published\n\
                                            versions under the running service\n\
           serve --dataset NAME [--method akda|akda-nystrom|akda-rff|...]\n\
                 [--landmarks M] [--stream] [--block-size B] [--pjrt]\n\
                                            train a detector bank in process, then\n\
                                            serve it (no registry involved)\n\
           check                            verify artifacts + PJRT round trip\n\n\
         ENV: AKDA_ARTIFACTS (default: ./artifacts)\n\
              AKDA_MODELS    (default: ./models)"
    );
}

fn cmd_datasets() -> Result<()> {
    println!("Cross-dataset collection (Table 1, scaled — DESIGN.md §3):");
    for d in cross_dataset_collection() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    println!("TRECVID MED (Sec. 6.1.1, scaled):");
    for d in med_datasets() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    Ok(())
}

fn suite_of(name: &str) -> Result<(Vec<DatasetSpec>, Condition, &'static str)> {
    Ok(match name {
        "med" => (med_datasets(), Condition::Ex100, "TRECVID MED (Tables 2, 5)"),
        "cross10" => (
            cross_dataset_collection(),
            Condition::Ex10,
            "cross-dataset 10Ex (Tables 3, 6)",
        ),
        "cross100" => (
            cross_dataset_collection(),
            Condition::Ex100,
            "cross-dataset 100Ex (Tables 4, 7)",
        ),
        other => bail!("unknown suite {other:?} (med|cross10|cross100)"),
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let suite = args.get("suite").unwrap_or("cross10");
    let (datasets, cond, title) = suite_of(suite)?;
    let mut cfg = match args.get("config") {
        Some(path) => EvalConfig::from_file(std::path::Path::new(path))?,
        None => EvalConfig::default(),
    };
    let methods: Vec<MethodId> = match args.get("methods") {
        Some(csv) => csv
            .split(',')
            .map(|m| {
                MethodId::from_name(m.trim())
                    .with_context(|| format!("unknown method {m:?}"))
            })
            .collect::<Result<_>>()?,
        None => MethodId::table_columns(),
    };
    let use_cv = args.get("cv").is_some();
    // set before CV so select_hyper scores the grid at the same budget m
    // (and the same execution mode) the final fit uses; an explicit
    // --landmarks also pins the CV m-grid so CV cannot override it
    if let Some(m) = args.get("landmarks") {
        cfg.landmarks = parse_landmarks(m)?;
        cfg.m_grid = vec![cfg.landmarks];
    }
    if let Some(b) = parse_stream_flags(args)? {
        cfg.stream_block = Some(b);
    }
    let engine = if args.get("pjrt").is_some()
        || methods.iter().any(|m| matches!(m, MethodId::AkdaPjrt | MethodId::AksdaPjrt))
    {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let pool = WorkPool::new(cfg.workers);

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let mut results = Vec::new();
        for &id in &methods {
            let hp = if use_cv {
                let hp = select_hyper(&split, id, &cfg, engine.as_ref())?;
                if id.uses_landmarks() {
                    eprintln!(
                        "   {}: CV picked rho={} c={} h={} m={}",
                        id.name(), hp.rho, hp.c, hp.h, hp.m
                    );
                } else {
                    eprintln!(
                        "   {}: CV picked rho={} c={} h={}",
                        id.name(), hp.rho, hp.c, hp.h
                    );
                }
                hp
            } else {
                Hyper {
                    rho: 0.05,
                    c: 1.0,
                    h: 2,
                    m: cfg.landmarks,
                    stream_block: cfg.stream_block,
                }
            };
            let res = evaluate_ovr(&split, id, hp, cfg.eps, engine.as_ref(), Some(&pool))?;
            eprintln!(
                "   {:<10} MAP={:.2}% train={:.2}s test={:.2}s",
                res.method, 100.0 * res.map, res.train_s, res.test_s
            );
            results.push(res);
        }
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }

    println!("{}", map_table(&format!("MAP — {title}"), &rows));
    println!("{}", speedup_table(&format!("train/test speedup over KDA — {title}"), &rows));
    if rows.iter().any(|r| r.results.iter().any(|m| m.peak_f64.is_some())) {
        println!(
            "{}",
            memory_table(&format!("peak resident training tiles — {title}"), &rows)
        );
    }
    if let Some(dir) = args.get("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("results_{suite}.csv"));
        std::fs::write(&path, results_csv(&rows))?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    // delegate to the shared implementation used by examples/toy_example.rs
    let out = args.get("out").unwrap_or("toy_output");
    akda_toy::run(std::path::Path::new(out), artifacts_dir().as_path())
}

/// The toy example logic is shared with examples/toy_example.rs via include.
mod akda_toy {
    include!("../../examples/toy_impl.rs");
}

fn parse_condition(s: &str) -> Result<Condition> {
    match s {
        "10" | "10Ex" | "ex10" => Ok(Condition::Ex10),
        "100" | "100Ex" | "ex100" => Ok(Condition::Ex100),
        other => bail!("unknown condition {other:?} (10|100)"),
    }
}

/// Training request shared by `akda train` and the train-in-process arm of
/// `akda serve`: dataset split, method, hyper-parameters, optional engine.
struct TrainSpec {
    dataset: String,
    cond: Condition,
    split: akda::data::Split,
    id: MethodId,
    hp: Hyper,
    engine: Option<Arc<PjrtEngine>>,
}

fn parse_train_spec(args: &Args) -> Result<TrainSpec> {
    let dataset = args.get("dataset").unwrap_or("eth80").to_string();
    let spec =
        akda::data::by_name(&dataset).with_context(|| format!("dataset {dataset:?}"))?;
    let cond = parse_condition(args.get("cond").unwrap_or("100"))?;
    let split = spec.split(cond);
    let use_pjrt = args.get("pjrt").is_some();
    let method = match args.get("method") {
        Some(m) => m,
        None if use_pjrt => "akda-pjrt",
        None => "akda",
    };
    let id = MethodId::from_name(method)
        .with_context(|| format!("unknown method {method:?}"))?;
    let needs_engine = matches!(id, MethodId::AkdaPjrt | MethodId::AksdaPjrt);
    if use_pjrt && !needs_engine {
        bail!("--pjrt serves the PJRT engines; use --method akda-pjrt|aksda-pjrt or drop --pjrt");
    }
    let engine = if needs_engine {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let mut hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    if let Some(m) = args.get("landmarks") {
        hp.m = parse_landmarks(m)?;
    }
    hp.stream_block = parse_stream_flags(args)?;
    Ok(TrainSpec { dataset, cond, split, id, hp, engine })
}

/// Fit the multiclass projection + one-vs-rest LSVM bank — the single
/// training path behind `akda train` and `akda serve --dataset`. Returns
/// the bank and the wall-clock training seconds.
fn fit_detector_bank(ts: &TrainSpec) -> Result<(Arc<akda::coordinator::DetectorBank>, f64)> {
    use akda::coordinator::DetectorBank;
    use akda::da::DrMethod;
    use akda::svm::{LinearSvm, LinearSvmConfig};

    let split = &ts.split;
    let t0 = std::time::Instant::now();
    let proj: Box<dyn akda::da::Projection> = match (ts.hp.stream_block, ts.id) {
        (Some(block_rows), MethodId::AkdaNystrom | MethodId::AkdaRff) => {
            // out-of-core training: tiled ΦᵀΦ/class-sum accumulation, then
            // one m×m solve — the bank never sees an N×m feature matrix
            let ap = akda::coordinator::protocol::approx_config(ts.id, ts.hp, 1e-3);
            let mut src = akda::data::stream::MemBlockSource::new(
                &split.x_train,
                &split.y_train,
                block_rows,
            );
            let prep = ap.prepare_stream(&mut src)?;
            // the comparison is training-STATE residency: registry datasets
            // are served from RAM either way (a CsvBlockSource would make
            // the whole run out-of-core), but the tiled path never builds
            // the N×m Φ the in-memory trainer would hold on top
            eprintln!(
                "streaming fit: {} tiles of <= {} rows, training-state peak {:.2} MB \
                 vs {:.2} MB in-memory (dataset itself stays resident here)",
                prep.stats.blocks,
                prep.stats.peak_block_rows,
                prep.stats.peak_resident_f64() as f64 * 8.0 / 1e6,
                prep.stats.dense_resident_f64() as f64 * 8.0 / 1e6,
            );
            let w = prep.solve_w_multiclass()?;
            Box::new(akda::da::akda_stream::BlockedProjection {
                map: prep.map.clone(),
                w,
                block_rows,
            })
        }
        (Some(_), _) => {
            bail!("--stream applies to --method akda-nystrom|akda-rff only")
        }
        (None, _) => {
            let dr = build_dr(ts.id, ts.hp, 1e-3, ts.engine.as_ref())?
                .with_context(|| format!("{} has no DR stage to serve", ts.id.name()))?;
            dr.fit(&split.x_train, &split.y_train, split.n_classes)?
        }
    };
    let z = proj.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    let bank = Arc::new(DetectorBank { projection: proj, svms });
    Ok((bank, t0.elapsed().as_secs_f64()))
}

/// Argmax class of one observation's per-class scores — the single
/// prediction rule shared by `eval_bank` and `drive_demo` (CI asserts
/// their printed accuracies are equal, so tie-breaking must match).
fn predict(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(c, _)| c)
        .unwrap()
}

/// Direct (service-less) test-split evaluation of a trained bank:
/// multiclass accuracy + one-vs-rest MAP. Used by `akda train` to stamp
/// the manifest; `serve`'s demo reports the same accuracy through the
/// scoring service, so the two paths cross-check each other.
fn eval_bank(bank: &akda::coordinator::DetectorBank, split: &akda::data::Split) -> (f64, f64) {
    use akda::eval::{average_precision, mean_average_precision};

    let scores = bank.score(&split.x_test);
    let n = split.x_test.rows();
    let mut correct = 0usize;
    for i in 0..n {
        if predict(scores.row(i)) == split.y_test[i] {
            correct += 1;
        }
    }
    let accuracy = correct as f64 / n as f64;
    let aps: Vec<f64> = (0..split.n_classes)
        .map(|cls| {
            let col = scores.col(cls);
            let positive: Vec<bool> = split.y_test.iter().map(|&l| l == cls).collect();
            average_precision(&col, &positive)
        })
        .collect();
    (accuracy, mean_average_precision(&aps))
}

/// Drive the demo load through the scoring service from a fixed-size pool
/// of client workers, each walking a strided chunk of the test rows — the
/// request path stays concurrent (so micro-batching kicks in) without
/// spawning one OS thread per test row.
fn drive_demo(
    svc: &akda::coordinator::ScoringService,
    split: &akda::data::Split,
) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let client = svc.client();
    let n = split.x_test.rows();
    let workers = akda::util::threads::available().clamp(2, 16).min(n.max(1));
    let correct = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for w in 0..workers {
            let client = client.clone();
            let correct = &correct;
            s.spawn(move || {
                let mut i = w;
                while i < n {
                    let scores = client.score(split.x_test.row(i).to_vec()).unwrap();
                    if predict(&scores) == split.y_test[i] {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                    i += workers;
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "served {} requests in {:.2}s ({:.0} req/s, {} client workers), \
         accuracy {:.2}%, batches={} max_batch={}",
        n,
        dt,
        n as f64 / dt,
        workers,
        100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64,
        stats.batches,
        stats.max_batch
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    use akda::model::{ModelManifest, ModelRegistry};

    let ts = parse_train_spec(args)?;
    eprintln!(
        "training detector bank on {} [{}] (C={}) with {}",
        ts.dataset,
        ts.cond.name(),
        ts.split.n_classes,
        ts.id.name()
    );
    let (bank, train_s) = fit_detector_bank(&ts)?;
    let (accuracy, map) = eval_bank(&bank, &ts.split);
    println!(
        "train-eval: accuracy {:.2}%  MAP {:.2}%  (train {:.2}s)",
        100.0 * accuracy,
        100.0 * map,
        train_s
    );

    let artifact = akda::model::encode_bank(&bank, ts.id.name())?;
    let manifest = ModelManifest {
        method: ts.id.name().to_string(),
        dataset: ts.dataset.clone(),
        condition: ts.cond.name().to_string(),
        rho: ts.hp.rho,
        c: ts.hp.c,
        h: ts.hp.h,
        m: ts.hp.m,
        stream_block: ts.hp.stream_block,
        n_classes: ts.split.n_classes,
        input_dim: ts.split.x_train.cols(),
        train_s,
        map,
        accuracy,
        ..Default::default()
    };
    let name = args.get("name").unwrap_or(ts.dataset.as_str());
    let registry = ModelRegistry::open(models_dir(args));
    let entry = registry.publish(name, &artifact, &manifest)?;
    println!(
        "published {} -> {:?} (serve it with: akda serve --model {})",
        entry.spec(),
        entry.dir,
        entry.spec()
    );
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    use akda::model::ModelRegistry;

    let registry = ModelRegistry::open(models_dir(args));
    if let Some(spec) = args.get("inspect") {
        let (entry, artifact) = registry.load_artifact(spec)?;
        println!("# {} — {:?}", entry.spec(), entry.artifact_path());
        print!("{}", entry.manifest.to_text());
        println!("# artifact sections (checksums verified):");
        for (name, rows, cols) in artifact.section_summaries() {
            println!("  {name:<18} {rows:>6} x {cols}");
        }
        return Ok(());
    }
    let names = registry.models()?;
    if names.is_empty() {
        println!(
            "no models in {:?} — train one with `akda train --dataset NAME`",
            registry.root()
        );
        return Ok(());
    }
    println!(
        "{:<16} {:<8} {:<14} {:<12} {:>6} {:>9} {:>9}",
        "model", "latest", "method", "dataset", "vers", "MAP", "accuracy"
    );
    for name in names {
        let (latest, n_versions) = registry.latest_with_count(&name)?;
        let mf = &latest.manifest;
        println!(
            "{:<16} v{:<7} {:<14} {:<12} {:>6} {:>8.2}% {:>8.2}%",
            name,
            latest.version,
            mf.method,
            mf.dataset,
            n_versions,
            100.0 * mf.map,
            100.0 * mf.accuracy
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use akda::coordinator::{BankHandle, ScoringService};
    use akda::model::{HotReloader, ModelRegistry};
    use std::time::Duration;

    // registry path: load a published model — zero training work (the
    // bank is decoded from checksummed tensors; no fit call anywhere)
    if let Some(spec) = args.get("model") {
        // the stored model carries its own hyper-parameters; reject the
        // training knobs instead of silently ignoring them
        for flag in ["method", "landmarks", "stream", "block-size", "cond", "pjrt"] {
            anyhow::ensure!(
                args.get(flag).is_none(),
                "--{flag} configures training and conflicts with --model \
                 (the published model's hyper-parameters are used as stored)"
            );
        }
        anyhow::ensure!(
            !(spec.contains('@') && args.get("watch").is_some()),
            "--watch tracks the latest version and would override the \
             pinned {spec:?}; drop --watch or use the bare model name"
        );
        let registry = ModelRegistry::open(models_dir(args));
        let (entry, artifact) = registry.load_artifact(spec)?;
        // size the service from the checksummed artifact, not the
        // editable plain-text MANIFEST (which is informational only)
        let input_dim = akda::model::codec::input_dim(&artifact)?;
        let bank = akda::model::decode_bank(&artifact)
            .with_context(|| format!("decoding model {}", entry.spec()))?;
        let mf = entry.manifest.clone();
        eprintln!(
            "loaded {} (method {}, trained on {} [{}], C={}) — no retraining",
            entry.spec(),
            mf.method,
            mf.dataset,
            mf.condition,
            bank.svms.len()
        );
        // demo traffic comes from the dataset the model was trained on
        // (or an explicit --dataset override with matching features)
        let dataset = args.get("dataset").unwrap_or(mf.dataset.as_str());
        let dspec = akda::data::by_name(dataset)
            .with_context(|| format!("dataset {dataset:?}"))?;
        let split = dspec.split(parse_condition(&mf.condition)?);
        anyhow::ensure!(
            split.x_test.cols() == input_dim,
            "dataset {dataset:?} has {} features but {} expects {}",
            split.x_test.cols(),
            entry.spec(),
            input_dim
        );
        let handle = BankHandle::new(Arc::new(bank));
        let watcher = match args.get("watch") {
            Some(v) => {
                let poll: f64 =
                    if v == "true" { 2.0 } else { v.parse().context("--watch SECS")? };
                anyhow::ensure!(poll > 0.0, "--watch SECS must be positive");
                eprintln!("watching {:?} for new versions every {poll}s", registry.root());
                Some(HotReloader::start(
                    registry.clone(),
                    entry.name.clone(),
                    handle.clone(),
                    entry.version,
                    input_dim,
                    Duration::from_secs_f64(poll),
                ))
            }
            None => None,
        };
        let svc = ScoringService::start_reloadable(
            handle,
            input_dim,
            64,
            Duration::from_millis(5),
        );
        drive_demo(&svc, &split)?;
        return match watcher {
            // --watch means "stay up": keep the service + watcher alive so
            // newly published versions actually get hot-swapped in
            Some(_watcher) => {
                eprintln!(
                    "demo complete; still serving {} with hot reload — Ctrl-C to stop",
                    entry.spec()
                );
                loop {
                    std::thread::sleep(Duration::from_secs(60));
                }
            }
            None => Ok(()),
        };
    }

    // in-process path: train a bank now, then serve it
    let ts = parse_train_spec(args)?;
    eprintln!(
        "training detector bank on {} (C={}) with {}",
        ts.dataset,
        ts.split.n_classes,
        ts.id.name()
    );
    let (bank, train_s) = fit_detector_bank(&ts)?;
    eprintln!("trained in {train_s:.2}s — tip: `akda train` publishes instead");
    let svc = ScoringService::start(
        bank,
        ts.split.x_train.cols(),
        64,
        Duration::from_millis(5),
    );
    drive_demo(&svc, &ts.split)
}

fn cmd_check() -> Result<()> {
    let dir = artifacts_dir();
    let engine = PjrtEngine::from_dir(&dir)?;
    let mf_entries = engine.handle().manifest().entries.len();
    println!("manifest: {mf_entries} artifacts in {dir:?}");
    // smoke: tiny fit through the smallest bucket
    use akda::data::synthetic::{gaussian_classes, GaussianSpec};
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![20, 20],
        dim: 8,
        class_sep: 2.0,
        noise: 0.5,
        modes_per_class: 1,
        seed: 1,
    });
    let theta = akda::da::core::theta_binary(&labels);
    let psi = engine.fit(&x, &theta, akda::kernels::Kernel::Rbf { rho: 0.2 })?;
    anyhow::ensure!(psi.is_finite(), "non-finite psi");
    println!("PJRT round trip OK (psi {}x{})", psi.rows(), psi.cols());
    Ok(())
}
