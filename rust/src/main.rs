//! `akda` CLI — the coordinator launcher.
//!
//! Subcommands:
//!   datasets                      print the Table-1 registry (scaled)
//!   eval --suite med|cross10|cross100 [...]
//!                                 regenerate the MAP + speedup tables
//!   toy                           Sec. 6.2 toy example (Figs. 2–3 data)
//!   train --dataset NAME          fit a detector bank, evaluate it, and
//!                                 publish it to the model registry
//!   train --shard I/K --out FILE  distributed training, map side: accumulate
//!                                 one stride shard of the stream into a
//!                                 partial .akda artifact (L11)
//!   merge SHARD... --publish NAME distributed training, reduce side: merge
//!                                 shard accumulators (any order, bit-for-bit
//!                                 identical), factorize once, publish
//!   models                        list / inspect published models
//!   serve --model NAME[@V]        load a published model and serve scores
//!                                 (zero training work on this path)
//!   serve --fleet                 serve EVERY model in the registry from one
//!                                 process, routed by model id (L6)
//!   serve --fleet --listen ADDR   additionally expose the fleet over TCP
//!                                 speaking akda-wire/1 (L8)
//!   client --connect ADDR         remote akda-wire/1 client: list the roster,
//!                                 score a tenant's held-out split (--trace
//!                                 prints the per-stage server-timing
//!                                 breakdown next to the observed RTT;
//!                                 --metrics scrapes the remote registry
//!                                 snapshot), or probe the server with a
//!                                 malformed frame
//!   trace FILE                    analyze an akda-trace/1 JSONL file written
//!                                 by `serve --fleet --listen ... --trace-out`:
//!                                 top-k slowest requests, per-stage p50/p99,
//!                                 stage-share attribution
//!   serve --dataset NAME          train in process, then serve scores
//!   daemon --drop-dir DIR         auto-update: apply NAME.csv drops to model
//!                                 NAME and republish (fleet hot-swaps it)
//!   metrics                       snapshot the observability registry
//!                                 (Prometheus or JSON), or validate emitted
//!                                 metrics/bench files against their schemas
//!   check                         verify artifacts + PJRT round trip
//!
//! `eval`, `serve`, and `daemon` accept `--metrics-out FILE` to append
//! periodic `akda-metrics/1` JSONL snapshots while they run.
//!
//! The model registry root is `--models-dir DIR`, else `$AKDA_MODELS`,
//! else `./models` (layout: `<dir>/<name>/<version>/{model.akda,MANIFEST}`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use akda::coordinator::{
    build_dr, evaluate_ovr, select_hyper, EvalConfig, Hyper, MethodId, WorkPool,
};
use akda::data::{cross_dataset_collection, med_datasets, Condition, DatasetSpec};
use akda::eval::tables::{map_table, memory_table, results_csv, speedup_table, DatasetRow};
use akda::runtime::PjrtEngine;

fn artifacts_dir() -> PathBuf {
    std::env::var("AKDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn models_dir(args: &Args) -> PathBuf {
    args.get("models-dir").map(PathBuf::from).unwrap_or_else(|| {
        std::env::var("AKDA_MODELS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("models"))
    })
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", rest[i]))?;
            i += 1;
            // consume the following non-flag tokens: single-valued flags
            // get their value, the one multi-valued flag (`--diff A B`)
            // gets the tokens joined with a space, bare flags get "true"
            let mut vals: Vec<String> = Vec::new();
            while i < rest.len() && !rest[i].starts_with("--") {
                vals.push(rest[i].clone());
                i += 1;
            }
            anyhow::ensure!(
                vals.len() <= 1 || k == "diff",
                "--{k} takes at most one value, got {vals:?} (stray token?)"
            );
            if vals.is_empty() {
                flags.insert(k.to_string(), "true".to_string());
            } else {
                flags.insert(k.to_string(), vals.join(" "));
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn parse_landmarks(s: &str) -> Result<usize> {
    let m: usize = s.parse().context("--landmarks must be a positive integer")?;
    anyhow::ensure!(m >= 1, "--landmarks must be a positive integer, got 0");
    Ok(m)
}

/// `--stream [--block-size B]` → `Some(B)`; `--block-size` alone implies
/// `--stream`; `--stream B` is accepted as shorthand for the pair;
/// neither flag → `None` (in-memory).
fn parse_stream_flags(args: &Args) -> Result<Option<usize>> {
    let stream = args.get("stream");
    let block = args.get("block-size");
    if stream.is_none() && block.is_none() {
        return Ok(None);
    }
    // a bare `--stream` parses as "true" (see Args::parse); any other
    // attached value is a tile height, same as --block-size
    let explicit = block.or_else(|| stream.filter(|v| *v != "true"));
    match explicit {
        Some(s) => {
            let b: usize = s.parse().context("--block-size must be a positive integer")?;
            anyhow::ensure!(b >= 1, "--block-size must be a positive integer, got 0");
            Ok(Some(b))
        }
        None => Ok(Some(akda::data::stream::DEFAULT_BLOCK_ROWS)),
    }
}

/// `--backend scalar|blocked|parallel|auto` → install the process-wide
/// linalg backend (`linalg::backend`) for every dense hot path this
/// invocation runs. Returns the kind in force (flag, else `AKDA_BACKEND`
/// env, else `auto`) so `train` can record it in the model MANIFEST.
fn parse_backend_flag(args: &Args) -> Result<akda::linalg::BackendKind> {
    use akda::linalg::{backend, BackendKind};
    if let Some(name) = args.get("backend") {
        let kind = BackendKind::from_name(name).with_context(|| {
            format!("unknown backend {name:?} (scalar|blocked|parallel|auto)")
        })?;
        backend::set_global(kind);
    }
    Ok(backend::global_kind())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    // `update` takes a positional NAME[@VERSION] before its flags
    if cmd == "update" {
        return cmd_update(&argv[1..]);
    }
    // `trace` takes a positional FILE before its flags
    if cmd == "trace" {
        return cmd_trace(&argv[1..]);
    }
    // `merge` takes positional SHARD.akda paths before its flags
    if cmd == "merge" {
        return cmd_merge(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "eval" => cmd_eval(&args),
        "toy" => cmd_toy(&args),
        "train" => cmd_train(&args),
        "export" => cmd_export(&args),
        "models" => cmd_models(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "daemon" => cmd_daemon(&args),
        "metrics" => cmd_metrics(&args),
        "check" => cmd_check(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `akda help`"),
    }
}

fn print_help() {
    println!(
        "akda — Accelerated Kernel Discriminant Analysis (paper reproduction)\n\n\
         USAGE: akda <command> [flags]\n\n\
         COMMANDS:\n\
           datasets                         print the dataset registry (Table 1)\n\
           eval --suite med|cross10|cross100\n\
                [--methods csv] [--landmarks M] [--stream] [--block-size B]\n\
                [--cv] [--pjrt] [--backend KIND] [--config file] [--out dir]\n\
                                            regenerate MAP + speedup tables (Tables 2-7);\n\
                                            methods include akda-nystrom|akda-rff (approx\n\
                                            subsystem, --landmarks sets the budget m);\n\
                                            --stream trains them out of core in tiles of\n\
                                            B rows and adds a peak-residency table\n\
           toy [--out dir]                  Sec. 6.2 toy example (Figs. 2-3 data)\n\
           train --dataset NAME [--method akda|aksda|akda-nystrom|akda-rff|...]\n\
                 [--cond 10|100] [--landmarks M] [--stream] [--block-size B]\n\
                 [--name MODEL] [--models-dir DIR] [--pjrt] [--no-resume]\n\
                 [--backend KIND]\n\
                                            fit a detector bank, evaluate it on the\n\
                                            test split, and publish it as the next\n\
                                            version of MODEL (default: dataset name);\n\
                                            akda / akda-nystrom / akda-rff models embed\n\
                                            resume state so `akda update` can grow them\n\
                                            (--no-resume skips it, shrinking the artifact)\n\
           train --shard I/K --out FILE [--landmarks-from SHARD.akda] [...]\n\
                                            distributed training, map side: accumulate\n\
                                            shard I of a K-way stride partition of the\n\
                                            stream into a partial .akda artifact (no\n\
                                            model is published; requires --stream and a\n\
                                            streaming method); every shard fits the same\n\
                                            landmark basis from the full stream, or\n\
                                            reuses a sibling shard's via --landmarks-from\n\
           merge SHARD.akda... --publish NAME [--models-dir DIR]\n\
                 [--reservoir CAP] [--backend KIND]\n\
                                            distributed training, reduce side: check the\n\
                                            shards' compatibility (m/C/eps/landmark\n\
                                            fingerprint), merge their accumulators —\n\
                                            any merge order is bit-for-bit identical —\n\
                                            factorize once, evaluate, and publish the\n\
                                            model exactly as `akda train` would\n\
           update NAME[@V] --data new.csv [--models-dir DIR]\n\
                  [--refresh-landmarks] [--reservoir CAP] [--backend KIND]\n\
                                            Sec. 7 recursive learning: decode the published\n\
                                            model, grow it with the new rows — bordered-\n\
                                            Cholesky extension (exact) or accumulator\n\
                                            continuation (approx) — with ZERO full refits,\n\
                                            re-evaluate, and publish the next version\n\
                                            (a `serve --watch` service hot-swaps it in);\n\
                                            --refresh-landmarks re-runs warm-started\n\
                                            k-means so Nystrom landmarks track drift\n\
           export --dataset NAME [--cond 10|100] [--split train|test]\n\
                  [--skip K] [--stride S] [--rows N] --out FILE\n\
                                            dump registry-dataset rows as label,f1,...\n\
                                            CSV (update/drift simulations, smoke tests)\n\
           models [--models-dir DIR] [--inspect NAME[@V]]\n\
                  [--prune K [--model NAME [--protect V]]] [--diff A B]\n\
                                            list published models, dump one version's\n\
                                            manifest + artifact sections, GC old\n\
                                            versions (newest K kept; latest never\n\
                                            deleted, nor the --protect'ed version,\n\
                                            nor any version a live fleet/serve\n\
                                            process has marked served), or diff two\n\
                                            versions' manifests, tensor checksums,\n\
                                            and eval accuracy\n\
           serve --model NAME[@V] [--models-dir DIR] [--watch [SECS]]\n\
                 [--dataset NAME]           serve a published model: load, verify\n\
                                            checksums, score — zero training work;\n\
                                            --watch hot-reloads newly published\n\
                                            versions under the running service\n\
           serve --fleet [--models-dir DIR] [--watch [SECS]] [--listen ADDR]\n\
                 [--trace-out FILE [--trace-sample N] [--trace-slow-ms MS]]\n\
                                            multi-tenant: serve EVERY model in the\n\
                                            registry from one process, requests\n\
                                            routed by model id over one shared\n\
                                            worker pool; unknown ids are protocol-\n\
                                            rejected; --watch hot-swaps any tenant\n\
                                            republished (e.g. by the daemon) AND\n\
                                            onboards newly published names without\n\
                                            restart; --listen HOST:PORT fronts the\n\
                                            fleet with the akda-wire/1 TCP protocol\n\
                                            (port 0 picks a free port, printed on\n\
                                            stdout) and stays up serving it;\n\
                                            --trace-out appends one akda-trace/1\n\
                                            JSONL record per sampled request (every\n\
                                            Nth with --trace-sample, default all;\n\
                                            --trace-slow-ms MS always records\n\
                                            requests at/above MS — 0 records every\n\
                                            request; sheds are always recorded)\n\
           client --connect HOST:PORT [--model NAME [--dataset DS] [--cond 10|100]]\n\
                  [--trace] [--metrics] [--probe] [--timeout SECS]\n\
                                            akda-wire/1 client: print the server's\n\
                                            tenant roster; with --model, score that\n\
                                            tenant's held-out split over TCP and\n\
                                            report accuracy (bit-for-bit the served\n\
                                            model's scores); --trace mints per-\n\
                                            request trace ids and prints the\n\
                                            server's per-stage timing breakdown\n\
                                            next to the client-observed RTT;\n\
                                            --metrics scrapes the server's\n\
                                            akda-metrics/1 snapshot over the same\n\
                                            socket; --probe sends a deliberately\n\
                                            malformed frame and expects a typed\n\
                                            error answer\n\
           trace FILE [--top K]             analyze an akda-trace/1 JSONL file\n\
                                            (a serve --trace-out artifact): per-\n\
                                            stage p50/p99, stage-share attribution\n\
                                            over all records and over the p99\n\
                                            latency tail, top-K slowest requests\n\
           serve --dataset NAME [--method akda|akda-nystrom|akda-rff|...]\n\
                 [--landmarks M] [--stream] [--block-size B] [--pjrt]\n\
                 [--backend KIND]\n\
                                            train a detector bank in process, then\n\
                                            serve it (no registry involved)\n\
           daemon --drop-dir DIR [--registry DIR] [--interval SECS]\n\
                  [--refresh-landmarks] [--reservoir CAP]\n\
                                            scheduled auto-update: watch the drop\n\
                                            directory for NAME.csv files of labeled\n\
                                            rows, apply the Sec. 7 recursive update\n\
                                            to model NAME, republish (a watching\n\
                                            fleet hot-swaps the new version in);\n\
                                            malformed/partial files are quarantined\n\
                                            as *.rejected, never retried in a loop\n\
           metrics [--format prometheus|json]\n\
                   [--from FILE] [--validate FILE [--require k1,k2]]\n\
                                            observability: run a tiny in-process\n\
                                            workload and print the metrics registry\n\
                                            snapshot (default Prometheus text, --format\n\
                                            json for the akda-metrics/1 document);\n\
                                            --from re-prints the last snapshot of a\n\
                                            --metrics-out JSONL file; --validate checks\n\
                                            a metrics JSONL or BENCH_*.json artifact\n\
                                            against its schema (--require additionally\n\
                                            asserts the named metrics are nonzero and\n\
                                            heartbeats fresh)\n\
           check                            verify artifacts + PJRT round trip\n\n\
         FLAGS shared by eval/serve/daemon:\n\
           --metrics-out FILE [--metrics-interval SECS]\n\
                                            append akda-metrics/1 JSONL snapshots of\n\
                                            the live metrics registry every SECS\n\
                                            (default 2) plus one final snapshot on\n\
                                            shutdown\n\n\
         FLAGS shared by eval/train/update/serve --dataset:\n\
           --backend scalar|blocked|parallel|auto\n\
                                            linalg execution backend for the dense\n\
                                            hot paths (Gram build, blocked Cholesky,\n\
                                            streamed accumulation, matmuls); every\n\
                                            choice is bit-for-bit equivalent — only\n\
                                            wall-clock differs; auto (the default)\n\
                                            picks per matrix size; recorded in the\n\
                                            model MANIFEST (`backend` +\n\
                                            `health.backend`)\n\n\
         ENV: AKDA_ARTIFACTS (default: ./artifacts)\n\
              AKDA_MODELS    (default: ./models)\n\
              AKDA_BACKEND   (default: auto — same values as --backend)"
    );
}

fn cmd_datasets() -> Result<()> {
    println!("Cross-dataset collection (Table 1, scaled — DESIGN.md §3):");
    for d in cross_dataset_collection() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    println!("TRECVID MED (Sec. 6.1.1, scaled):");
    for d in med_datasets() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    Ok(())
}

fn suite_of(name: &str) -> Result<(Vec<DatasetSpec>, Condition, &'static str)> {
    Ok(match name {
        "med" => (med_datasets(), Condition::Ex100, "TRECVID MED (Tables 2, 5)"),
        "cross10" => (
            cross_dataset_collection(),
            Condition::Ex10,
            "cross-dataset 10Ex (Tables 3, 6)",
        ),
        "cross100" => (
            cross_dataset_collection(),
            Condition::Ex100,
            "cross-dataset 100Ex (Tables 4, 7)",
        ),
        other => bail!("unknown suite {other:?} (med|cross10|cross100)"),
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let suite = args.get("suite").unwrap_or("cross10");
    let (datasets, cond, title) = suite_of(suite)?;
    // held for the whole run; the drop at the end appends a final snapshot
    // that covers every phase span the evaluation recorded
    let _metrics = parse_metrics_out(args)?;
    let mut cfg = match args.get("config") {
        Some(path) => EvalConfig::from_file(std::path::Path::new(path))?,
        None => EvalConfig::default(),
    };
    let methods: Vec<MethodId> = match args.get("methods") {
        Some(csv) => csv
            .split(',')
            .map(|m| {
                MethodId::from_name(m.trim())
                    .with_context(|| format!("unknown method {m:?}"))
            })
            .collect::<Result<_>>()?,
        None => MethodId::table_columns(),
    };
    let use_cv = args.get("cv").is_some();
    // set before CV so select_hyper scores the grid at the same budget m
    // (and the same execution mode) the final fit uses; an explicit
    // --landmarks also pins the CV m-grid so CV cannot override it
    if let Some(m) = args.get("landmarks") {
        cfg.landmarks = parse_landmarks(m)?;
        cfg.m_grid = vec![cfg.landmarks];
    }
    if let Some(b) = parse_stream_flags(args)? {
        cfg.stream_block = Some(b);
    }
    let backend = parse_backend_flag(args)?;
    eprintln!("linalg backend: {}", backend.name());
    let engine = if args.get("pjrt").is_some()
        || methods.iter().any(|m| matches!(m, MethodId::AkdaPjrt | MethodId::AksdaPjrt))
    {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let pool = WorkPool::new(cfg.workers);

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let mut results = Vec::new();
        for &id in &methods {
            let hp = if use_cv {
                let hp = select_hyper(&split, id, &cfg, engine.as_ref())?;
                if id.uses_landmarks() {
                    eprintln!(
                        "   {}: CV picked rho={} c={} h={} m={}",
                        id.name(), hp.rho, hp.c, hp.h, hp.m
                    );
                } else {
                    eprintln!(
                        "   {}: CV picked rho={} c={} h={}",
                        id.name(), hp.rho, hp.c, hp.h
                    );
                }
                hp
            } else {
                Hyper {
                    rho: 0.05,
                    c: 1.0,
                    h: 2,
                    m: cfg.landmarks,
                    stream_block: cfg.stream_block,
                }
            };
            let res = evaluate_ovr(&split, id, hp, cfg.eps, engine.as_ref(), Some(&pool))?;
            eprintln!(
                "   {:<10} MAP={:.2}% train={:.2}s test={:.2}s",
                res.method, 100.0 * res.map, res.train_s, res.test_s
            );
            results.push(res);
        }
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }

    println!("{}", map_table(&format!("MAP — {title}"), &rows));
    println!("{}", speedup_table(&format!("train/test speedup over KDA — {title}"), &rows));
    if rows.iter().any(|r| r.results.iter().any(|m| m.peak_f64.is_some())) {
        println!(
            "{}",
            memory_table(&format!("peak resident training tiles — {title}"), &rows)
        );
    }
    if let Some(dir) = args.get("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("results_{suite}.csv"));
        std::fs::write(&path, results_csv(&rows))?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    // delegate to the shared implementation used by examples/toy_example.rs
    let out = args.get("out").unwrap_or("toy_output");
    akda_toy::run(std::path::Path::new(out), artifacts_dir().as_path())
}

/// The toy example logic is shared with examples/toy_example.rs via include.
mod akda_toy {
    include!("../../examples/toy_impl.rs");
}

fn parse_condition(s: &str) -> Result<Condition> {
    Condition::parse(s).with_context(|| format!("unknown condition {s:?} (10|100)"))
}

/// Training request shared by `akda train` and the train-in-process arm of
/// `akda serve`: dataset split, method, hyper-parameters, optional engine.
struct TrainSpec {
    dataset: String,
    cond: Condition,
    split: akda::data::Split,
    id: MethodId,
    hp: Hyper,
    engine: Option<Arc<PjrtEngine>>,
    backend: akda::linalg::BackendKind,
}

fn parse_train_spec(args: &Args) -> Result<TrainSpec> {
    let dataset = args.get("dataset").unwrap_or("eth80").to_string();
    let spec =
        akda::data::by_name(&dataset).with_context(|| format!("dataset {dataset:?}"))?;
    let cond = parse_condition(args.get("cond").unwrap_or("100"))?;
    let split = spec.split(cond);
    let use_pjrt = args.get("pjrt").is_some();
    let method = match args.get("method") {
        Some(m) => m,
        None if use_pjrt => "akda-pjrt",
        None => "akda",
    };
    let id = MethodId::from_name(method)
        .with_context(|| format!("unknown method {method:?}"))?;
    let needs_engine = matches!(id, MethodId::AkdaPjrt | MethodId::AksdaPjrt);
    if use_pjrt && !needs_engine {
        bail!("--pjrt serves the PJRT engines; use --method akda-pjrt|aksda-pjrt or drop --pjrt");
    }
    let engine = if needs_engine {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let mut hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    if let Some(m) = args.get("landmarks") {
        hp.m = parse_landmarks(m)?;
    }
    hp.stream_block = parse_stream_flags(args)?;
    let backend = parse_backend_flag(args)?;
    Ok(TrainSpec { dataset, cond, split, id, hp, engine, backend })
}

/// Fit the multiclass projection + one-vs-rest LSVM bank — the single
/// training path behind `akda train` and `akda serve --dataset`. With
/// `want_resume`, also returns (for the resumable methods akda /
/// akda-nystrom / akda-rff) the continual-learning resume state `akda
/// train` embeds so `akda update` can grow the model later; callers that
/// discard it (`serve --dataset`, `train --no-resume`) pass `false` and
/// skip the extra reservoir pass / aggregate retention entirely.
fn fit_detector_bank(
    ts: &TrainSpec,
    want_resume: bool,
) -> Result<(Arc<akda::coordinator::DetectorBank>, f64, Option<akda::model::ResumeState>)> {
    use akda::coordinator::DetectorBank;
    use akda::da::DrMethod;
    use akda::model::codec::{ApproxResume, ExactResume};
    use akda::model::update::{approx_resume_from_phi, DEFAULT_RESERVOIR_CAP, DEFAULT_UPDATE_SEED};
    use akda::model::ResumeState;

    let split = &ts.split;
    let train_span = akda::obs::span("train");
    let mut resume: Option<ResumeState> = None;
    let proj: Box<dyn akda::da::Projection> = match (ts.hp.stream_block, ts.id) {
        (Some(block_rows), MethodId::AkdaNystrom | MethodId::AkdaRff) => {
            // out-of-core training: tiled ΦᵀΦ/class-sum accumulation, then
            // one m×m solve — the bank never sees an N×m feature matrix
            let ap = akda::coordinator::protocol::approx_config(ts.id, ts.hp, 1e-3);
            let mut src = akda::data::stream::MemBlockSource::new(
                &split.x_train,
                &split.y_train,
                block_rows,
            );
            let prep = ap.prepare_stream(&mut src)?;
            // the comparison is training-STATE residency: registry datasets
            // are served from RAM either way (a CsvBlockSource would make
            // the whole run out-of-core), but the tiled path never builds
            // the N×m Φ the in-memory trainer would hold on top
            eprintln!(
                "streaming fit: {} tiles of <= {} rows, training-state peak {:.2} MB \
                 vs {:.2} MB in-memory (dataset itself stays resident here)",
                prep.stats.blocks,
                prep.stats.peak_block_rows,
                prep.stats.peak_resident_f64() as f64 * 8.0 / 1e6,
                prep.stats.dense_resident_f64() as f64 * 8.0 / 1e6,
            );
            let w = prep.solve_w_multiclass()?;
            if want_resume {
                // resume state: the accumulator aggregates plus a labeled
                // reservoir of the stream (a second bounded pass)
                let mut res_src = akda::data::stream::MemBlockSource::new(
                    &split.x_train,
                    &split.y_train,
                    block_rows,
                );
                let (reservoir, reservoir_labels, seen) =
                    akda::data::stream::reservoir_sample_labeled(
                        &mut res_src,
                        DEFAULT_RESERVOIR_CAP,
                        DEFAULT_UPDATE_SEED,
                    )?;
                resume = Some(ResumeState::Approx(ApproxResume {
                    gram: prep.gram().clone(),
                    class_sums: prep.class_sums().clone(),
                    counts: prep.counts().to_vec(),
                    reservoir,
                    reservoir_labels,
                    seen,
                    eps: ap.eps,
                }));
            }
            Box::new(akda::da::akda_stream::BlockedProjection {
                map: prep.map.clone(),
                w,
                block_rows,
            })
        }
        (Some(_), _) => {
            bail!("--stream applies to --method akda-nystrom|akda-rff only")
        }
        (None, MethodId::AkdaNystrom | MethodId::AkdaRff) => {
            // same arithmetic as build_dr -> AkdaApprox::fit (prepare +
            // fit), opened up so the Φ-side aggregates can seed the
            // continual-learning resume state
            let ap = akda::coordinator::protocol::approx_config(ts.id, ts.hp, 1e-3);
            let prep = ap.prepare(&split.x_train)?;
            let proj = prep.fit(&split.y_train, split.n_classes)?;
            if want_resume {
                resume = Some(ResumeState::Approx(approx_resume_from_phi(
                    &prep.phi,
                    prep.gram(),
                    &split.x_train,
                    &split.y_train,
                    split.n_classes,
                    ap.eps,
                    DEFAULT_RESERVOIR_CAP,
                    DEFAULT_UPDATE_SEED,
                )?));
            }
            Box::new(proj)
        }
        (None, MethodId::Akda) => {
            // same configuration and arithmetic as build_dr -> Akda::fit,
            // keeping the Cholesky factor for bordered growth under
            // `akda update`
            let akda_cfg = akda::coordinator::protocol::akda_config(ts.hp, 1e-3);
            let (proj, chol_l) =
                akda_cfg.fit_with_factor(&split.x_train, &split.y_train, split.n_classes)?;
            if want_resume {
                resume = Some(ResumeState::Exact(ExactResume {
                    chol_l,
                    labels: split.y_train.clone(),
                    eps: akda_cfg.eps,
                    n_classes: split.n_classes,
                }));
            }
            Box::new(proj)
        }
        (None, _) => {
            let dr = build_dr(ts.id, ts.hp, 1e-3, ts.engine.as_ref())?
                .with_context(|| format!("{} has no DR stage to serve", ts.id.name()))?;
            dr.fit(&split.x_train, &split.y_train, split.n_classes)?
        }
    };
    let z = proj.project(&split.x_train);
    let svms =
        akda::model::update::train_svm_bank(&z, &split.y_train, split.n_classes);
    let bank = Arc::new(DetectorBank { projection: proj, svms });
    Ok((bank, train_span.finish(), resume))
}

// `predict` and `eval_bank` live in `coordinator::service` (shared with
// the update engine's re-evaluation and the fleet demo below).
use akda::coordinator::service::{eval_bank, predict};

/// Drive the demo load through the scoring service from a fixed-size pool
/// of client workers, each walking a strided chunk of the test rows — the
/// request path stays concurrent (so micro-batching kicks in) without
/// spawning one OS thread per test row.
fn drive_demo(
    svc: &akda::coordinator::ScoringService,
    split: &akda::data::Split,
) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let client = svc.client();
    let n = split.x_test.rows();
    let workers = akda::util::threads::available().clamp(2, 16).min(n.max(1));
    let correct = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for w in 0..workers {
            let client = client.clone();
            let correct = &correct;
            s.spawn(move || {
                let mut i = w;
                while i < n {
                    let scores = client.score(split.x_test.row(i).to_vec()).unwrap();
                    if predict(&scores) == split.y_test[i] {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                    i += workers;
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "served {} requests in {:.2}s ({:.0} req/s, {} client workers), \
         accuracy {:.2}%, batches={} max_batch={}",
        n,
        dt,
        n as f64 / dt,
        workers,
        100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64,
        stats.batches,
        stats.max_batch
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    use akda::model::{ModelManifest, ModelRegistry};

    if let Some(spec) = args.get("shard") {
        return cmd_train_shard(args, spec);
    }
    let ts = parse_train_spec(args)?;
    eprintln!(
        "training detector bank on {} [{}] (C={}) with {} (backend {})",
        ts.dataset,
        ts.cond.name(),
        ts.split.n_classes,
        ts.id.name(),
        ts.backend.name()
    );
    let want_resume = args.get("no-resume").is_none();
    // flight recorder on: the fit's numerical-health facts (Cholesky
    // pivots, ridge ε, core-eigenvalue extremes, phase durations) land
    // in the manifest as `health.*` keys below
    akda::obs::flight::reset();
    let (bank, train_s, resume) = fit_detector_bank(&ts, want_resume)?;
    let (accuracy, map) = eval_bank(&bank, &ts.split);
    println!(
        "train-eval: accuracy {:.2}%  MAP {:.2}%  (train {:.2}s)",
        100.0 * accuracy,
        100.0 * map,
        train_s
    );

    let mut artifact = akda::model::encode_bank(&bank, ts.id.name())?;
    match &resume {
        Some(state) => {
            akda::model::codec::encode_resume(&mut artifact, state)?;
            eprintln!(
                "embedded {} resume state — grow this model later with `akda update`",
                state.kind()
            );
        }
        None if !want_resume => {
            eprintln!("--no-resume: artifact is not updatable in place")
        }
        None => {}
    }
    let manifest = ModelManifest {
        method: ts.id.name().to_string(),
        dataset: ts.dataset.clone(),
        condition: ts.cond.name().to_string(),
        rho: ts.hp.rho,
        c: ts.hp.c,
        h: ts.hp.h,
        m: ts.hp.m,
        stream_block: ts.hp.stream_block,
        n_classes: ts.split.n_classes,
        input_dim: ts.split.x_train.cols(),
        train_s,
        map,
        accuracy,
        backend: ts.backend.name().to_string(),
        health: akda::obs::flight::snapshot(),
        ..Default::default()
    };
    let name = args.get("name").unwrap_or(ts.dataset.as_str());
    let registry = ModelRegistry::open(models_dir(args));
    let entry = registry.publish(name, &artifact, &manifest)?;
    println!(
        "published {} -> {:?} (serve it with: akda serve --model {})",
        entry.spec(),
        entry.dir,
        entry.spec()
    );
    Ok(())
}

/// `--shard I/K` → zero-based stride index + shard count.
fn parse_shard_spec(s: &str) -> Result<(usize, usize)> {
    let (i, k) = s
        .split_once('/')
        .with_context(|| format!("--shard takes I/K (e.g. 0/3), got {s:?}"))?;
    let index: usize = i.trim().parse().context("--shard index must be an integer")?;
    let count: usize = k.trim().parse().context("--shard count must be an integer")?;
    anyhow::ensure!(count >= 1, "--shard count must be >= 1");
    anyhow::ensure!(index < count, "--shard index {index} out of range for count {count}");
    Ok((index, count))
}

/// `akda train --shard I/K --out FILE` — distributed training, map side
/// (L11): fit the shared landmark basis, stream shard I of the K-way
/// stride partition through a `TiledAccumulator`, and persist the partial
/// state as a shard artifact. No model is published — `akda merge` folds
/// the full shard set into one model and publishes that.
fn cmd_train_shard(args: &Args, spec: &str) -> Result<()> {
    use akda::da::akda_stream::TiledAccumulator;
    use akda::data::stream::{
        reservoir_sample_labeled, BlockSource, MemBlockSource, StridedBlockSource,
    };
    use akda::model::codec::ApproxResume;
    use akda::model::shard::basis_fingerprint;
    use akda::model::update::{DEFAULT_RESERVOIR_CAP, DEFAULT_UPDATE_SEED};
    use akda::model::ShardPiece;
    use akda::util::rng::shard_seed;

    let (index, count) = parse_shard_spec(spec)?;
    let ts = parse_train_spec(args)?;
    let Some(block_rows) = ts.hp.stream_block else {
        bail!("--shard is the distributed streaming trainer: add --stream [--block-size B]")
    };
    if !matches!(ts.id, MethodId::AkdaNystrom | MethodId::AkdaRff) {
        bail!("--shard applies to --method akda-nystrom|akda-rff only");
    }
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{}-shard-{index}of{count}.akda", ts.dataset)));
    let split = &ts.split;
    let ap = akda::coordinator::protocol::approx_config(ts.id, ts.hp, 1e-3);
    let t0 = std::time::Instant::now();
    // every shard must project into the SAME feature space: either reuse a
    // sibling shard's landmark basis, or fit it from the full stream — the
    // fit is deterministic per seed, so shards that each see the whole
    // stream derive the identical basis independently
    let map: Arc<dyn akda::approx::FeatureMap> = match args.get("landmarks-from") {
        Some(path) => {
            let art = akda::model::ModelArtifact::load(std::path::Path::new(path))?;
            akda::model::decode_shard(&art)
                .with_context(|| format!("--landmarks-from {path}"))?
                .map
        }
        None => {
            let mut src = MemBlockSource::new(&split.x_train, &split.y_train, block_rows);
            Arc::from(ap.build_map_stream(&mut src)?)
        }
    };
    // accumulate ONLY this shard's stride of the stream
    let mut src = StridedBlockSource::new(
        MemBlockSource::new(&split.x_train, &split.y_train, block_rows),
        index,
        count,
    )?;
    let mut acc = TiledAccumulator::new(map.dim());
    src.reset()?;
    while let Some(block) = src.next_block()? {
        let phi = map.transform(&block.x);
        acc.absorb(&phi, &block.labels)?;
    }
    // pad the class axis to the dataset's declared C: a stride shard may
    // never see a rare class; only the MERGED state must cover them all
    let agg = acc.into_aggregates(split.n_classes)?;
    let rows = agg.stats.rows;
    // per-shard reservoir on a derived RNG stream (identically-seeded
    // shards would sample correlated reservoirs); k = 1 keeps the base
    // seed, so the single-shard merge is bit-for-bit `akda train`
    let (reservoir, reservoir_labels, seen) = reservoir_sample_labeled(
        &mut src,
        DEFAULT_RESERVOIR_CAP,
        shard_seed(DEFAULT_UPDATE_SEED, index, count),
    )?;
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("dataset".to_string(), ts.dataset.clone());
    meta.insert("cond".to_string(), args.get("cond").unwrap_or("100").to_string());
    meta.insert("method".to_string(), ts.id.name().to_string());
    meta.insert("landmarks".to_string(), ts.hp.m.to_string());
    let piece = ShardPiece {
        index,
        count,
        basis: basis_fingerprint(map.as_ref())?,
        block_rows,
        map,
        resume: ApproxResume {
            gram: agg.gram,
            class_sums: agg.class_sums,
            counts: agg.counts,
            reservoir,
            reservoir_labels,
            seen,
            eps: ap.eps,
        },
        meta,
    };
    akda::model::encode_shard(&piece)?.save(&out)?;
    println!(
        "shard {index}/{count}: accumulated {rows} of {} rows into {:?} in {:.2}s \
         (merge the full set with: akda merge SHARD... --publish NAME)",
        split.x_train.rows(),
        out,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `akda merge SHARD.akda... --publish NAME` — distributed training,
/// reduce side (L11): decode the shard artifacts in parallel on the work
/// pool, fold them pairwise (every merge tree yields the bit-identical
/// state), factorize the merged accumulator once, rebuild the OvR bank,
/// evaluate, and publish — the same artifact shape `akda train` emits,
/// resume state included.
fn cmd_merge(rest: &[String]) -> Result<()> {
    use akda::coordinator::DetectorBank;
    use akda::da::Projection;
    use akda::model::codec::ApproxResume;
    use akda::model::update::{train_svm_bank, DEFAULT_RESERVOIR_CAP};
    use akda::model::{ModelArtifact, ModelManifest, ModelRegistry, ResumeState, ShardSet};

    let paths: Vec<String> =
        rest.iter().take_while(|s| !s.starts_with("--")).cloned().collect();
    let args = Args::parse(&rest[paths.len()..])?;
    if paths.is_empty() {
        bail!(
            "usage: akda merge SHARD.akda... --publish NAME [--models-dir DIR] \
             [--reservoir CAP] [--backend KIND]"
        );
    }
    let name = args.get("publish").context("merge needs --publish NAME")?.to_string();
    let backend = parse_backend_flag(&args)?;
    let reservoir_cap = match args.get("reservoir") {
        Some(s) => {
            let cap: usize = s.parse().context("--reservoir must be a positive integer")?;
            anyhow::ensure!(cap >= 1, "--reservoir must be >= 1");
            cap
        }
        None => DEFAULT_RESERVOIR_CAP,
    };
    akda::obs::flight::reset();
    let t0 = std::time::Instant::now();

    // map side of the reduce: load + decode every shard concurrently
    let pool = WorkPool::new(
        akda::util::threads::available().clamp(1, 8).min(paths.len().max(1)),
    );
    let shared: Arc<Vec<PathBuf>> = Arc::new(paths.iter().map(PathBuf::from).collect());
    let decoded = {
        let shared = Arc::clone(&shared);
        pool.map(shared.len(), move |i| -> Result<akda::model::ShardPiece> {
            let art = ModelArtifact::load(&shared[i])?;
            akda::model::decode_shard(&art)
        })
    };
    let mut sets: Vec<ShardSet> = Vec::with_capacity(decoded.len());
    for (path, piece) in paths.iter().zip(decoded) {
        let piece = piece.with_context(|| format!("shard {path}"))?;
        let mut set = ShardSet::new();
        set.insert(piece).with_context(|| format!("shard {path}"))?;
        sets.push(set);
    }

    // reduce side: pairwise rounds on the pool — the set union is
    // order-free, and finalize's canonical ascending-stride fold makes
    // every tree shape bit-identical
    while sets.len() > 1 {
        let pairs: Vec<(ShardSet, Option<ShardSet>)> = {
            let mut it = sets.into_iter();
            let mut pairs = Vec::new();
            while let Some(a) = it.next() {
                pairs.push((a, it.next()));
            }
            pairs
        };
        let slots: Vec<std::sync::Mutex<Option<Result<ShardSet>>>> =
            pairs.iter().map(|_| std::sync::Mutex::new(None)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = pairs
            .into_iter()
            .zip(slots.iter())
            .map(|((mut a, b), slot)| {
                let job = move || {
                    let merged = match b {
                        Some(b) => a.merge(b).map(|()| a).map_err(anyhow::Error::from),
                        None => Ok(a),
                    };
                    *slot.lock().unwrap() = Some(merged);
                };
                Box::new(job) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        sets = Vec::with_capacity(slots.len());
        for slot in slots {
            sets.push(slot.into_inner().unwrap().expect("merge job always reports")?);
        }
    }
    let set = sets.pop().expect("at least one shard");
    let n_pieces = set.len();
    let merged = set.finalize(reservoir_cap)?;

    // rebuild the evaluation context the shards were trained from
    let dataset = merged
        .meta
        .get("dataset")
        .context("shard meta lacks the dataset name")?
        .clone();
    let cond = parse_condition(merged.meta.get("cond").map(String::as_str).unwrap_or("100"))?;
    let method = merged
        .meta
        .get("method")
        .map(String::as_str)
        .unwrap_or("akda-nystrom")
        .to_string();
    let landmarks: usize =
        merged.meta.get("landmarks").and_then(|s| s.parse().ok()).unwrap_or(0);
    let dspec = akda::data::by_name(&dataset)
        .with_context(|| format!("shard meta dataset {dataset:?}"))?;
    let split = dspec.split(cond);

    // factorize the merged accumulator ONCE, exactly as the unsharded
    // streaming train would have
    let count = merged.count;
    let block_rows = merged.block_rows;
    let eps = merged.eps;
    let (reservoir, reservoir_labels) = merged.reservoir.snapshot()?;
    let seen = merged.reservoir.seen();
    let prep = akda::da::akda_stream::PreparedStream::from_aggregates(
        Arc::clone(&merged.map),
        merged.aggregates,
        eps,
        akda::linalg::chol::DEFAULT_BLOCK,
    )?;
    anyhow::ensure!(
        prep.n_classes() == split.n_classes,
        "merged state covers {} classes, dataset {dataset:?} has {}",
        prep.n_classes(),
        split.n_classes
    );
    let w = prep.solve_w_multiclass()?;
    let proj = akda::da::akda_stream::BlockedProjection {
        map: Arc::clone(&prep.map),
        w,
        block_rows,
    };
    // same post-projection path as `akda train`: identical inputs ⇒ the
    // published bank (and its scores) match the unsharded train exactly
    let z = proj.project(&split.x_train);
    let svms = train_svm_bank(&z, &split.y_train, split.n_classes);
    let bank = Arc::new(DetectorBank { projection: Box::new(proj), svms });
    let (accuracy, map_score) = eval_bank(&bank, &split);
    let train_s = t0.elapsed().as_secs_f64();
    println!(
        "merge-eval: accuracy {:.2}%  MAP {:.2}%  ({n_pieces} shards, merge+fit {:.2}s)",
        100.0 * accuracy,
        100.0 * map_score,
        train_s
    );

    let mut artifact = akda::model::encode_bank(&bank, &method)?;
    akda::model::codec::encode_resume(
        &mut artifact,
        &ResumeState::Approx(ApproxResume {
            gram: prep.gram().clone(),
            class_sums: prep.class_sums().clone(),
            counts: prep.counts().to_vec(),
            reservoir,
            reservoir_labels,
            seen,
            eps,
        }),
    )?;
    akda::obs::flight::record("shards", count as f64);
    let manifest = ModelManifest {
        method,
        dataset: dataset.clone(),
        condition: cond.name().to_string(),
        rho: 0.05,
        c: 1.0,
        h: 2,
        m: landmarks,
        stream_block: Some(block_rows),
        n_classes: split.n_classes,
        input_dim: split.x_train.cols(),
        train_s,
        map: map_score,
        accuracy,
        backend: backend.name().to_string(),
        health: akda::obs::flight::snapshot(),
        ..Default::default()
    };
    let registry = ModelRegistry::open(models_dir(&args));
    let entry = registry.publish(&name, &artifact, &manifest)?;
    println!(
        "published {} from {n_pieces} shards -> {:?} (serve it with: akda serve --model {})",
        entry.spec(),
        entry.dir,
        entry.spec()
    );
    Ok(())
}

/// `akda update NAME[@V] --data new.csv` — the paper's Sec. 7 recursive
/// learning wired through the registry: decode a published artifact, grow
/// it with the new observations (zero full refits — bordered-Cholesky
/// extension for exact models, accumulator continuation / warm landmark
/// refresh for approximate ones), re-evaluate, and publish the next
/// version. A running `serve --model NAME --watch` hot-swaps it in.
fn cmd_update(rest: &[String]) -> Result<()> {
    use akda::model::{ModelRegistry, UpdateOptions};

    let Some(spec) = rest.first().filter(|s| !s.starts_with("--")) else {
        bail!("usage: akda update NAME[@VERSION] --data new.csv [--models-dir DIR] \
               [--refresh-landmarks] [--reservoir CAP] [--backend KIND]")
    };
    let args = Args::parse(&rest[1..])?;
    parse_backend_flag(&args)?;
    let data = args
        .get("data")
        .context("akda update needs --data new.csv (label,f1,f2,... rows)")?;
    let (x_new, y_new) = akda::data::csv::load_labeled(std::path::Path::new(data))?;

    let registry = ModelRegistry::open(models_dir(&args));
    let reservoir_cap = match args.get("reservoir") {
        Some(cap) => {
            let cap: usize = cap.parse().context("--reservoir CAP must be an integer")?;
            anyhow::ensure!(cap >= 1, "--reservoir CAP must be >= 1");
            cap
        }
        None => UpdateOptions::default().reservoir_cap,
    };
    let opts = UpdateOptions {
        refresh_landmarks: args.get("refresh-landmarks").is_some(),
        reservoir_cap,
        ..Default::default()
    };
    eprintln!(
        "updating {spec} with {} rows from {data:?} ({})",
        x_new.rows(),
        if opts.refresh_landmarks { "landmark refresh on" } else { "no landmark refresh" },
    );

    // the whole resolve → grow → re-eval → publish lifecycle is one
    // library call, shared verbatim with the auto-update daemon
    let up = akda::model::update_registry_model(&registry, spec, &x_new, &y_new, &opts)?;
    let report = &up.report;
    eprintln!(
        "update [{}]: +{} rows -> {} total (C={}), bordered growths {}, \
         full refactorizations {} (structurally impossible), {:.2}s",
        report.kind,
        report.appended,
        report.total_rows,
        report.n_classes,
        report.bordered_growths,
        report.full_refactorizations,
        up.update_s
    );
    if report.kind == "exact-bordered" && args.get("reservoir").is_some() {
        eprintln!(
            "note: --reservoir has no effect on exact models (the full \
             training set is retained; reservoirs exist for approx models only)"
        );
    }
    match up.eval {
        Some((accuracy, map)) => {
            println!("update-eval: accuracy {:.2}%  MAP {:.2}%", 100.0 * accuracy, 100.0 * map)
        }
        None => eprintln!(
            "update-eval skipped: dataset {:?} is not in the registry",
            up.from.manifest.dataset
        ),
    }
    println!(
        "published {} (updated from {}; a `serve --model {} --watch` service \
         hot-swaps it in)",
        up.published.spec(),
        up.from.spec(),
        up.published.name
    );
    Ok(())
}

/// `akda trace FILE` — offline analyzer for an `akda-trace/1` JSONL
/// file (the `serve --fleet --listen ... --trace-out` artifact): count
/// of records/sheds, per-stage p50/p99 with stage-share attribution over
/// all records and over the p99 latency tail, and the top-K slowest
/// requests each attributed to its dominant stage. The headline line —
/// "p99 is 71% fleet/batch_wait" — is the tuning signal the whole trace
/// pipeline exists to produce.
fn cmd_trace(rest: &[String]) -> Result<()> {
    let Some(path) = rest.first().filter(|s| !s.starts_with("--")) else {
        bail!("usage: akda trace FILE [--top K]   (FILE is a --trace-out JSONL artifact)")
    };
    let args = Args::parse(&rest[1..])?;
    let top: usize = match args.get("top") {
        Some(v) => v.parse().context("--top K must be an integer")?,
        None => 5,
    };
    anyhow::ensure!(top >= 1, "--top K must be >= 1");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let report = akda::obs::trace::analyze(&text, top)?;
    print!("{report}");
    Ok(())
}

/// `akda daemon` — the scheduled auto-update service: watch a drop
/// directory for `NAME.csv` files of labeled rows, apply the Sec. 7
/// recursive update to model `NAME`, and republish. A fleet (or a
/// `serve --model NAME --watch` service) picks the new version up at its
/// next poll, closing the train → publish → serve-fleet → drop-data →
/// auto-update → hot-swap loop without any process restart.
fn cmd_daemon(args: &Args) -> Result<()> {
    use akda::coordinator::UpdateDaemon;
    use akda::model::{ModelRegistry, UpdateOptions};
    use std::time::Duration;

    let _metrics = parse_metrics_out(args)?;

    // --registry DIR is the documented spelling; --models-dir/$AKDA_MODELS
    // keep working so every subcommand addresses the registry the same way
    let root = args.get("registry").map(PathBuf::from).unwrap_or_else(|| models_dir(args));
    let drop_dir = args
        .get("drop-dir")
        .context("akda daemon needs --drop-dir DIR (watched for NAME.csv update files)")?;
    let interval: f64 = match args.get("interval") {
        Some(v) => v.parse().context("--interval SECS must be a number")?,
        None => 5.0,
    };
    anyhow::ensure!(interval > 0.0, "--interval SECS must be positive");
    let reservoir_cap = match args.get("reservoir") {
        Some(cap) => {
            let cap: usize = cap.parse().context("--reservoir CAP must be an integer")?;
            anyhow::ensure!(cap >= 1, "--reservoir CAP must be >= 1");
            cap
        }
        None => UpdateOptions::default().reservoir_cap,
    };
    let opts = UpdateOptions {
        refresh_landmarks: args.get("refresh-landmarks").is_some(),
        reservoir_cap,
        ..Default::default()
    };
    let registry = ModelRegistry::open(&root);
    anyhow::ensure!(
        !registry.models()?.is_empty(),
        "no models in {root:?} — train some with `akda train` before starting the daemon"
    );
    std::fs::create_dir_all(drop_dir)
        .with_context(|| format!("creating drop dir {drop_dir:?}"))?;
    eprintln!(
        "daemon: watching {drop_dir:?} every {interval}s — drop NAME.csv \
         (label,f1,f2,... rows) to grow model NAME in {root:?}"
    );
    let daemon = UpdateDaemon::start(registry, drop_dir, Duration::from_secs_f64(interval), opts);
    // supervise rather than sleep blindly: per-file panics are contained
    // inside the watcher, so a dead thread is an unexpected failure the
    // operator must see instead of a process that looks healthy forever
    while daemon.is_alive() {
        std::thread::sleep(Duration::from_secs(1));
    }
    bail!("daemon polling thread terminated unexpectedly — check the log above")
}

/// `akda export` — dump registry-dataset rows as `label,f1,f2,...` CSV,
/// the input format `akda update --data` (and the streaming
/// `CsvBlockSource`) consume. `--skip`/`--stride`/`--rows` select a row
/// subset, e.g. a strided slice of the test split as a drift simulation.
fn cmd_export(args: &Args) -> Result<()> {
    let dataset = args.get("dataset").context("export needs --dataset NAME")?;
    let spec =
        akda::data::by_name(dataset).with_context(|| format!("dataset {dataset:?}"))?;
    let cond = parse_condition(args.get("cond").unwrap_or("100"))?;
    let split = spec.split(cond);
    let which = args.get("split").unwrap_or("test");
    let (x, y) = match which {
        "train" => (&split.x_train, &split.y_train),
        "test" => (&split.x_test, &split.y_test),
        other => bail!("unknown split {other:?} (train|test)"),
    };
    let parse_n = |key: &str, default: usize| -> Result<usize> {
        match args.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    };
    let skip = parse_n("skip", 0)?;
    let stride = parse_n("stride", 1)?.max(1);
    let rows = parse_n("rows", usize::MAX)?;
    let idx: Vec<usize> = (skip..x.rows()).step_by(stride).take(rows).collect();
    anyhow::ensure!(
        !idx.is_empty(),
        "selection is empty ({} has {} rows, skip {skip}, stride {stride})",
        which,
        x.rows()
    );
    let xm = x.select_rows(&idx);
    let ym: Vec<usize> = idx.iter().map(|&i| y[i]).collect();
    let out = args.get("out").context("export needs --out FILE")?;
    akda::data::csv::save_labeled(std::path::Path::new(out), &xm, &ym)?;
    println!(
        "wrote {} rows x {} features ({} [{}] {which} split) to {out}",
        xm.rows(),
        xm.cols(),
        dataset,
        cond.name()
    );
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    use akda::model::ModelRegistry;

    let registry = ModelRegistry::open(models_dir(args));
    if let Some(pair) = args.get("diff") {
        // `--diff A B` (space) and `--diff A,B` both work
        let parts: Vec<&str> = if pair.contains(',') {
            pair.split(',').map(str::trim).collect()
        } else {
            pair.split_whitespace().collect()
        };
        anyhow::ensure!(
            parts.len() == 2,
            "--diff takes two specs, e.g. `akda models --diff mymodel@1 mymodel@2`"
        );
        print!("{}", registry.diff(parts[0], parts[1])?);
        return Ok(());
    }
    if let Some(k) = args.get("prune") {
        let keep: usize = k.parse().context("--prune K must be an integer")?;
        // the registry never deletes the newest version; --protect V
        // additionally shields the version a running `serve` process has
        // pinned (the CLI cannot see another process's BankHandle)
        let protect: Option<u32> = match args.get("protect") {
            Some(v) => {
                anyhow::ensure!(
                    args.get("model").is_some(),
                    "--protect V names one model's version: pass --model NAME with it"
                );
                Some(v.parse().context("--protect V must be a version number")?)
            }
            None => None,
        };
        let names = match args.get("model") {
            Some(n) => vec![n.to_string()],
            None => registry.models()?,
        };
        anyhow::ensure!(!names.is_empty(), "no models in {:?}", registry.root());
        for name in names {
            // every version a live fleet/serve process has marked with a
            // serve lease is auto-protected inside prune — report the ones
            // that actually survived the cut because of their lease
            let before = registry.versions(&name)?;
            let served = registry.served_versions(&name)?;
            let pruned = registry.prune(&name, keep, protect)?;
            if pruned.is_empty() {
                println!("{name}: nothing to prune");
            } else {
                let specs: Vec<String> =
                    pruned.iter().map(|v| format!("{name}@{v}")).collect();
                println!("{name}: pruned {} (kept the newest {keep})", specs.join(", "));
            }
            let shielded: Vec<String> = before
                .iter()
                .copied()
                .take(before.len().saturating_sub(keep))
                .filter(|v| served.contains(v) && !pruned.contains(v))
                .map(|v| format!("v{v}"))
                .collect();
            if !shielded.is_empty() {
                println!(
                    "{name}: auto-protected served {} (live serve markers)",
                    shielded.join(", ")
                );
            }
        }
        return Ok(());
    }
    if let Some(spec) = args.get("inspect") {
        let (entry, artifact) = registry.load_artifact(spec)?;
        println!("# {} — {:?}", entry.spec(), entry.artifact_path());
        print!("{}", entry.manifest.to_text());
        println!("# artifact sections (checksums verified):");
        for (name, rows, cols) in artifact.section_summaries() {
            println!("  {name:<18} {rows:>6} x {cols}");
        }
        return Ok(());
    }
    let names = registry.models()?;
    if names.is_empty() {
        println!(
            "no models in {:?} — train one with `akda train --dataset NAME`",
            registry.root()
        );
        return Ok(());
    }
    println!(
        "{:<16} {:<8} {:<14} {:<12} {:>6} {:>9} {:>9}",
        "model", "latest", "method", "dataset", "vers", "MAP", "accuracy"
    );
    for name in names {
        let (latest, n_versions) = registry.latest_with_count(&name)?;
        let mf = &latest.manifest;
        println!(
            "{:<16} v{:<7} {:<14} {:<12} {:>6} {:>8.2}% {:>8.2}%",
            name,
            latest.version,
            mf.method,
            mf.dataset,
            n_versions,
            100.0 * mf.map,
            100.0 * mf.accuracy
        );
    }
    Ok(())
}

/// Parse `--watch [SECS]` into a poll interval (bare flag = 2s).
fn parse_watch(args: &Args) -> Result<Option<std::time::Duration>> {
    match args.get("watch") {
        Some(v) => {
            let poll: f64 = if v == "true" { 2.0 } else { v.parse().context("--watch SECS")? };
            anyhow::ensure!(poll > 0.0, "--watch SECS must be positive");
            Ok(Some(std::time::Duration::from_secs_f64(poll)))
        }
        None => Ok(None),
    }
}

/// `--metrics-out FILE [--metrics-interval SECS]` — start the background
/// JSONL metrics writer for the long-running subcommands. The returned
/// writer must be held for the life of the command: it appends one
/// snapshot immediately, one per interval, and a final one on drop.
fn parse_metrics_out(args: &Args) -> Result<Option<akda::obs::MetricsWriter>> {
    let Some(path) = args.get("metrics-out") else {
        anyhow::ensure!(
            args.get("metrics-interval").is_none(),
            "--metrics-interval only makes sense with --metrics-out FILE"
        );
        return Ok(None);
    };
    let period: f64 = match args.get("metrics-interval") {
        Some(v) => v.parse().context("--metrics-interval SECS must be a number")?,
        None => 2.0,
    };
    anyhow::ensure!(period > 0.0, "--metrics-interval SECS must be positive");
    let writer = akda::obs::MetricsWriter::start(
        std::path::Path::new(path),
        std::time::Duration::from_secs_f64(period),
    );
    Ok(Some(writer))
}

/// `--trace-out FILE [--trace-sample N] [--trace-slow-ms MS]` — build the
/// request-trace sink for the TCP edge. Sampling defaults to every
/// request; an explicit `--trace-slow-ms` without `--trace-sample` turns
/// sampling off, so the file holds only the slow log (plus sheds, which
/// are always recorded while any policy is active).
fn parse_trace_flags(args: &Args) -> Result<Option<Arc<akda::obs::TraceSink>>> {
    let Some(path) = args.get("trace-out") else {
        anyhow::ensure!(
            args.get("trace-sample").is_none() && args.get("trace-slow-ms").is_none(),
            "--trace-sample/--trace-slow-ms only make sense with --trace-out FILE"
        );
        return Ok(None);
    };
    let slow_ms: Option<f64> = match args.get("trace-slow-ms") {
        Some(v) => {
            let ms: f64 = v.parse().context("--trace-slow-ms MS must be a number")?;
            anyhow::ensure!(ms >= 0.0, "--trace-slow-ms MS must be >= 0");
            Some(ms)
        }
        None => None,
    };
    let sample: u64 = match args.get("trace-sample") {
        Some(v) => v.parse().context("--trace-sample N must be an integer")?,
        // slow-log-only when a threshold is given, else trace everything
        None if slow_ms.is_some() => 0,
        None => 1,
    };
    let sink = akda::obs::TraceSink::create(std::path::Path::new(path), sample, slow_ms)?;
    Ok(Some(Arc::new(sink)))
}

/// `akda serve --fleet` — multi-tenant serving: every model in the
/// registry behind one process, routed by model id over one shared
/// worker pool (`coordinator::fleet::FleetService`). The demo drives
/// each tenant's held-out split through the shared pool by id, proves
/// unknown ids are protocol-rejected, and — with `--watch` — stays up
/// so daemon-republished tenants hot-swap in live.
fn cmd_serve_fleet(args: &Args) -> Result<()> {
    use akda::coordinator::fleet::{FleetError, FleetOptions, FleetService};
    use akda::coordinator::net::{NetOptions, NetServer};
    use akda::model::ModelRegistry;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    let conflicts = [
        "model", "method", "landmarks", "stream", "block-size", "cond", "pjrt", "dataset",
        "backend",
    ];
    for flag in conflicts {
        anyhow::ensure!(
            args.get(flag).is_none(),
            "--{flag} conflicts with --fleet (the fleet serves every published \
             model as stored)"
        );
    }
    let registry = ModelRegistry::open(models_dir(args));
    let watch = parse_watch(args)?;
    let opts = FleetOptions { watch, ..Default::default() };
    let svc = FleetService::start(&registry, opts)?;
    let client = svc.client();
    let served = svc.served_versions();
    let roster: Vec<String> = served.iter().map(|(n, v)| format!("{n}@{v}")).collect();
    eprintln!(
        "fleet: serving {} tenants from {:?}: {}",
        served.len(),
        registry.root(),
        roster.join(", ")
    );
    if let Some(poll) = watch {
        eprintln!(
            "fleet: watching for republished tenants every {:.1}s",
            poll.as_secs_f64()
        );
    }
    // the TCP edge starts before the demo traffic, so remote clients can
    // connect as soon as the line below is printed
    let trace_sink = parse_trace_flags(args)?;
    anyhow::ensure!(
        trace_sink.is_none() || args.get("listen").is_some(),
        "--trace-out traces the TCP edge: pass --listen ADDR with it"
    );
    let net = match args.get("listen") {
        Some(addr) => {
            let opts = NetOptions { trace: trace_sink.clone(), ..Default::default() };
            let server = NetServer::start(addr, svc.client(), opts)?;
            println!("fleet: listening on {} (akda-wire/1)", server.local_addr());
            if let Some(sink) = &trace_sink {
                eprintln!("fleet: tracing requests to {:?} (akda-trace/1)", sink.path());
            }
            Some(server)
        }
        None => None,
    };

    // demo traffic per tenant, all routed by model id through one pool
    for (name, version) in &served {
        let mf = registry.resolve(name)?.manifest;
        let split = akda::data::by_name(&mf.dataset)
            .and_then(|dspec| akda::data::Condition::parse(&mf.condition).map(|c| dspec.split(c)));
        let Some(split) = split else {
            eprintln!(
                "fleet demo: {name}@{version} skipped (dataset {:?} is not in the registry)",
                mf.dataset
            );
            continue;
        };
        let n = split.x_test.rows();
        let workers = akda::util::threads::available().clamp(2, 8).min(n.max(1));
        let correct = AtomicUsize::new(0);
        std::thread::scope(|s| -> Result<()> {
            let mut joins = Vec::new();
            for w in 0..workers {
                let client = client.clone();
                let (split, correct, name) = (&split, &correct, name.as_str());
                joins.push(s.spawn(move || -> Result<()> {
                    let mut i = w;
                    while i < n {
                        let scores = client.score(name, split.x_test.row(i).to_vec())?;
                        if predict(&scores) == split.y_test[i] {
                            correct.fetch_add(1, Ordering::Relaxed);
                        }
                        i += workers;
                    }
                    Ok(())
                }));
            }
            for j in joins {
                j.join().expect("fleet demo worker panicked")?;
            }
            Ok(())
        })?;
        println!(
            "fleet demo: {name}@{version} accuracy {:.2}% over {n} requests",
            100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64
        );
    }

    // protocol check: an unknown id is rejected on the reply path — the
    // service neither panics nor stops answering the real tenants
    match client.score("no-such-model", vec![0.0]) {
        Err(err @ FleetError::UnknownModel { .. }) => {
            println!("fleet demo: unknown model rejected: {err}")
        }
        other => bail!("unknown model must be protocol-rejected, got {other:?}"),
    }
    let stats = svc.stats();
    println!(
        "fleet: {} requests in {} dispatch rounds (max round {}, rejected {})",
        stats.requests, stats.batches, stats.max_batch, stats.rejected
    );
    if watch.is_some() || net.is_some() {
        eprintln!(
            "fleet demo complete; still serving {} tenants{} — Ctrl-C to stop",
            served.len(),
            if net.is_some() { " (in-process and over TCP)" } else { " with hot reload" }
        );
        loop {
            std::thread::sleep(Duration::from_secs(60));
        }
    }
    Ok(())
}

/// `akda client` — the remote side of `serve --fleet --listen`: connect
/// over TCP speaking akda-wire/1, print the live tenant roster, and
/// optionally score one tenant's held-out split (the scores cross the
/// wire bit-for-bit, so the printed accuracy equals the train-time eval)
/// or probe the server with a deliberately malformed frame.
fn cmd_client(args: &Args) -> Result<()> {
    use akda::coordinator::net::{NetClient, NetReply};
    use akda::coordinator::wire::Frame;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    let addr = args.get("connect").context("akda client needs --connect HOST:PORT")?;
    let timeout: f64 = match args.get("timeout") {
        Some(v) => v.parse().context("--timeout SECS must be a number")?,
        None => 30.0,
    };
    anyhow::ensure!(timeout > 0.0, "--timeout SECS must be positive");
    let timeout = Duration::from_secs_f64(timeout);
    let mut conn = NetClient::connect(addr, timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    let roster = conn.models()?;
    println!("client: {} tenants at {addr}:", roster.len());
    for m in &roster {
        println!("  {}@{} (input dim {})", m.name, m.version, m.input_dim);
    }

    // --metrics: scrape the server's registry snapshot over the same
    // socket (MetricsRequest/MetricsResponse frames — no HTTP port)
    if args.get("metrics").is_some() {
        println!("{}", conn.metrics()?);
        if args.get("model").is_none() && args.get("probe").is_none() {
            return Ok(());
        }
    }

    if args.get("probe").is_some() {
        // bytes that can never be a frame: the server must answer with a
        // typed BadFrame error and close THIS connection, nothing else
        conn.send_raw(b"NOT-AKDA-WIRE-AT-ALL-JUST-GARBAGE-BYTES.")?;
        match conn.recv()? {
            Frame::Error { code, message, .. } => {
                println!("probe: typed error frame: {code} ({message})");
                return Ok(());
            }
            other => bail!("probe expected an Error frame, got {other:?}"),
        }
    }

    let Some(model) = args.get("model") else {
        return Ok(());
    };
    let Some(tenant) = roster.iter().find(|m| m.name == model) else {
        let names: Vec<&str> = roster.iter().map(|m| m.name.as_str()).collect();
        bail!("model {model:?} is not served (roster: {})", names.join(", "));
    };
    // demo rows come from a registry dataset — by default the one named
    // like the model (the `akda train` default naming)
    let dataset = args.get("dataset").unwrap_or(model);
    let dspec =
        akda::data::by_name(dataset).with_context(|| format!("dataset {dataset:?}"))?;
    let cond = parse_condition(args.get("cond").unwrap_or("100"))?;
    let split = dspec.split(cond);
    anyhow::ensure!(
        split.x_test.cols() == tenant.input_dim as usize,
        "dataset {dataset:?} has {} features but {}@{} expects {}",
        split.x_test.cols(),
        tenant.name,
        tenant.version,
        tenant.input_dim
    );
    let n = split.x_test.rows();
    let workers = akda::util::threads::available().clamp(2, 8).min(n.max(1));
    let correct = AtomicUsize::new(0);
    // --trace aggregator: (traced requests, summed RTT seconds, summed
    // per-stage seconds from the server-timing echo, keyed by stage id)
    let trace_on = args.get("trace").is_some();
    let agg: std::sync::Mutex<(u64, f64, std::collections::BTreeMap<u8, f64>)> =
        std::sync::Mutex::new((0, 0.0, std::collections::BTreeMap::new()));
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut joins = Vec::new();
        for w in 0..workers {
            let (split, correct, agg) = (&split, &correct, &agg);
            joins.push(s.spawn(move || -> Result<()> {
                let mut conn = NetClient::connect(addr, timeout)?;
                // per-worker deterministic id stream: same invocation,
                // same trace ids (the crate's reproducibility spine)
                let mut ids = akda::obs::TraceIdGen::new(0x414B_4441 + w as u64);
                let mut i = w;
                while i < n {
                    let reply = if trace_on {
                        let traced =
                            conn.score_traced(model, split.x_test.row(i), ids.next_id())?;
                        let mut a = agg.lock().expect("trace aggregator poisoned");
                        a.0 += 1;
                        a.1 += traced.rtt.as_secs_f64();
                        for &(id, nanos) in &traced.timings {
                            *a.2.entry(id).or_insert(0.0) += nanos as f64 * 1e-9;
                        }
                        traced.reply
                    } else {
                        conn.score(model, split.x_test.row(i))?
                    };
                    match reply {
                        NetReply::Scores(scores) => {
                            if predict(&scores) == split.y_test[i] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        NetReply::Rejected { code, message, .. } => {
                            bail!("request rejected: {code}: {message}")
                        }
                    }
                    i += workers;
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().expect("client worker panicked")?;
        }
        Ok(())
    })?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "client: {}@{} accuracy {:.2}% over {n} requests \
         ({:.0} req/s, {workers} connections)",
        tenant.name,
        tenant.version,
        100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64,
        n as f64 / dt
    );
    if trace_on {
        let (count, rtt_s, stage_s) = agg.into_inner().expect("trace aggregator poisoned");
        anyhow::ensure!(count > 0, "--trace scored no requests");
        let sum_s: f64 = stage_s.values().sum();
        println!("client trace: mean server-side stage timing over {count} traced requests:");
        // BTreeMap order == hop order (stage ids are hop-numbered)
        for (&id, &secs) in &stage_s {
            let name = akda::obs::trace::stage_name(id)
                .map(str::to_string)
                .unwrap_or_else(|| format!("stage/{id}"));
            println!(
                "  {name:<18} {:>9.3} ms  ({:>4.1}% of rtt)",
                secs / count as f64 * 1e3,
                100.0 * secs / rtt_s.max(f64::EPSILON)
            );
        }
        println!(
            "  stage sum {:.3} ms <= mean rtt {:.3} ms \
             (server residency {:.1}%; the rest is wire + client stack)",
            sum_s / count as f64 * 1e3,
            rtt_s / count as f64 * 1e3,
            100.0 * sum_s / rtt_s.max(f64::EPSILON)
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use akda::coordinator::{BankHandle, ScoringService};
    use akda::model::{HotReloader, ModelRegistry};
    use std::time::Duration;

    let _metrics = parse_metrics_out(args)?;

    // fleet path: every model in the registry behind one process
    if args.get("fleet").is_some() {
        return cmd_serve_fleet(args);
    }
    anyhow::ensure!(
        args.get("listen").is_none(),
        "--listen requires --fleet (the akda-wire/1 protocol fronts the fleet)"
    );
    anyhow::ensure!(
        args.get("trace-out").is_none(),
        "--trace-out requires --fleet --listen (request tracing fronts the TCP edge)"
    );

    // registry path: load a published model — zero training work (the
    // bank is decoded from checksummed tensors; no fit call anywhere)
    if let Some(spec) = args.get("model") {
        // the stored model carries its own hyper-parameters; reject the
        // training knobs instead of silently ignoring them
        for flag in ["method", "landmarks", "stream", "block-size", "cond", "pjrt", "backend"] {
            anyhow::ensure!(
                args.get(flag).is_none(),
                "--{flag} configures training and conflicts with --model \
                 (the published model's hyper-parameters are used as stored)"
            );
        }
        anyhow::ensure!(
            !(spec.contains('@') && args.get("watch").is_some()),
            "--watch tracks the latest version and would override the \
             pinned {spec:?}; drop --watch or use the bare model name"
        );
        let registry = ModelRegistry::open(models_dir(args));
        let (entry, artifact) = registry.load_artifact(spec)?;
        // size the service from the checksummed artifact, not the
        // editable plain-text MANIFEST (which is informational only)
        let input_dim = akda::model::codec::input_dim(&artifact)?;
        let bank = akda::model::decode_bank(&artifact)
            .with_context(|| format!("decoding model {}", entry.spec()))?;
        let mf = entry.manifest.clone();
        eprintln!(
            "loaded {} (method {}, trained on {} [{}], C={}) — no retraining",
            entry.spec(),
            mf.method,
            mf.dataset,
            mf.condition,
            bank.svms.len()
        );
        // demo traffic comes from the dataset the model was trained on
        // (or an explicit --dataset override with matching features)
        let dataset = args.get("dataset").unwrap_or(mf.dataset.as_str());
        let dspec = akda::data::by_name(dataset)
            .with_context(|| format!("dataset {dataset:?}"))?;
        let split = dspec.split(parse_condition(&mf.condition)?);
        anyhow::ensure!(
            split.x_test.cols() == input_dim,
            "dataset {dataset:?} has {} features but {} expects {}",
            split.x_test.cols(),
            entry.spec(),
            input_dim
        );
        // versioned handle: monitoring (and in-process GC callers) can ask
        // which registry version is live; the watcher advances it on swap
        let handle = BankHandle::new_versioned(Arc::new(bank), entry.version);
        // GC shield: lease the served version so `akda models --prune` run
        // from another process cannot delete it while this one serves it
        // (released on exit; the watcher re-points it on every hot swap)
        let mut marker =
            Some(akda::model::ServeMarker::publish(&registry, &entry.name, entry.version)?);
        let watcher = match parse_watch(args)? {
            Some(poll) => {
                eprintln!(
                    "watching {:?} for new versions every {}s",
                    registry.root(),
                    poll.as_secs_f64()
                );
                Some(HotReloader::start(
                    registry.clone(),
                    entry.name.clone(),
                    handle.clone(),
                    entry.version,
                    input_dim,
                    poll,
                    marker.take(),
                ))
            }
            None => None,
        };
        // without a watcher the lease lives (and dies) with this function
        let _marker = marker;
        let svc = ScoringService::start_reloadable(
            handle,
            input_dim,
            64,
            Duration::from_millis(5),
        );
        drive_demo(&svc, &split)?;
        return match watcher {
            // --watch means "stay up": keep the service + watcher alive so
            // newly published versions actually get hot-swapped in
            Some(_watcher) => {
                eprintln!(
                    "demo complete; still serving {} with hot reload — Ctrl-C to stop",
                    entry.spec()
                );
                loop {
                    std::thread::sleep(Duration::from_secs(60));
                }
            }
            None => Ok(()),
        };
    }

    // in-process path: train a bank now, then serve it
    let ts = parse_train_spec(args)?;
    eprintln!(
        "training detector bank on {} (C={}) with {}",
        ts.dataset,
        ts.split.n_classes,
        ts.id.name()
    );
    let (bank, train_s, _resume) = fit_detector_bank(&ts, false)?;
    eprintln!("trained in {train_s:.2}s — tip: `akda train` publishes instead");
    let svc = ScoringService::start(
        bank,
        ts.split.x_train.cols(),
        64,
        Duration::from_millis(5),
    );
    drive_demo(&svc, &ts.split)
}

/// Last non-empty line of a `--metrics-out` JSONL file, parsed.
fn last_snapshot(path: &str) -> Result<akda::util::json::Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let last = text
        .lines()
        .rev()
        .find(|l| !l.trim().is_empty())
        .with_context(|| format!("{path:?} contains no snapshots"))?;
    akda::util::json::parse(last).with_context(|| format!("parsing the last snapshot in {path:?}"))
}

/// `akda metrics` — print an `obs` registry snapshot, or validate files
/// previously emitted through `--metrics-out` and the bench emitters.
///
/// The default mode runs a tiny in-process training workload first so a
/// fresh process has live instruments to render; `--from FILE` instead
/// re-prints the most recent snapshot a long-running service appended.
/// Both surfaces — this command and the `--metrics-out` JSONL — render
/// the same [`akda::obs::Snapshot`], so names and labels always agree.
fn cmd_metrics(args: &Args) -> Result<()> {
    use akda::obs;

    // --validate FILE [--require k1,k2]: the CI entry point — schema
    // check, optionally asserting named metrics are nonzero (and
    // heartbeats fresh) in the file's last snapshot
    if let Some(path) = args.get("validate") {
        let summary = obs::validate::validate_file(std::path::Path::new(path))?;
        if let Some(csv) = args.get("require") {
            let keys: Vec<&str> = csv.split(',').map(str::trim).filter(|k| !k.is_empty()).collect();
            anyhow::ensure!(!keys.is_empty(), "--require needs at least one metric name");
            let doc = last_snapshot(path)?;
            obs::validate::require_nonzero(&doc, &keys)
                .with_context(|| format!("--require failed on the last snapshot in {path:?}"))?;
            println!("{summary}; required nonzero: {}", keys.join(", "));
        } else {
            println!("{summary}");
        }
        return Ok(());
    }
    anyhow::ensure!(
        args.get("require").is_none(),
        "--require only makes sense with --validate FILE"
    );

    // --from FILE: re-print what a running service last wrote
    if let Some(path) = args.get("from") {
        let doc = last_snapshot(path)?;
        obs::validate::validate_metrics_line(&doc)?;
        println!("{doc}");
        return Ok(());
    }

    // default: exercise the training path so the snapshot shows live
    // phase spans, then render this process's registry
    use akda::da::{DrMethod, Projection};
    use akda::data::synthetic::{gaussian_classes, GaussianSpec};
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![24, 24],
        dim: 8,
        class_sep: 2.0,
        noise: 0.5,
        modes_per_class: 1,
        seed: 7,
    });
    let mut watch = akda::util::timer::Stopwatch::new();
    let hp = Hyper { rho: 0.2, c: 1.0, h: 2, ..Default::default() };
    let dr = akda::coordinator::protocol::akda_config(hp, 1e-3);
    let proj = watch.train(|| dr.fit(&x, &labels, 2))?;
    let _scores = watch.test(|| proj.project(&x));
    let snap = obs::global().snapshot();
    match args.get("format").unwrap_or("prometheus") {
        "json" => println!("{}", snap.to_json(obs::unix_now())),
        "prometheus" | "prom" => print!("{}", snap.to_prometheus()),
        other => bail!("unknown --format {other:?} (expected prometheus or json)"),
    }
    Ok(())
}

fn cmd_check() -> Result<()> {
    let dir = artifacts_dir();
    let engine = PjrtEngine::from_dir(&dir)?;
    let mf_entries = engine.handle().manifest().entries.len();
    println!("manifest: {mf_entries} artifacts in {dir:?}");
    // smoke: tiny fit through the smallest bucket
    use akda::data::synthetic::{gaussian_classes, GaussianSpec};
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![20, 20],
        dim: 8,
        class_sep: 2.0,
        noise: 0.5,
        modes_per_class: 1,
        seed: 1,
    });
    let theta = akda::da::core::theta_binary(&labels);
    let psi = engine.fit(&x, &theta, akda::kernels::Kernel::Rbf { rho: 0.2 })?;
    anyhow::ensure!(psi.is_finite(), "non-finite psi");
    println!("PJRT round trip OK (psi {}x{})", psi.rows(), psi.cols());
    Ok(())
}
