//! `akda` CLI — the coordinator launcher.
//!
//! Subcommands:
//!   datasets                      print the Table-1 registry (scaled)
//!   eval --suite med|cross10|cross100 [...]
//!                                 regenerate the MAP + speedup tables
//!   toy                           Sec. 6.2 toy example (Figs. 2–3 data)
//!   serve --dataset NAME          train a detector bank and serve scores
//!   check                         verify artifacts + PJRT round trip

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use akda::coordinator::{
    build_dr, evaluate_ovr, select_hyper, EvalConfig, Hyper, MethodId, WorkPool,
};
use akda::data::{cross_dataset_collection, med_datasets, Condition, DatasetSpec};
use akda::eval::tables::{map_table, memory_table, results_csv, speedup_table, DatasetRow};
use akda::runtime::PjrtEngine;

fn artifacts_dir() -> PathBuf {
    std::env::var("AKDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", rest[i]))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.insert(k.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn parse_landmarks(s: &str) -> Result<usize> {
    let m: usize = s.parse().context("--landmarks must be a positive integer")?;
    anyhow::ensure!(m >= 1, "--landmarks must be a positive integer, got 0");
    Ok(m)
}

/// `--stream [--block-size B]` → `Some(B)`; `--block-size` alone implies
/// `--stream`; `--stream B` is accepted as shorthand for the pair;
/// neither flag → `None` (in-memory).
fn parse_stream_flags(args: &Args) -> Result<Option<usize>> {
    let stream = args.get("stream");
    let block = args.get("block-size");
    if stream.is_none() && block.is_none() {
        return Ok(None);
    }
    // a bare `--stream` parses as "true" (see Args::parse); any other
    // attached value is a tile height, same as --block-size
    let explicit = block.or_else(|| stream.filter(|v| *v != "true"));
    match explicit {
        Some(s) => {
            let b: usize = s.parse().context("--block-size must be a positive integer")?;
            anyhow::ensure!(b >= 1, "--block-size must be a positive integer, got 0");
            Ok(Some(b))
        }
        None => Ok(Some(akda::data::stream::DEFAULT_BLOCK_ROWS)),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "eval" => cmd_eval(&args),
        "toy" => cmd_toy(&args),
        "serve" => cmd_serve(&args),
        "check" => cmd_check(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `akda help`"),
    }
}

fn print_help() {
    println!(
        "akda — Accelerated Kernel Discriminant Analysis (paper reproduction)\n\n\
         USAGE: akda <command> [flags]\n\n\
         COMMANDS:\n\
           datasets                         print the dataset registry (Table 1)\n\
           eval --suite med|cross10|cross100\n\
                [--methods csv] [--landmarks M] [--stream] [--block-size B]\n\
                [--cv] [--pjrt] [--config file] [--out dir]\n\
                                            regenerate MAP + speedup tables (Tables 2-7);\n\
                                            methods include akda-nystrom|akda-rff (approx\n\
                                            subsystem, --landmarks sets the budget m);\n\
                                            --stream trains them out of core in tiles of\n\
                                            B rows and adds a peak-residency table\n\
           toy [--out dir]                  Sec. 6.2 toy example (Figs. 2-3 data)\n\
           serve --dataset NAME [--method akda|akda-nystrom|akda-rff|...]\n\
                 [--landmarks M] [--stream] [--block-size B] [--pjrt]\n\
                                            train a detector bank, demo scoring service\n\
           check                            verify artifacts + PJRT round trip\n\n\
         ENV: AKDA_ARTIFACTS (default: ./artifacts)"
    );
}

fn cmd_datasets() -> Result<()> {
    println!("Cross-dataset collection (Table 1, scaled — DESIGN.md §3):");
    for d in cross_dataset_collection() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    println!("TRECVID MED (Sec. 6.1.1, scaled):");
    for d in med_datasets() {
        println!("  {}", d.describe(Condition::Ex10));
    }
    Ok(())
}

fn suite_of(name: &str) -> Result<(Vec<DatasetSpec>, Condition, &'static str)> {
    Ok(match name {
        "med" => (med_datasets(), Condition::Ex100, "TRECVID MED (Tables 2, 5)"),
        "cross10" => (
            cross_dataset_collection(),
            Condition::Ex10,
            "cross-dataset 10Ex (Tables 3, 6)",
        ),
        "cross100" => (
            cross_dataset_collection(),
            Condition::Ex100,
            "cross-dataset 100Ex (Tables 4, 7)",
        ),
        other => bail!("unknown suite {other:?} (med|cross10|cross100)"),
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let suite = args.get("suite").unwrap_or("cross10");
    let (datasets, cond, title) = suite_of(suite)?;
    let mut cfg = match args.get("config") {
        Some(path) => EvalConfig::from_file(std::path::Path::new(path))?,
        None => EvalConfig::default(),
    };
    let methods: Vec<MethodId> = match args.get("methods") {
        Some(csv) => csv
            .split(',')
            .map(|m| {
                MethodId::from_name(m.trim())
                    .with_context(|| format!("unknown method {m:?}"))
            })
            .collect::<Result<_>>()?,
        None => MethodId::table_columns(),
    };
    let use_cv = args.get("cv").is_some();
    // set before CV so select_hyper scores the grid at the same budget m
    // (and the same execution mode) the final fit uses
    if let Some(m) = args.get("landmarks") {
        cfg.landmarks = parse_landmarks(m)?;
    }
    if let Some(b) = parse_stream_flags(args)? {
        cfg.stream_block = Some(b);
    }
    let engine = if args.get("pjrt").is_some()
        || methods.iter().any(|m| matches!(m, MethodId::AkdaPjrt | MethodId::AksdaPjrt))
    {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let pool = WorkPool::new(cfg.workers);

    let mut rows = Vec::new();
    for spec in &datasets {
        eprintln!("== {} [{}]", spec.name, cond.name());
        let split = spec.split(cond);
        let mut results = Vec::new();
        for &id in &methods {
            let hp = if use_cv {
                let hp = select_hyper(&split, id, &cfg, engine.as_ref())?;
                eprintln!("   {}: CV picked rho={} c={} h={}", id.name(), hp.rho, hp.c, hp.h);
                hp
            } else {
                Hyper {
                    rho: 0.05,
                    c: 1.0,
                    h: 2,
                    m: cfg.landmarks,
                    stream_block: cfg.stream_block,
                }
            };
            let res = evaluate_ovr(&split, id, hp, cfg.eps, engine.as_ref(), Some(&pool))?;
            eprintln!(
                "   {:<10} MAP={:.2}% train={:.2}s test={:.2}s",
                res.method, 100.0 * res.map, res.train_s, res.test_s
            );
            results.push(res);
        }
        rows.push(DatasetRow { dataset: spec.name.to_string(), results });
    }

    println!("{}", map_table(&format!("MAP — {title}"), &rows));
    println!("{}", speedup_table(&format!("train/test speedup over KDA — {title}"), &rows));
    if rows.iter().any(|r| r.results.iter().any(|m| m.peak_f64.is_some())) {
        println!(
            "{}",
            memory_table(&format!("peak resident training tiles — {title}"), &rows)
        );
    }
    if let Some(dir) = args.get("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("results_{suite}.csv"));
        std::fs::write(&path, results_csv(&rows))?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    // delegate to the shared implementation used by examples/toy_example.rs
    let out = args.get("out").unwrap_or("toy_output");
    akda_toy::run(std::path::Path::new(out), artifacts_dir().as_path())
}

/// The toy example logic is shared with examples/toy_example.rs via include.
mod akda_toy {
    include!("../../examples/toy_impl.rs");
}

fn cmd_serve(args: &Args) -> Result<()> {
    use akda::coordinator::{DetectorBank, ScoringService};
    use akda::da::DrMethod;
    use akda::svm::{LinearSvm, LinearSvmConfig};
    use std::time::Duration;

    let name = args.get("dataset").unwrap_or("eth80");
    let spec = akda::data::by_name(name).with_context(|| format!("dataset {name:?}"))?;
    let split = spec.split(Condition::Ex100);
    let use_pjrt = args.get("pjrt").is_some();
    let method = match args.get("method") {
        Some(m) => m,
        None if use_pjrt => "akda-pjrt",
        None => "akda",
    };
    let id = MethodId::from_name(method)
        .with_context(|| format!("unknown method {method:?}"))?;
    let needs_engine = matches!(id, MethodId::AkdaPjrt | MethodId::AksdaPjrt);
    if use_pjrt && !needs_engine {
        bail!("--pjrt serves the PJRT engines; use --method akda-pjrt|aksda-pjrt or drop --pjrt");
    }
    eprintln!(
        "training detector bank on {} (C={}) with {}",
        name, split.n_classes, method
    );

    let engine = if needs_engine {
        Some(Arc::new(PjrtEngine::from_dir(&artifacts_dir())?))
    } else {
        None
    };
    let mut hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    if let Some(m) = args.get("landmarks") {
        hp.m = parse_landmarks(m)?;
    }
    hp.stream_block = parse_stream_flags(args)?;
    let proj: Box<dyn akda::da::Projection> = match (hp.stream_block, id) {
        (Some(block_rows), MethodId::AkdaNystrom | MethodId::AkdaRff) => {
            // out-of-core training: tiled ΦᵀΦ/class-sum accumulation, then
            // one m×m solve — the bank never sees an N×m feature matrix
            let ap = akda::coordinator::protocol::approx_config(id, hp, 1e-3);
            let mut src = akda::data::stream::MemBlockSource::new(
                &split.x_train,
                &split.y_train,
                block_rows,
            );
            let prep = ap.prepare_stream(&mut src)?;
            // the comparison is training-STATE residency: registry datasets
            // are served from RAM either way (a CsvBlockSource would make
            // the whole run out-of-core), but the tiled path never builds
            // the N×m Φ the in-memory trainer would hold on top
            eprintln!(
                "streaming fit: {} tiles of <= {} rows, training-state peak {:.2} MB \
                 vs {:.2} MB in-memory (dataset itself stays resident here)",
                prep.stats.blocks,
                prep.stats.peak_block_rows,
                prep.stats.peak_resident_f64() as f64 * 8.0 / 1e6,
                prep.stats.dense_resident_f64() as f64 * 8.0 / 1e6,
            );
            let w = prep.solve_w_multiclass()?;
            Box::new(akda::da::akda_stream::BlockedProjection {
                map: prep.map.clone(),
                w,
                block_rows,
            })
        }
        (Some(_), _) => {
            bail!("--stream applies to --method akda-nystrom|akda-rff only")
        }
        (None, _) => {
            let dr = build_dr(id, hp, 1e-3, engine.as_ref())?
                .with_context(|| format!("{method} has no DR stage to serve"))?;
            dr.fit(&split.x_train, &split.y_train, split.n_classes)?
        }
    };
    let z = proj.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    let bank = Arc::new(DetectorBank { projection: proj, svms });
    let svc = ScoringService::start(bank, split.x_train.cols(), 64, Duration::from_millis(5));
    let client = svc.client();

    // demo: score the test set through the service, report accuracy + stats
    let t0 = std::time::Instant::now();
    let mut correct = 0;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..split.x_test.rows() {
            let client = client.clone();
            let row = split.x_test.row(i).to_vec();
            handles.push(s.spawn(move || client.score(row).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let scores = h.join().unwrap();
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap();
            if pred == split.y_test[i] {
                correct += 1;
            }
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "served {} requests in {:.2}s ({:.0} req/s), accuracy {:.1}%, batches={} max_batch={}",
        split.x_test.rows(),
        dt,
        split.x_test.rows() as f64 / dt,
        100.0 * correct as f64 / split.x_test.rows() as f64,
        stats.batches,
        stats.max_batch
    );
    Ok(())
}

fn cmd_check() -> Result<()> {
    let dir = artifacts_dir();
    let engine = PjrtEngine::from_dir(&dir)?;
    let mf_entries = engine.handle().manifest().entries.len();
    println!("manifest: {mf_entries} artifacts in {dir:?}");
    // smoke: tiny fit through the smallest bucket
    use akda::data::synthetic::{gaussian_classes, GaussianSpec};
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![20, 20],
        dim: 8,
        class_sep: 2.0,
        noise: 0.5,
        modes_per_class: 1,
        seed: 1,
    });
    let theta = akda::da::core::theta_binary(&labels);
    let psi = engine.fit(&x, &theta, akda::kernels::Kernel::Rbf { rho: 0.2 })?;
    anyhow::ensure!(psi.is_finite(), "non-finite psi");
    println!("PJRT round trip OK (psi {}x{})", psi.rows(), psi.cols());
    Ok(())
}
