//! Random Fourier features for the RBF kernel (Rahimi & Recht).
//!
//! For k(x, y) = exp(−ρ‖x − y‖²) — exactly `kernels::Kernel::Rbf`, whose
//! bandwidth `Kernel::rho` this map consumes — Bochner's theorem gives
//! k(x, y) = E_ω[cos(ωᵀ(x − y))] with ω ~ N(0, 2ρ I). Sampling p
//! frequencies and stacking the cos/sin pair per frequency,
//!
//!   φ(x) = p^{−1/2} [cos(ω_1ᵀx), sin(ω_1ᵀx), …, cos(ω_pᵀx), sin(ω_pᵀx)]
//!
//! yields an unbiased estimate φ(x)·φ(y) → k(x, y) with O(p^{−1/2})
//! Monte-Carlo error. Unlike Nyström the map is data-independent: only
//! the input dimensionality and a seed are needed, so it can be built
//! before any data arrives (streaming / serving friendly).

use anyhow::Result;

use super::FeatureMap;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::util::rng::Rng;

pub struct RffMap {
    /// F×p frequency matrix Ω, ω_j ~ N(0, 2ρ I).
    omega: Mat,
    /// p^{−1/2} normalization so Φ Φᵀ is an unbiased Gram estimate.
    scale: f64,
}

impl RffMap {
    /// Build a map with `n_features` output dimensions (rounded down to an
    /// even count — features come in cos/sin pairs; at least one pair).
    pub fn fit(dim_in: usize, kernel: Kernel, n_features: usize, seed: u64) -> Result<Self> {
        let rho = match kernel {
            Kernel::Rbf { rho } => rho,
            other => anyhow::bail!(
                "RFF approximates the RBF kernel only, got {:?} kernel",
                other.name()
            ),
        };
        anyhow::ensure!(rho > 0.0, "RFF needs a positive RBF bandwidth, got {rho}");
        anyhow::ensure!(dim_in > 0, "RFF needs a positive input dimensionality");
        let pairs = (n_features / 2).max(1);
        let mut rng = Rng::new(seed);
        let sd = (2.0 * rho).sqrt();
        let omega = Mat::from_fn(dim_in, pairs, |_, _| sd * rng.normal());
        Ok(RffMap { omega, scale: 1.0 / (pairs as f64).sqrt() })
    }

    /// The F×p frequency matrix Ω — exposed for the model-artifact
    /// subsystem.
    pub fn omega(&self) -> &Mat {
        &self.omega
    }

    /// The p^{−1/2} normalization factor.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Reassemble a fitted map from persisted state (`model::codec`); the
    /// map is fully determined by Ω and the scale, so the reconstruction
    /// transforms bit-for-bit identically to the original.
    pub fn from_parts(omega: Mat, scale: f64) -> Result<Self> {
        anyhow::ensure!(
            omega.rows() > 0 && omega.cols() > 0 && scale > 0.0,
            "RFF state must have a nonempty frequency matrix and positive scale"
        );
        Ok(RffMap { omega, scale })
    }
}

impl FeatureMap for RffMap {
    fn name(&self) -> &'static str {
        "rff"
    }

    fn dim(&self) -> usize {
        2 * self.omega.cols()
    }

    fn transform(&self, x: &Mat) -> Mat {
        let proj = x.matmul(&self.omega); // N×p phases
        let (n, p) = proj.shape();
        let mut out = Mat::zeros(n, 2 * p);
        for i in 0..n {
            let phases = proj.row(i);
            let orow = out.row_mut(i);
            for (j, &ph) in phases.iter().enumerate() {
                let (s, c) = ph.sin_cos();
                orow[2 * j] = self.scale * c;
                orow[2 * j + 1] = self.scale * s;
            }
        }
        out
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gram;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn mean_abs_gram_err(x: &Mat, rho: f64, d: usize) -> f64 {
        let map = RffMap::fit(x.cols(), Kernel::Rbf { rho }, d, 7).unwrap();
        let phi = map.transform(x);
        let approx = phi.matmul_nt(&phi);
        let exact = gram(x, Kernel::Rbf { rho });
        let n = x.rows();
        approx.sub(&exact).data().iter().map(|v| v.abs()).sum::<f64>() / (n * n) as f64
    }

    #[test]
    fn gram_estimate_converges_with_feature_count() {
        // Satellite regression: ΦΦᵀ must approach the exact Kernel::Rbf
        // Gram as the feature budget grows (Monte-Carlo rate p^{-1/2}).
        let x = randmat(40, 6, 11);
        let coarse = mean_abs_gram_err(&x, 0.3, 128);
        let fine = mean_abs_gram_err(&x, 0.3, 8192);
        assert!(fine < coarse, "err(d=8192)={fine} vs err(d=128)={coarse}");
        assert!(fine < 0.03, "err(d=8192)={fine}");
    }

    #[test]
    fn diagonal_is_exactly_one() {
        // φ(x)·φ(x) = (1/p) Σ (cos² + sin²) = 1 = k(x, x), with zero
        // Monte-Carlo variance — a structural property of the pairing.
        let x = randmat(10, 4, 3);
        let map = RffMap::fit(4, Kernel::Rbf { rho: 0.8 }, 64, 1).unwrap();
        let phi = map.transform(&x);
        for i in 0..10 {
            let d: f64 = phi.row(i).iter().map(|v| v * v).sum();
            assert!((d - 1.0).abs() < 1e-12, "row {i}: {d}");
        }
    }

    #[test]
    fn dim_is_even_and_at_least_two() {
        let map = RffMap::fit(5, Kernel::Rbf { rho: 1.0 }, 33, 2).unwrap();
        assert_eq!(map.dim(), 32);
        let map = RffMap::fit(5, Kernel::Rbf { rho: 1.0 }, 1, 2).unwrap();
        assert_eq!(map.dim(), 2);
    }

    #[test]
    fn rejects_non_rbf_kernels() {
        assert!(RffMap::fit(4, Kernel::Linear, 16, 1).is_err());
        assert!(RffMap::fit(4, Kernel::Poly { degree: 2, c: 1.0 }, 16, 1).is_err());
        assert!(RffMap::fit(4, Kernel::Rbf { rho: 0.0 }, 16, 1).is_err());
    }

    #[test]
    fn deterministic_for_seed() {
        let x = randmat(8, 3, 5);
        let kernel = Kernel::Rbf { rho: 0.5 };
        let a = RffMap::fit(3, kernel, 64, 9).unwrap().transform(&x);
        let b = RffMap::fit(3, kernel, 64, 9).unwrap().transform(&x);
        assert_eq!(a, b);
    }
}
