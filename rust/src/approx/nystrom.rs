//! Nyström landmark features.
//!
//! Pick m landmarks Z covering the data (k-means centroids — reusing
//! `cluster::kmeans`, the same routine AKSDA's subclass partitioning
//! uses), form the small landmark Gram K_zz = k(Z, Z), eigendecompose it
//! (`linalg::eig`, m×m — cheap), and map
//!
//!   φ(x) = k(x, Z) · U_r Λ_r^{−1/2}          (r = rank of K_zz)
//!
//! so that Φ Φᵀ = K_nz K_zz^{+} K_zn — the Nyström approximation of the
//! full Gram matrix. When the landmarks are the training set itself
//! (m = N) the approximation is exact: Φ Φᵀ = K.
//!
//! Cost: O(N m) kernel evaluations + O(m³) eigen work, vs O(N²) / O(N³)
//! for the exact Gram + Cholesky path.

use anyhow::Result;

use super::FeatureMap;
use crate::cluster::kmeans::kmeans;
use crate::kernels::{cross_gram, gram, Kernel};
use crate::linalg::{sym_eig_desc, Mat};

/// Lloyd iterations for landmark selection. Landmarks only need to *cover*
/// the data, not to converge — a short run is the standard trade-off and
/// keeps selection well below the O(N m²) feature-map cost.
const LANDMARK_KMEANS_ITERS: usize = 15;

/// Relative eigenvalue cut-off below which landmark-Gram directions are
/// dropped (pseudo-inverse behaviour for rank-deficient K_zz).
const RANK_TOL: f64 = 1e-12;

pub struct NystromMap {
    /// m×F landmark matrix Z.
    pub landmarks: Mat,
    pub kernel: Kernel,
    /// m×r whitening W = U_r Λ_r^{−1/2}; φ(x) = k(x, Z) W.
    w: Mat,
}

impl NystromMap {
    /// Select landmarks from the rows of `x` and build the feature map.
    /// `m` is clamped to [1, N]; at m = N the training rows themselves are
    /// the landmarks (exact Nyström — used by the equivalence tests).
    pub fn fit(x: &Mat, kernel: Kernel, m: usize, seed: u64) -> Result<Self> {
        let n = x.rows();
        anyhow::ensure!(n > 0, "Nystrom needs at least one observation");
        let m = m.clamp(1, n);
        let landmarks = if m == n {
            x.clone()
        } else {
            kmeans(x, m, LANDMARK_KMEANS_ITERS, seed).centroids
        };
        Self::from_landmarks(landmarks, kernel)
    }

    /// Build the map from an explicitly supplied landmark matrix: form the
    /// m×m landmark Gram, eigendecompose, truncate near-null directions,
    /// and whiten. This is both [`NystromMap::fit`]'s second half and the
    /// incremental landmark-refresh entry point (`model::update` feeds it
    /// warm-started k-means centroids as the data drifts) — O(m³) work,
    /// independent of the stream length.
    pub fn from_landmarks(landmarks: Mat, kernel: Kernel) -> Result<Self> {
        anyhow::ensure!(landmarks.rows() > 0, "Nystrom needs at least one landmark");
        let k_zz = gram(&landmarks, kernel);
        let eig = sym_eig_desc(&k_zz)
            .map_err(|e| anyhow::anyhow!("landmark Gram eigendecomposition failed: {e}"))?;
        let lam_max = eig.values.first().copied().unwrap_or(0.0);
        anyhow::ensure!(
            lam_max > 0.0,
            "landmark Gram has no positive eigenvalue — degenerate kernel/landmarks"
        );
        let tol = lam_max * RANK_TOL;
        let r = eig.values.iter().take_while(|&&l| l > tol).count();
        let rows = landmarks.rows();
        let mut w = Mat::zeros(rows, r);
        for j in 0..r {
            let s = 1.0 / eig.values[j].sqrt();
            for i in 0..rows {
                w[(i, j)] = eig.vectors[(i, j)] * s;
            }
        }
        Ok(NystromMap { landmarks, kernel, w })
    }

    /// The m×r whitening factor W = U_r Λ_r^{−1/2} (φ(x) = k(x, Z) W) —
    /// exposed for the model-artifact subsystem.
    pub fn whitening(&self) -> &Mat {
        &self.w
    }

    /// Reassemble a fitted map from persisted state (`model::codec`):
    /// exactly the landmarks and whitening a previous `fit` produced, so
    /// `transform` is bit-for-bit identical to the original map's.
    pub fn from_parts(landmarks: Mat, kernel: Kernel, whitening: Mat) -> Result<Self> {
        anyhow::ensure!(
            landmarks.rows() == whitening.rows(),
            "Nystrom state mismatch: {} landmarks vs {} whitening rows",
            landmarks.rows(),
            whitening.rows()
        );
        Ok(NystromMap { landmarks, kernel, w: whitening })
    }
}

impl FeatureMap for NystromMap {
    fn name(&self) -> &'static str {
        "nystrom"
    }

    fn dim(&self) -> usize {
        self.w.cols()
    }

    fn transform(&self, x: &Mat) -> Mat {
        cross_gram(x, &self.landmarks, self.kernel).matmul(&self.w)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blobs(n_per: usize, centers: &[[f64; 2]], seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let n = n_per * centers.len();
        let mut x = Mat::zeros(n, 2);
        for (c, ctr) in centers.iter().enumerate() {
            for i in 0..n_per {
                let r = c * n_per + i;
                x[(r, 0)] = ctr[0] + 0.15 * rng.normal();
                x[(r, 1)] = ctr[1] + 0.15 * rng.normal();
            }
        }
        x
    }

    fn gram_err(x: &Mat, kernel: Kernel, m: usize) -> f64 {
        let map = NystromMap::fit(x, kernel, m, 5).unwrap();
        let phi = map.transform(x);
        let approx = phi.matmul_nt(&phi);
        let exact = gram(x, kernel);
        approx.sub(&exact).frobenius_norm() / exact.frobenius_norm()
    }

    #[test]
    fn full_landmarks_reproduce_exact_gram() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(30, 4, |_, _| rng.normal());
        let kernel = Kernel::Rbf { rho: 0.5 };
        let map = NystromMap::fit(&x, kernel, 30, 2).unwrap();
        let phi = map.transform(&x);
        let k = gram(&x, kernel);
        assert!(phi.matmul_nt(&phi).sub(&k).max_abs() < 1e-6, "m = N must be exact");
    }

    #[test]
    fn more_landmarks_tighten_the_approximation() {
        let x = blobs(30, &[[0.0, 0.0], [4.0, 0.0], [0.0, 4.0]], 9);
        let kernel = Kernel::Rbf { rho: 0.5 };
        let coarse = gram_err(&x, kernel, 3);
        let fine = gram_err(&x, kernel, 45);
        assert!(fine < coarse, "err(m=45)={fine} vs err(m=3)={coarse}");
        assert!(fine < 0.1, "err(m=45)={fine}");
    }

    #[test]
    fn linear_kernel_rank_deficiency_is_truncated() {
        // 2-D data: linear landmark Gram has rank ≤ 2 regardless of m
        let x = blobs(20, &[[1.0, 0.5], [-1.0, 2.0]], 4);
        let map = NystromMap::fit(&x, Kernel::Linear, 10, 3).unwrap();
        assert!(map.dim() <= 2, "dim {} should collapse to input rank", map.dim());
        let phi = map.transform(&x);
        let k = gram(&x, Kernel::Linear);
        assert!(phi.matmul_nt(&phi).sub(&k).frobenius_norm() / k.frobenius_norm() < 0.2);
    }

    #[test]
    fn budget_is_clamped_to_n() {
        let mut rng = Rng::new(8);
        let x = Mat::from_fn(7, 3, |_, _| rng.normal());
        let map = NystromMap::fit(&x, Kernel::Rbf { rho: 1.0 }, 100, 1).unwrap();
        assert_eq!(map.landmarks.rows(), 7);
        assert!(map.dim() <= 7);
    }

    #[test]
    fn from_landmarks_matches_fit_given_the_same_landmarks() {
        let x = blobs(20, &[[0.0, 0.0], [4.0, 4.0]], 6);
        let kernel = Kernel::Rbf { rho: 0.6 };
        let fitted = NystromMap::fit(&x, kernel, 8, 11).unwrap();
        let rebuilt =
            NystromMap::from_landmarks(fitted.landmarks.clone(), kernel).unwrap();
        assert_eq!(rebuilt.dim(), fitted.dim());
        let (a, b) = (fitted.transform(&x), rebuilt.transform(&x));
        assert!(a.sub(&b).max_abs() == 0.0, "same landmarks must give the same map");
    }

    #[test]
    fn deterministic_for_seed() {
        let x = blobs(15, &[[0.0, 0.0], [3.0, 3.0]], 2);
        let kernel = Kernel::Rbf { rho: 0.7 };
        let a = NystromMap::fit(&x, kernel, 6, 42).unwrap().transform(&x);
        let b = NystromMap::fit(&x, kernel, 6, 42).unwrap().transform(&x);
        assert_eq!(a, b);
    }
}
