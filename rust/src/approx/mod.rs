//! Approximate kernel-feature subsystem: explicit low-dimensional feature
//! maps φ: R^F → R^m with φ(x)·φ(y) ≈ k(x, y).
//!
//! AKDA's accelerated pipeline still pays O(N²F) for the Gram matrix and
//! N³/3 for its Cholesky — fine at the paper's scale, a wall at N ≫ 10⁴.
//! The standard escape hatch ("Scalable Kernel Learning via the
//! Discriminant Information") is to replace the implicit kernel expansion
//! with an explicit m-dimensional feature map, m ≪ N, and run the exact
//! same core-matrix + Cholesky machinery on the m-dim Gram ΦᵀΦ instead of
//! the N×N kernel matrix:
//!
//! * [`NystromMap`] — data-dependent landmark features
//!   φ(x) = k(x, Z) K_zz^{−1/2}, landmarks Z from `cluster::kmeans`;
//! * [`RffMap`] — data-independent random Fourier features for the RBF
//!   kernel (Rahimi & Recht's construction, seeded and deterministic).
//!
//! Both are pluggable behind the [`FeatureMap`] trait so
//! `da::akda_approx::AkdaApprox` (and any future consumer) can treat
//! approximators uniformly. Because `transform` is row-independent, maps
//! also drive the out-of-core tiled pipeline (`da::akda_stream`): blocks
//! of rows can be transformed and absorbed one tile at a time with
//! results identical to the in-memory path.

pub mod nystrom;
pub mod rff;

pub use nystrom::NystromMap;
pub use rff::RffMap;

use crate::linalg::Mat;

/// Default landmark / random-feature budget m — the single source for
/// `coordinator::Hyper::default` and `coordinator::EvalConfig::default`.
pub const DEFAULT_BUDGET: usize = 64;

/// An explicit feature map approximating a Mercer kernel: `transform`
/// returns the N×m feature matrix Φ with Φ Φᵀ ≈ K.
pub trait FeatureMap: Send + Sync {
    fn name(&self) -> &'static str;
    /// Output feature dimensionality m (may be below the requested budget
    /// when the landmark Gram is rank-deficient).
    fn dim(&self) -> usize;
    /// Map observations (rows of `x`) into the feature space.
    fn transform(&self, x: &Mat) -> Mat;
    /// Introspection hook for the model-artifact subsystem
    /// (`model::codec` downcasts to the concrete map to serialize it).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Which approximator to build — the knob the coordinator and CLI expose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApproxKind {
    Nystrom,
    Rff,
}

impl ApproxKind {
    pub fn name(&self) -> &'static str {
        match self {
            ApproxKind::Nystrom => "nystrom",
            ApproxKind::Rff => "rff",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::util::rng::Rng;

    #[test]
    fn feature_maps_are_object_safe_and_uniform() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(12, 5, |_, _| rng.normal());
        let kernel = Kernel::Rbf { rho: 0.4 };
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(NystromMap::fit(&x, kernel, 6, 1).unwrap()),
            Box::new(RffMap::fit(5, kernel, 32, 1).unwrap()),
        ];
        for map in &maps {
            let phi = map.transform(&x);
            assert_eq!(phi.rows(), 12);
            assert_eq!(phi.cols(), map.dim());
            assert!(phi.is_finite(), "{}", map.name());
        }
    }
}
