//! The `.akda` model-artifact format: a hand-rolled, versioned, checksummed
//! binary container for trained-model state. No serde, no external crates —
//! the whole format is ~200 lines of explicit little-endian encoding so the
//! on-disk layout is auditable byte by byte.
//!
//! # Layout (format version 1)
//!
//! ```text
//! offset 0   magic           8 bytes  b"AKDAMODL"
//!            format version  u32 LE   (readers reject newer versions)
//!            meta count      u32 LE
//!            meta entries    count x (str key, str value)
//!            section count   u32 LE
//!            sections        count x section
//!            file checksum   u64 LE   FNV-1a 64 over every preceding byte
//!
//! str     := u32 LE byte length, then that many UTF-8 bytes
//! section := str name
//!            u64 LE rows, u64 LE cols
//!            rows*cols x f64 LE      (row-major tensor payload)
//!            u64 LE section checksum (FNV-1a 64 over name/shape/payload
//!                                     bytes of this section)
//! ```
//!
//! Meta entries carry the small, discrete state (method id, projection
//! kind, class names, integer shapes); every floating-point quantity lives
//! in an f64 tensor section so save -> load round-trips are bit-for-bit.
//!
//! # Integrity
//!
//! Two checksum layers: each section checksums its own bytes (localizes
//! corruption to a named tensor) and the trailing file checksum covers the
//! whole byte stream including the header and the section checksums.
//! `from_bytes` verifies the file checksum first — truncation, bit flips,
//! and magic/version mismatches all fail with a descriptive `Err`, never a
//! panic or a silently-wrong model. Tensor payload lengths are validated
//! against the remaining buffer before allocation, so a corrupt shape
//! cannot trigger an unbounded allocation.
//!
//! # Examples
//!
//! ```
//! use akda::linalg::Mat;
//! use akda::model::ModelArtifact;
//!
//! let mut art = ModelArtifact::new();
//! art.set_meta("method", "akda");
//! art.push_tensor("psi", Mat::from_fn(3, 2, |r, c| (r + c) as f64));
//!
//! let bytes = art.to_bytes();
//! let back = ModelArtifact::from_bytes(&bytes).unwrap();
//! assert_eq!(back.meta_str("method").unwrap(), "akda");
//! assert_eq!(back.tensor("psi").unwrap(), art.tensor("psi").unwrap()); // bit-for-bit
//!
//! // corruption is detected, never served
//! let mut bad = bytes.clone();
//! bad[bytes.len() / 2] ^= 1;
//! assert!(ModelArtifact::from_bytes(&bad).is_err());
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::linalg::Mat;

/// Leading magic bytes of every `.akda` artifact.
pub const MAGIC: &[u8; 8] = b"AKDAMODL";

/// Current writer format version. Readers accept versions `<=` this.
pub const FORMAT_VERSION: u32 = 1;

/// Conventional file name inside a registry version directory.
pub const ARTIFACT_FILE: &str = "model.akda";

/// An in-memory model artifact: string metadata plus named f64 tensors.
#[derive(Debug, Clone, Default)]
pub struct ModelArtifact {
    /// Discrete state: method id, projection kind, class names, dims.
    pub meta: BTreeMap<String, String>,
    /// Named tensor sections in write order.
    sections: Vec<(String, Mat)>,
}

impl ModelArtifact {
    pub fn new() -> Self {
        ModelArtifact::default()
    }

    pub fn set_meta(&mut self, key: &str, value: impl Into<String>) {
        self.meta.insert(key.to_string(), value.into());
    }

    pub fn meta_str(&self, key: &str) -> Result<&str> {
        self.meta
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("artifact is missing meta key {key:?}"))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta_str(key)?
            .parse()
            .with_context(|| format!("artifact meta key {key:?} is not an integer"))
    }

    /// Append a named tensor section (names must be unique).
    pub fn push_tensor(&mut self, name: &str, tensor: Mat) {
        assert!(
            self.sections.iter().all(|(n, _)| n != name),
            "duplicate artifact section {name:?}"
        );
        self.sections.push((name.to_string(), tensor));
    }

    pub fn tensor(&self, name: &str) -> Result<&Mat> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
            .with_context(|| format!("artifact is missing tensor section {name:?}"))
    }

    pub fn has_tensor(&self, name: &str) -> bool {
        self.sections.iter().any(|(n, _)| n == name)
    }

    /// Section names with shapes, for `akda models --inspect`.
    pub fn section_summaries(&self) -> Vec<(String, usize, usize)> {
        self.sections
            .iter()
            .map(|(n, t)| (n.clone(), t.rows(), t.cols()))
            .collect()
    }

    /// Per-section `(name, rows, cols, checksum)` — the same FNV-1a 64
    /// the on-disk format stores for each section, so `akda models
    /// --diff` can report which tensors actually changed between two
    /// versions without comparing payloads element by element.
    pub fn section_digests(&self) -> Vec<(String, usize, usize, u64)> {
        self.sections
            .iter()
            .map(|(n, t)| {
                // stream the exact bytes `write_section` emits (minus its
                // trailing stored checksum) through the hash, so a large
                // tensor payload is never materialized a second time
                let mut header = Vec::with_capacity(4 + n.len() + 16);
                write_str(&mut header, n);
                header.extend_from_slice(&(t.rows() as u64).to_le_bytes());
                header.extend_from_slice(&(t.cols() as u64).to_le_bytes());
                let mut sum = fnv1a64_update(FNV_OFFSET_BASIS, &header);
                for v in t.data() {
                    sum = fnv1a64_update(sum, &v.to_le_bytes());
                }
                (n.clone(), t.rows(), t.cols(), sum)
            })
            .collect()
    }

    /// Serialize to the format described in the module docs.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.meta.len() as u32).to_le_bytes());
        for (k, v) in &self.meta {
            write_str(&mut out, k);
            write_str(&mut out, v);
        }
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, tensor) in &self.sections {
            write_section(&mut out, name, tensor);
        }
        let file_sum = fnv1a64(&out);
        out.extend_from_slice(&file_sum.to_le_bytes());
        out
    }

    /// Parse and fully verify an artifact byte stream.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(
            bytes.len() >= MAGIC.len() + 4 + 8,
            "artifact truncated: {} bytes is smaller than any valid artifact \
             (checksum verification impossible)",
            bytes.len()
        );
        ensure!(
            &bytes[..MAGIC.len()] == MAGIC,
            "bad artifact magic: not an .akda model file"
        );
        // Whole-file checksum first: catches truncation and bit flips
        // anywhere before we interpret any field.
        let body_len = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        let actual = fnv1a64(&bytes[..body_len]);
        ensure!(
            stored == actual,
            "artifact file checksum mismatch (stored {stored:#018x}, computed \
             {actual:#018x}) — file is truncated or corrupt"
        );

        let mut r = Reader { buf: &bytes[..body_len], pos: MAGIC.len() };
        let version = r.u32()?;
        ensure!(
            version <= FORMAT_VERSION,
            "artifact format version {version} is newer than this reader \
             (max {FORMAT_VERSION})"
        );
        let n_meta = r.u32()? as usize;
        let mut meta = BTreeMap::new();
        for _ in 0..n_meta {
            let k = r.str()?;
            let v = r.str()?;
            meta.insert(k, v);
        }
        let n_sections = r.u32()? as usize;
        let mut sections = Vec::with_capacity(n_sections.min(1024));
        for _ in 0..n_sections {
            let start = r.pos;
            let name = r.str()?;
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let len = rows
                .checked_mul(cols)
                .and_then(|n| n.checked_mul(8))
                .map(|_| rows * cols)
                .with_context(|| format!("section {name:?}: shape overflow"))?;
            ensure!(
                len * 8 <= r.remaining(),
                "section {name:?} claims {rows}x{cols} f64s but only {} bytes \
                 remain — artifact truncated or corrupt",
                r.remaining()
            );
            // length is validated above, so decode the payload in one take
            // (per-element bounds-checked reads are measurably slower on
            // multi-megabyte kernel-expansion tensors)
            let payload = r.take(len * 8)?;
            let data: Vec<f64> = payload
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let computed = fnv1a64(&r.buf[start..r.pos]);
            let stored = r.u64()?;
            ensure!(
                stored == computed,
                "section {name:?} checksum mismatch — tensor payload corrupt"
            );
            sections.push((name, Mat::from_vec(rows, cols, data)));
        }
        ensure!(
            r.remaining() == 0,
            "{} trailing bytes after the last section — artifact corrupt",
            r.remaining()
        );
        Ok(ModelArtifact { meta, sections })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing artifact {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading artifact {path:?}"))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing artifact {path:?}"))
    }
}

/// FNV-1a 64-bit — tiny, dependency-free, and plenty for integrity checks
/// of a local trusted-path format (this is corruption detection, not
/// cryptographic authentication).
const FNV_OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64 state — the streaming form
/// behind [`fnv1a64`], also used by `section_digests` to hash a tensor
/// payload without copying it into a contiguous buffer first.
fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(FNV_OFFSET_BASIS, bytes)
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Append one section (name, shape, payload, section checksum) to `out`.
/// `section_digests` streams these exact bytes (minus the trailing
/// checksum) through the hash, so its digest always matches what lands on
/// disk — keep the two byte layouts in lockstep.
fn write_section(out: &mut Vec<u8>, name: &str, tensor: &Mat) {
    let start = out.len();
    write_str(out, name);
    out.extend_from_slice(&(tensor.rows() as u64).to_le_bytes());
    out.extend_from_slice(&(tensor.cols() as u64).to_le_bytes());
    for v in tensor.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let sum = fnv1a64(&out[start..]);
    out.extend_from_slice(&sum.to_le_bytes());
}

/// Bounds-checked little-endian reader over the verified body bytes.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "artifact truncated: wanted {n} bytes, {} remain",
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        match std::str::from_utf8(bytes) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => bail!("artifact string field is not valid UTF-8"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelArtifact {
        let mut a = ModelArtifact::new();
        a.set_meta("method", "akda");
        a.set_meta("classes", "3");
        a.push_tensor("psi", Mat::from_fn(4, 2, |r, c| (r * 2 + c) as f64 * 0.5));
        a.push_tensor("w", Mat::from_fn(1, 3, |_, c| -(c as f64) / 3.0));
        a
    }

    #[test]
    fn roundtrip_preserves_meta_and_tensors_bitwise() {
        let a = sample();
        let b = ModelArtifact::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.tensor("psi").unwrap(), b.tensor("psi").unwrap());
        assert_eq!(a.tensor("w").unwrap(), b.tensor("w").unwrap());
        assert_eq!(b.section_summaries(), vec![
            ("psi".to_string(), 4, 2),
            ("w".to_string(), 1, 3),
        ]);
    }

    #[test]
    fn nonfinite_values_survive_bitwise() {
        // the format must not normalize payload bits (NaN payloads, -0.0)
        let mut a = ModelArtifact::new();
        a.push_tensor(
            "t",
            Mat::from_vec(1, 3, vec![f64::NAN, -0.0, f64::INFINITY]),
        );
        let b = ModelArtifact::from_bytes(&a.to_bytes()).unwrap();
        let bits: Vec<u64> =
            b.tensor("t").unwrap().data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits[0], f64::NAN.to_bits());
        assert_eq!(bits[1], (-0.0_f64).to_bits());
        assert_eq!(bits[2], f64::INFINITY.to_bits());
    }

    #[test]
    fn every_truncation_point_is_rejected() {
        let bytes = sample().to_bytes();
        for cut in 0..bytes.len() {
            let err = ModelArtifact::from_bytes(&bytes[..cut])
                .expect_err("truncated artifact must not parse");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("checksum") || msg.contains("truncated"),
                "cut={cut}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let bytes = sample().to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(
                ModelArtifact::from_bytes(&bad).is_err(),
                "bit flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn rejects_wrong_magic_and_future_versions() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        // re-seal so only the magic is wrong
        let n = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..n]).to_le_bytes();
        bytes[n..].copy_from_slice(&sum);
        let msg = format!("{:#}", ModelArtifact::from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("magic"), "{msg}");

        let mut bytes = sample().to_bytes();
        bytes[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let n = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..n]).to_le_bytes();
        bytes[n..].copy_from_slice(&sum);
        let msg = format!("{:#}", ModelArtifact::from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("version"), "{msg}");
    }

    #[test]
    fn corrupt_shape_cannot_force_a_huge_allocation() {
        // blow up a section's row count and re-seal both checksums: the
        // length-vs-remaining check must fire before any allocation
        let a = sample();
        let mut bytes = a.to_bytes();
        // section table starts after magic+version+meta; find "psi" name
        let pat = b"psi";
        let at = bytes.windows(pat.len()).position(|w| w == pat).unwrap();
        let rows_at = at + pat.len();
        bytes[rows_at..rows_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let n = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..n]).to_le_bytes();
        bytes[n..].copy_from_slice(&sum);
        let msg = format!("{:#}", ModelArtifact::from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("overflow") || msg.contains("truncated"), "{msg}");
    }

    #[test]
    fn section_digests_track_payload_changes() {
        let a = sample();
        let d1 = a.section_digests();
        assert_eq!(d1.len(), 2);
        assert_eq!((d1[0].0.as_str(), d1[0].1, d1[0].2), ("psi", 4, 2));
        // identical artifact, identical digests
        let b = ModelArtifact::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(d1, b.section_digests());
        // one payload element changes, only that section's digest moves
        let mut c = ModelArtifact::new();
        c.set_meta("method", "akda");
        c.set_meta("classes", "3");
        c.push_tensor("psi", Mat::from_fn(4, 2, |r, col| (r * 2 + col) as f64 * 0.5 + 1.0));
        c.push_tensor("w", Mat::from_fn(1, 3, |_, col| -(col as f64) / 3.0));
        let d2 = c.section_digests();
        assert_ne!(d1[0].3, d2[0].3, "psi digest must change");
        assert_eq!(d1[1].3, d2[1].3, "w digest must not change");
    }

    #[test]
    fn missing_keys_give_descriptive_errors() {
        let a = sample();
        assert!(a.tensor("nope").is_err());
        assert!(a.meta_str("nope").is_err());
        assert!(a.meta_usize("method").is_err()); // "akda" is not an integer
        assert_eq!(a.meta_usize("classes").unwrap(), 3);
    }
}
