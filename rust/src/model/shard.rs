//! Shard artifacts and the merge algebra (L11): distributed training by
//! accumulator merge.
//!
//! The streaming training state of the approximate AKDA path is a pure
//! sum — the pre-ridge m×m Gram G = ΦᵀΦ, the m×C class sums S = ΦᵀR, and
//! the per-class counts all add elementwise — so `k` workers can each
//! accumulate a disjoint stride of the stream and their states merge into
//! exactly what one pass over the whole stream would have produced. This
//! module is the persistence + algebra half of that story:
//!
//! * [`ShardPiece`] — one worker's output: the shared feature map, its
//!   partial [`ApproxResume`] aggregates (class axis padded to the
//!   dataset's declared C, so shards that missed a rare class still line
//!   up), its stride identity `index/count`, and the landmark-basis
//!   fingerprint.
//! * [`encode_shard`]/[`decode_shard`] — the partial-artifact grammar:
//!   an `.akda` container holding map + resume sections plus `shard.*`
//!   meta, but *no* projection/bank (a shard is not servable).
//! * [`ShardSet`] — the merge algebra. A set is a map keyed by stride
//!   index; [`ShardSet::merge`] is set union with compatibility checks
//!   (m / C / ε / basis / k → typed [`MergeError`]s, never panics), which
//!   makes merging **associative and commutative by construction**.
//!   [`ShardSet::finalize`] then folds the aggregates in ascending stride
//!   order — one canonical reduction — so *any* merge tree over the same
//!   shards produces bit-identical output (f64 `+` commutes bitwise but
//!   does not associate; the canonical fold sidesteps that entirely).
//!
//! A single-shard set finalizes to its shard's aggregates untouched, and
//! `shard_seed(base, 0, 1) == base`, so `k = 1` sharded training is
//! bit-for-bit the unsharded `akda train`. `tests/shard.rs` pins all of
//! these claims.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::artifact::{fnv1a64, ModelArtifact};
use super::codec::{self, ApproxResume, ResumeState};
use crate::approx::FeatureMap;
use crate::da::akda_stream::{MergeError, StreamAggregates, StreamStats};
use crate::data::stream::LabeledReservoir;
use crate::util::rng::shard_seed;

/// Meta key for a shard artifact's stride index `i`.
pub const SHARD_INDEX_KEY: &str = "shard.index";
/// Meta key for the total shard count `k` of the train.
pub const SHARD_COUNT_KEY: &str = "shard.count";
/// Meta key for the hex landmark-basis fingerprint.
pub const SHARD_BASIS_KEY: &str = "shard.basis";
/// Meta key for the tile height the shard accumulated with.
pub const SHARD_BLOCK_KEY: &str = "shard.block";
/// Prefix under which train-spec passthrough meta is stored.
pub const SHARD_META_PREFIX: &str = "shard.meta.";

/// Fixed base seed for the reservoir-union draws during finalize. The
/// fold order is canonical (ascending stride index), so this only has to
/// be deterministic, not configurable.
const MERGE_RESERVOIR_SEED: u64 = 0x9E37_79B9;

/// Fingerprint of a feature map's exact persisted state: FNV-1a 64 over
/// the map's artifact meta and per-section digests (which themselves hash
/// the exact on-disk tensor bytes). Two maps fingerprint equal iff they
/// would serialize identically — the property shard merging needs, since
/// Grams accumulated in different feature bases are not summable.
pub fn basis_fingerprint(map: &dyn FeatureMap) -> Result<u64> {
    let mut art = ModelArtifact::new();
    codec::encode_map(&mut art, map)?;
    let mut bytes = Vec::new();
    for (k, v) in &art.meta {
        bytes.extend_from_slice(k.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(v.as_bytes());
        bytes.push(0);
    }
    for (name, rows, cols, sum) in art.section_digests() {
        bytes.extend_from_slice(name.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(&(rows as u64).to_le_bytes());
        bytes.extend_from_slice(&(cols as u64).to_le_bytes());
        bytes.extend_from_slice(&sum.to_le_bytes());
    }
    Ok(fnv1a64(&bytes))
}

/// One shard's training output: stride identity, the shared feature map
/// with its fingerprint, and the partial aggregates.
pub struct ShardPiece {
    /// Stride index `i` — this shard accumulated rows `g ≡ i (mod count)`.
    pub index: usize,
    /// Total shard count `k` of the train.
    pub count: usize,
    /// [`basis_fingerprint`] of `map`.
    pub basis: u64,
    /// Tile height the shard streamed with (the merged model serves with
    /// the same `BlockedProjection` tiling).
    pub block_rows: usize,
    /// The shared feature map every shard of the train must agree on.
    pub map: Arc<dyn FeatureMap>,
    /// Partial aggregates: pre-ridge Gram, class sums padded to the
    /// declared C, per-class counts (zeros allowed — only the *merged*
    /// state must cover every class), this shard's labeled reservoir.
    pub resume: ApproxResume,
    /// Train-spec passthrough (dataset, method, …) the merge CLI uses to
    /// rebuild the evaluation context. Free-form string pairs.
    pub meta: BTreeMap<String, String>,
}

impl ShardPiece {
    fn dim(&self) -> usize {
        self.resume.gram.rows()
    }

    fn n_classes(&self) -> usize {
        self.resume.class_sums.cols()
    }
}

/// Serialize a shard into a partial `.akda` artifact: map sections +
/// resume sections + `shard.*` meta. No projection, no SVM bank — the
/// artifact is merge input, not a servable model.
pub fn encode_shard(piece: &ShardPiece) -> Result<ModelArtifact> {
    ensure!(
        piece.index < piece.count,
        "shard index {} out of range for {} shards",
        piece.index,
        piece.count
    );
    let mut art = ModelArtifact::new();
    art.set_meta(SHARD_INDEX_KEY, piece.index.to_string());
    art.set_meta(SHARD_COUNT_KEY, piece.count.to_string());
    art.set_meta(SHARD_BASIS_KEY, format!("{:016x}", piece.basis));
    art.set_meta(SHARD_BLOCK_KEY, piece.block_rows.to_string());
    for (k, v) in &piece.meta {
        art.set_meta(&format!("{SHARD_META_PREFIX}{k}"), v.clone());
    }
    codec::encode_map(&mut art, piece.map.as_ref())?;
    codec::encode_resume(&mut art, &ResumeState::Approx(piece.resume.clone()))?;
    Ok(art)
}

/// `true` when the artifact carries shard sections (and is therefore not
/// directly servable).
pub fn is_shard(art: &ModelArtifact) -> bool {
    art.meta.contains_key(SHARD_INDEX_KEY)
}

/// Deserialize a shard artifact. The stored basis fingerprint is
/// re-derived from the map sections actually present and must match —
/// a shard whose map was tampered with (or spliced from another train)
/// fails here instead of producing a silently wrong merge.
pub fn decode_shard(art: &ModelArtifact) -> Result<ShardPiece> {
    ensure!(is_shard(art), "artifact carries no shard sections (not `train --shard` output?)");
    let index = art.meta_usize(SHARD_INDEX_KEY)?;
    let count = art.meta_usize(SHARD_COUNT_KEY)?;
    ensure!(count >= 1 && index < count, "shard {index}/{count} is malformed");
    let block_rows = art.meta_usize(SHARD_BLOCK_KEY)?.max(1);
    let stored = u64::from_str_radix(art.meta_str(SHARD_BASIS_KEY)?, 16)
        .context("shard.basis is not a hex fingerprint")?;
    let map = codec::decode_map(art)?;
    let actual = basis_fingerprint(map.as_ref())?;
    ensure!(
        stored == actual,
        "shard basis fingerprint {stored:016x} does not match its own map sections \
         ({actual:016x}) — corrupt or spliced shard artifact"
    );
    let resume = match codec::decode_resume(art)? {
        Some(ResumeState::Approx(r)) => r,
        Some(ResumeState::Exact(_)) => bail!("shard artifacts carry approx resume state only"),
        None => bail!("shard artifact has no resume sections"),
    };
    let mut meta = BTreeMap::new();
    for (k, v) in &art.meta {
        if let Some(stripped) = k.strip_prefix(SHARD_META_PREFIX) {
            meta.insert(stripped.to_string(), v.clone());
        }
    }
    Ok(ShardPiece { index, count, basis: actual, block_rows, map, resume, meta })
}

/// The finalized (merged) training state: everything `akda merge` needs
/// to factorize, fit the bank, and publish.
pub struct MergedTrain {
    pub map: Arc<dyn FeatureMap>,
    /// Summed pre-ridge Gram / class sums / counts, folded in canonical
    /// (ascending stride index) order.
    pub aggregates: StreamAggregates,
    /// Union reservoir over the shards' labeled reservoirs.
    pub reservoir: LabeledReservoir,
    pub eps: f64,
    pub block_rows: usize,
    /// Shard count the state was merged from (`health.shards`).
    pub count: usize,
    /// Train-spec passthrough from shard 0.
    pub meta: BTreeMap<String, String>,
}

/// A set of compatible shards of one train, keyed by stride index.
///
/// Merging two sets is *map union* — checked for compatibility but
/// order-free — so any parenthesization and any argument order over the
/// same shards yields the same set, and the canonical fold in
/// [`ShardSet::finalize`] then makes the numeric output bit-identical
/// too.
#[derive(Default)]
pub struct ShardSet {
    shards: BTreeMap<usize, ShardPiece>,
}

impl ShardSet {
    pub fn new() -> ShardSet {
        ShardSet::default()
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// The declared shard count `k`, once at least one shard is present.
    pub fn declared_count(&self) -> Option<usize> {
        self.shards.values().next().map(|p| p.count)
    }

    /// Admit one shard, checking it against the shards already present.
    /// Every violation is a typed [`MergeError`]; nothing panics.
    pub fn insert(&mut self, piece: ShardPiece) -> std::result::Result<(), MergeError> {
        if piece.count == 0 || piece.index >= piece.count {
            return Err(MergeError::IndexOutOfRange { index: piece.index, count: piece.count });
        }
        if let Some(anchor) = self.shards.values().next() {
            if anchor.count != piece.count {
                return Err(MergeError::ShardCountMismatch {
                    left: anchor.count,
                    right: piece.count,
                });
            }
            if anchor.dim() != piece.dim() {
                return Err(MergeError::DimMismatch { left: anchor.dim(), right: piece.dim() });
            }
            if anchor.n_classes() != piece.n_classes() {
                return Err(MergeError::ClassMismatch {
                    left: anchor.n_classes(),
                    right: piece.n_classes(),
                });
            }
            if anchor.resume.eps.to_bits() != piece.resume.eps.to_bits() {
                return Err(MergeError::EpsMismatch {
                    left: anchor.resume.eps,
                    right: piece.resume.eps,
                });
            }
            if anchor.basis != piece.basis {
                return Err(MergeError::BasisMismatch { left: anchor.basis, right: piece.basis });
            }
        }
        if self.shards.contains_key(&piece.index) {
            return Err(MergeError::DuplicateShard { index: piece.index });
        }
        crate::obs::counter("akda_shard_pieces_total").inc();
        self.shards.insert(piece.index, piece);
        Ok(())
    }

    /// Union with another set (pairwise-merge step of a parallel
    /// reduction tree). Associative and commutative: the result holds
    /// exactly the shards of both sides, whatever the call tree looked
    /// like.
    pub fn merge(&mut self, other: ShardSet) -> std::result::Result<(), MergeError> {
        for (_, piece) in other.shards {
            self.insert(piece)?;
        }
        crate::obs::counter("akda_shard_merges_total").inc();
        Ok(())
    }

    /// Fold the complete set into merged training state, in ascending
    /// stride-index order — the canonical reduction that makes every
    /// merge tree bit-identical. Requires all `k` shards; a single-shard
    /// set passes its aggregates through untouched (the `k = 1 ≡
    /// unsharded` guarantee).
    ///
    /// `reservoir_cap` bounds the union reservoir (the merged model's
    /// resume/SVM sample), matching the unsharded train's cap.
    pub fn finalize(self, reservoir_cap: usize) -> Result<MergedTrain> {
        let count = match self.declared_count() {
            Some(c) => c,
            None => return Err(MergeError::Empty.into()),
        };
        if self.shards.len() != count {
            return Err(MergeError::Incomplete { have: self.shards.len(), want: count }.into());
        }
        let mut it = self.shards.into_values();
        let first = it.next().expect("non-empty by the count check");
        let (map, block_rows, eps, meta) =
            (first.map, first.block_rows, first.resume.eps, first.meta);
        let m = first.resume.gram.rows();
        let c = first.resume.class_sums.cols();
        let mut gram = first.resume.gram;
        let mut class_sums = first.resume.class_sums;
        let mut counts = first.resume.counts;
        let mut reservoir = LabeledReservoir::from_parts(
            &first.resume.reservoir,
            &first.resume.reservoir_labels,
            first.resume.seen,
            first.resume.reservoir.rows().max(1),
            shard_seed(MERGE_RESERVOIR_SEED, 0, count),
        )?;
        let mut rows_total = 0usize;
        for (step, piece) in it.enumerate() {
            gram.add_assign(&piece.resume.gram);
            class_sums.add_assign(&piece.resume.class_sums);
            for (a, b) in counts.iter_mut().zip(&piece.resume.counts) {
                *a += b;
            }
            let other = LabeledReservoir::from_parts(
                &piece.resume.reservoir,
                &piece.resume.reservoir_labels,
                piece.resume.seen,
                piece.resume.reservoir.rows().max(1),
                shard_seed(MERGE_RESERVOIR_SEED, step + 1, count),
            )?;
            reservoir = reservoir.merge(
                &other,
                reservoir_cap,
                shard_seed(MERGE_RESERVOIR_SEED ^ 0x5851_F42D, step + 1, count),
            )?;
        }
        for &n in &counts {
            rows_total += n;
        }
        let stats = StreamStats {
            rows: rows_total,
            m,
            n_classes: c,
            n_features: if reservoir.is_empty() {
                0
            } else {
                reservoir.snapshot().map(|(x, _)| x.cols()).unwrap_or(0)
            },
            ..StreamStats::default()
        };
        crate::obs::gauge("akda_shard_finalized_rows").set_max(rows_total as f64);
        Ok(MergedTrain {
            map,
            aggregates: StreamAggregates { gram, class_sums, counts, stats },
            reservoir,
            eps,
            block_rows,
            count,
            meta,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::RffMap;
    use crate::kernels::Kernel;
    use crate::linalg::Mat;

    fn toy_map(seed: u64) -> Arc<dyn FeatureMap> {
        Arc::new(RffMap::fit(3, Kernel::Rbf { rho: 0.5 }, 8, seed).unwrap())
    }

    fn toy_piece(map: &Arc<dyn FeatureMap>, index: usize, count: usize) -> ShardPiece {
        let m = map.dim();
        let resume = ApproxResume {
            gram: Mat::from_fn(m, m, |r, c| (r * m + c + index) as f64 * 0.25),
            class_sums: Mat::from_fn(m, 2, |r, c| (r + c + index) as f64 * 0.5),
            counts: vec![3 + index, 4],
            reservoir: Mat::from_fn(4, 3, |r, c| (index * 12 + r * 3 + c) as f64),
            reservoir_labels: vec![0, 1, 0, 1],
            seen: 7 + index,
            eps: 1e-3,
        };
        ShardPiece {
            index,
            count,
            basis: basis_fingerprint(map.as_ref()).unwrap(),
            block_rows: 256,
            map: map.clone(),
            resume,
            meta: BTreeMap::from([("dataset".to_string(), "toy".to_string())]),
        }
    }

    #[test]
    fn shard_artifacts_round_trip() {
        let map = toy_map(1);
        let piece = toy_piece(&map, 1, 3);
        let art = encode_shard(&piece).unwrap();
        assert!(is_shard(&art));
        let art = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        let back = decode_shard(&art).unwrap();
        assert_eq!((back.index, back.count, back.block_rows), (1, 3, 256));
        assert_eq!(back.basis, piece.basis);
        assert_eq!(back.resume.gram, piece.resume.gram);
        assert_eq!(back.resume.class_sums, piece.resume.class_sums);
        assert_eq!(back.resume.counts, piece.resume.counts);
        assert_eq!(back.resume.reservoir, piece.resume.reservoir);
        assert_eq!(back.resume.seen, piece.resume.seen);
        assert_eq!(back.meta.get("dataset").map(String::as_str), Some("toy"));
    }

    #[test]
    fn tampered_basis_is_rejected_at_decode() {
        let map = toy_map(2);
        let piece = toy_piece(&map, 0, 2);
        let mut art = encode_shard(&piece).unwrap();
        art.set_meta(SHARD_BASIS_KEY, format!("{:016x}", piece.basis ^ 1));
        assert!(decode_shard(&art).is_err());
    }

    #[test]
    fn incompatible_shards_fail_with_typed_errors() {
        let map = toy_map(3);
        let mut set = ShardSet::new();
        set.insert(toy_piece(&map, 0, 2)).unwrap();
        // duplicate index
        match set.insert(toy_piece(&map, 0, 2)) {
            Err(MergeError::DuplicateShard { index: 0 }) => {}
            other => panic!("want DuplicateShard, got {other:?}"),
        }
        // k mismatch
        match set.insert(toy_piece(&map, 1, 3)) {
            Err(MergeError::ShardCountMismatch { left: 2, right: 3 }) => {}
            other => panic!("want ShardCountMismatch, got {other:?}"),
        }
        // eps mismatch
        let mut off_eps = toy_piece(&map, 1, 2);
        off_eps.resume.eps = 2e-3;
        match set.insert(off_eps) {
            Err(MergeError::EpsMismatch { .. }) => {}
            other => panic!("want EpsMismatch, got {other:?}"),
        }
        // basis mismatch (a different map)
        let other_map = toy_map(99);
        match set.insert(toy_piece(&other_map, 1, 2)) {
            Err(MergeError::BasisMismatch { .. }) => {}
            other => panic!("want BasisMismatch, got {other:?}"),
        }
        // finalize of an incomplete set
        match set.finalize(64).unwrap_err().downcast::<MergeError>() {
            Ok(MergeError::Incomplete { have: 1, want: 2 }) => {}
            other => panic!("want Incomplete, got {other:?}"),
        }
        // empty set
        match ShardSet::new().finalize(64).unwrap_err().downcast::<MergeError>() {
            Ok(MergeError::Empty) => {}
            other => panic!("want Empty, got {other:?}"),
        }
    }

    #[test]
    fn finalize_is_merge_tree_invariant_bit_for_bit() {
        let map = toy_map(4);
        let k = 4;
        let pieces = || (0..k).map(|i| toy_piece(&map, i, k));
        // left fold: ((0 ∪ 1) ∪ 2) ∪ 3
        let mut left = ShardSet::new();
        for p in pieces() {
            left.insert(p).unwrap();
        }
        // balanced tree in scrambled order: (3 ∪ 1) ∪ (2 ∪ 0)
        let all: Vec<ShardPiece> = pieces().collect();
        let mut t1 = ShardSet::new();
        let mut t2 = ShardSet::new();
        let mut rest = ShardSet::new();
        for (slot, p) in all.into_iter().enumerate() {
            match slot {
                3 | 1 => t1.insert(p).unwrap(),
                _ => t2.insert(p).unwrap(),
            }
        }
        rest.merge(t1).unwrap();
        rest.merge(t2).unwrap();
        let a = left.finalize(6).unwrap();
        let b = rest.finalize(6).unwrap();
        assert!(a.aggregates.gram.sub(&b.aggregates.gram).max_abs() == 0.0);
        assert!(a.aggregates.class_sums.sub(&b.aggregates.class_sums).max_abs() == 0.0);
        assert_eq!(a.aggregates.counts, b.aggregates.counts);
        let (ax, al) = a.reservoir.snapshot().unwrap();
        let (bx, bl) = b.reservoir.snapshot().unwrap();
        assert!(ax.sub(&bx).max_abs() == 0.0, "reservoir union must be tree-invariant");
        assert_eq!(al, bl);
        assert_eq!(a.reservoir.seen(), b.reservoir.seen());
    }

    #[test]
    fn single_shard_finalize_is_the_identity() {
        let map = toy_map(5);
        let piece = toy_piece(&map, 0, 1);
        let (g, s, c) =
            (piece.resume.gram.clone(), piece.resume.class_sums.clone(), piece.resume.counts.clone());
        let (rx, rl, seen) =
            (piece.resume.reservoir.clone(), piece.resume.reservoir_labels.clone(), piece.resume.seen);
        let mut set = ShardSet::new();
        set.insert(piece).unwrap();
        let merged = set.finalize(512).unwrap();
        assert!(merged.aggregates.gram.sub(&g).max_abs() == 0.0);
        assert!(merged.aggregates.class_sums.sub(&s).max_abs() == 0.0);
        assert_eq!(merged.aggregates.counts, c);
        let (mx, ml) = merged.reservoir.snapshot().unwrap();
        assert!(mx.sub(&rx).max_abs() == 0.0, "k=1 must not touch the reservoir");
        assert_eq!(ml, rl);
        assert_eq!(merged.reservoir.seen(), seen);
    }
}
