//! `akda update` — grow a published model with new observations and
//! republish it, with **zero full refits** (the paper's Sec. 7 recursive
//! learning, run through the registry).
//!
//! Two update engines, dispatched on the artifact's resume kind
//! ([`codec::ResumeState`]):
//!
//! * **Exact** (`akda`-trained kernel expansions): decode the persisted
//!   Cholesky factor of K + εI, extend it by B bordered rows in O(N²·B)
//!   (`da::incremental::IncrementalAkda::extend` — the factorization
//!   itself is never redone), rebuild Θ from the updated class counts,
//!   and re-solve K Ψ = Θ through the grown factor. The republished model
//!   matches a from-scratch fit on the concatenated data to ≤1e-10 in
//!   projected scores (`tests/continual.rs` pins it).
//! * **Approximate** (`akda-nystrom` / `akda-rff`, dense or streamed):
//!   continue the persisted m×m Gram accumulator G = ΦᵀΦ and the m×C
//!   class sums over the new rows (`linalg::accumulate_tn` — bit-for-bit
//!   the same aggregates a from-scratch pass over the concatenated stream
//!   would produce), then re-solve the m×m system. With
//!   [`UpdateOptions::refresh_landmarks`], the Nyström landmarks first
//!   track the drift: the new data is reservoir-sampled
//!   (`data::stream::reservoir_sample`), pooled with the persisted
//!   labeled history reservoir, and k-means is re-run warm-started from
//!   the current landmarks (`cluster::kmeans::kmeans_warm`); the
//!   aggregates are then re-estimated in the refreshed feature basis from
//!   the history reservoir (scaled per class), since the old basis's
//!   sums no longer apply.
//!
//! Either way the one-vs-rest LSVM bank is retrained in the updated
//! discriminant subspace (exact: on the full grown training set;
//! approximate: on the labeled reservoir — a bounded uniform sample of
//! the entire history including the new rows), and a fresh artifact with
//! refreshed resume sections is returned for the registry to publish as
//! the next version. A `serve --model NAME --watch` service hot-swaps it
//! in without dropping a request (`model::registry::HotReloader`).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::artifact::ModelArtifact;
use super::codec::{self, ApproxResume, ExactResume, ResumeState};
use super::registry::{ModelManifest, ModelRegistry, ModelVersion};
use crate::approx::{FeatureMap, NystromMap};
use crate::cluster::kmeans::kmeans_warm;
use crate::coordinator::DetectorBank;
use crate::da::akda_approx::ApproxProjection;
use crate::da::akda_stream::{multiclass_rhs, BlockedProjection, MAX_STREAM_CLASSES};
use crate::da::incremental::IncrementalAkda;
use crate::da::{KernelProjection, Projection};
use crate::data::stream::{
    reservoir_sample, BlockSource, LabeledReservoir, MemBlockSource, DEFAULT_BLOCK_ROWS,
};
use crate::linalg::{accumulate_tn, chol, Mat};
use crate::svm::{LinearSvm, LinearSvmConfig};
use crate::util::rng::derive_seed;

/// Default labeled-reservoir budget persisted with approximate models —
/// bounds the resume sections to cap×F floats regardless of how much data
/// ever streamed through.
pub const DEFAULT_RESERVOIR_CAP: usize = 512;

/// Default seed for reservoir continuation / refresh sampling.
pub const DEFAULT_UPDATE_SEED: u64 = 29;

/// Stream tag for the landmark-refresh sample of the NEW data — a named
/// sub-stream of [`UpdateOptions::seed`] derived through the splitmix64
/// finalizer (`util::rng::derive_seed`), so it is decorrelated from the
/// history-reservoir stream that uses `seed` directly. The old
/// `seed ^ 0x9E37` derivation only flipped low bits: two structured base
/// seeds could land on overlapping RNG streams, the exact failure mode
/// the sharded-training seeds (`util::rng::shard_seed`) must avoid.
pub const REFRESH_SAMPLE_STREAM: u64 = 1;

/// Knobs for [`apply_update`].
#[derive(Debug, Clone, Copy)]
pub struct UpdateOptions {
    /// Re-run warm-started k-means so the Nyström landmarks track the
    /// drift (Nyström-approximate models only; rejected for RFF, whose
    /// map is data-independent, and for exact models, which have no
    /// landmarks).
    pub refresh_landmarks: bool,
    /// Lloyd iterations for the warm restart.
    pub kmeans_iters: usize,
    /// Seed for the reservoir continuation and refresh sampling.
    pub seed: u64,
    /// Labeled-reservoir budget carried in the republished resume state.
    pub reservoir_cap: usize,
}

impl Default for UpdateOptions {
    fn default() -> Self {
        UpdateOptions {
            refresh_landmarks: false,
            kmeans_iters: 10,
            seed: DEFAULT_UPDATE_SEED,
            reservoir_cap: DEFAULT_RESERVOIR_CAP,
        }
    }
}

/// What an update did — the numbers `akda update` prints. The
/// `full_refactorizations` field is structural documentation: neither
/// engine has a refactorization path, so it is always 0.
#[derive(Debug, Clone, Copy)]
pub struct UpdateReport {
    /// `exact-bordered`, `approx-accumulate`, or `approx-refresh`.
    pub kind: &'static str,
    /// Rows appended by this update.
    pub appended: usize,
    /// Training rows the updated model now represents.
    pub total_rows: usize,
    pub n_classes: usize,
    /// Bordered Cholesky row/column growths performed (exact engine).
    pub bordered_growths: usize,
    /// Always 0 — the update engines cannot refactorize.
    pub full_refactorizations: usize,
    /// Whether the Nyström landmarks were warm-refreshed.
    pub landmarks_refreshed: bool,
}

/// Grow the trained state inside `artifact` with the labelled rows
/// `(x_new, y_new)` and return the updated servable bank, a fresh
/// artifact (bank + refreshed resume sections) ready to publish, and a
/// report of the work done.
pub fn apply_update(
    artifact: &ModelArtifact,
    x_new: &Mat,
    y_new: &[usize],
    opts: &UpdateOptions,
) -> Result<(DetectorBank, ModelArtifact, UpdateReport)> {
    anyhow::ensure!(x_new.rows() > 0, "update needs at least one new observation");
    anyhow::ensure!(
        x_new.rows() == y_new.len(),
        "update mismatch: {} rows vs {} labels",
        x_new.rows(),
        y_new.len()
    );
    let input_dim = codec::input_dim(artifact)?;
    anyhow::ensure!(
        x_new.cols() == input_dim,
        "update data has {} features but the model expects {}",
        x_new.cols(),
        input_dim
    );
    for &l in y_new {
        anyhow::ensure!(
            l < MAX_STREAM_CLASSES,
            "label {l} exceeds the class cap {MAX_STREAM_CLASSES} (corrupt row?)"
        );
    }
    let resume = codec::decode_resume(artifact)?.with_context(|| {
        "artifact carries no resume state — it can be served but not grown; \
         republish it with `akda train` (which embeds resume sections for \
         akda / akda-nystrom / akda-rff models) to enable `akda update`"
            .to_string()
    })?;
    match resume {
        ResumeState::Exact(r) => update_exact(artifact, r, x_new, y_new, opts),
        ResumeState::Approx(r) => update_approx(artifact, r, x_new, y_new, opts),
    }
}

/// What [`update_registry_model`] did: the version chain, the engine
/// report, and the post-update evaluation (when one could run).
#[derive(Debug)]
pub struct PublishedUpdate {
    /// The version the update started from (`updated_from` provenance).
    pub from: ModelVersion,
    /// The freshly published version.
    pub published: ModelVersion,
    pub report: UpdateReport,
    /// `(accuracy, MAP)` on the model's held-out split — `None` when the
    /// manifest names a dataset outside the registry (the manifest then
    /// stores the `0.0/0.0` "no evaluation" convention).
    pub eval: Option<(f64, f64)>,
    /// Wall-clock seconds of the update engine (excludes evaluation).
    pub update_s: f64,
}

/// The whole `akda update` lifecycle as one library call: resolve and
/// checksum-verify `spec`, grow the model with `(x_new, y_new)` via
/// [`apply_update`], re-evaluate on the held-out split of the dataset the
/// manifest names (when it is a registry dataset with matching feature
/// width), and publish the result as the next version with
/// `updated_from` provenance. Shared verbatim by `akda update` and the
/// drop-directory auto-update daemon (`coordinator::fleet::UpdateDaemon`),
/// so a daemon-triggered update can never drift in behavior from a manual
/// one.
pub fn update_registry_model(
    registry: &ModelRegistry,
    spec: &str,
    x_new: &Mat,
    y_new: &[usize],
    opts: &UpdateOptions,
) -> Result<PublishedUpdate> {
    let (entry, artifact) = registry.load_artifact(spec)?;
    crate::obs::flight::reset();
    // `health.backend` for update-produced versions: the bordered /
    // accumulator growth paths don't pass through the full-train entry
    // points that normally record it
    crate::obs::flight::record(
        "backend",
        crate::linalg::backend::global_kind().id() as f64,
    );
    let t0 = std::time::Instant::now();
    let (bank, new_artifact, report) = apply_update(&artifact, x_new, y_new, opts)?;
    let update_s = t0.elapsed().as_secs_f64();
    crate::obs::flight::record("phase_update_s", update_s);

    // re-evaluate on the held-out split the model was trained against
    // (possible whenever the manifest names a registry dataset)
    let mf = &entry.manifest;
    let eval = crate::data::by_name(&mf.dataset)
        .and_then(|dspec| crate::data::Condition::parse(&mf.condition).map(|c| dspec.split(c)))
        .filter(|split| split.x_test.cols() == x_new.cols())
        .map(|split| crate::coordinator::service::eval_bank(&bank, &split));
    let (accuracy, map) = eval.unwrap_or((0.0, 0.0));

    let manifest = ModelManifest {
        method: mf.method.clone(),
        dataset: mf.dataset.clone(),
        condition: mf.condition.clone(),
        rho: mf.rho,
        c: mf.c,
        h: mf.h,
        m: mf.m,
        stream_block: mf.stream_block,
        n_classes: report.n_classes,
        input_dim: mf.input_dim,
        train_s: update_s,
        // the backend THIS update ran under (not the parent version's):
        // it explains the `train_s` above; scores are backend-invariant
        backend: crate::linalg::backend::global_kind().name().to_string(),
        map,
        accuracy,
        updated_from: Some(entry.spec()),
        health: crate::obs::flight::snapshot(),
        ..Default::default()
    };
    let published = registry.publish(&entry.name, &new_artifact, &manifest)?;
    Ok(PublishedUpdate { from: entry, published, report, eval, update_s })
}

/// Train the one-vs-rest LSVM bank over projected rows `z` — the single
/// relabel + `LinearSvm::train` + `class<i>` naming loop shared by `akda
/// train` (`fit_detector_bank`), both update engines, and the continual
/// tests, so the bank an update retrains can never drift in config from
/// the bank training built.
pub fn train_svm_bank(z: &Mat, labels: &[usize], n_classes: usize) -> Vec<(String, LinearSvm)> {
    (0..n_classes)
        .map(|cls| {
            let y: Vec<f64> = labels
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(z, &y, LinearSvmConfig::default()))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Exact engine: bordered-Cholesky growth
// ---------------------------------------------------------------------------

fn update_exact(
    artifact: &ModelArtifact,
    r: ExactResume,
    x_new: &Mat,
    y_new: &[usize],
    opts: &UpdateOptions,
) -> Result<(DetectorBank, ModelArtifact, UpdateReport)> {
    // the exact engine has no sampling knobs: reservoir_cap is unused (the
    // full training set is retained) and a landmark refresh is meaningless
    anyhow::ensure!(
        !opts.refresh_landmarks,
        "--refresh-landmarks applies to Nystrom-approximate models only; \
         this is an exact kernel model (no landmarks)"
    );
    let proj = codec::decode_projection(artifact)?;
    let kp = proj
        .as_any()
        .downcast_ref::<KernelProjection>()
        .context("exact resume state requires a kernel-expansion projection")?;
    anyhow::ensure!(
        kp.center_against.is_none(),
        "centered kernel projections (GDA family) cannot be grown by bordered rows"
    );
    let mut inc = IncrementalAkda::from_parts(
        kp.kernel,
        r.eps,
        r.n_classes,
        kp.x_train.clone(),
        r.labels,
        r.chol_l,
    )?;
    inc.extend(x_new, y_new)?;
    crate::obs::flight::record("eps", inc.eps());
    crate::da::akda::record_pivots(inc.chol_l());

    // Θ rebuilt from the updated counts, Ψ re-solved through the grown
    // factor — no refactorization anywhere on this path.
    let projection = inc.to_projection()?;
    let z = projection.project(inc.x_train());
    let svms = train_svm_bank(&z, inc.labels(), inc.n_classes());
    let bank = DetectorBank { projection: Box::new(projection), svms };

    let method = artifact.meta_str("method").unwrap_or("akda").to_string();
    let mut new_art = codec::encode_bank(&bank, &method)?;
    codec::encode_resume(
        &mut new_art,
        &ResumeState::Exact(ExactResume {
            chol_l: inc.chol_l().clone(),
            labels: inc.labels().to_vec(),
            eps: inc.eps(),
            n_classes: inc.n_classes(),
        }),
    )?;
    let report = UpdateReport {
        kind: "exact-bordered",
        appended: y_new.len(),
        total_rows: inc.len(),
        n_classes: inc.n_classes(),
        bordered_growths: inc.growths(),
        full_refactorizations: 0,
        landmarks_refreshed: false,
    };
    Ok((bank, new_art, report))
}

// ---------------------------------------------------------------------------
// Approximate engine: accumulator continuation / landmark refresh
// ---------------------------------------------------------------------------

/// Stack two row-compatible matrices vertically.
fn vstack(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "vstack width mismatch");
    let mut out = Mat::zeros(a.rows() + b.rows(), a.cols());
    for r in 0..a.rows() {
        out.row_mut(r).copy_from_slice(a.row(r));
    }
    for r in 0..b.rows() {
        out.row_mut(a.rows() + r).copy_from_slice(b.row(r));
    }
    out
}

/// Per-class scaled aggregate estimates from the labeled history
/// reservoir, in a (possibly refreshed) feature basis: G ≈ (seen/r)·ΦᵣᵀΦᵣ
/// and S[:,c] ≈ (N_c/r_c)·Σ_{reservoir rows of class c} φ(x).
fn estimate_aggregates(
    map: &dyn FeatureMap,
    rx: &Mat,
    ry: &[usize],
    counts: &[usize],
    seen: usize,
) -> Result<(Mat, Mat)> {
    let phi = map.transform(rx);
    let m = phi.cols();
    let c = counts.len();
    let mut per_class = vec![0usize; c];
    for &l in ry {
        anyhow::ensure!(l < c, "reservoir label {l} out of range 0..{c}");
        per_class[l] += 1;
    }
    for (cls, (&have, &want)) in per_class.iter().zip(counts).enumerate() {
        anyhow::ensure!(
            have > 0 || want == 0,
            "the history reservoir lost every row of class {cls} — raise the \
             reservoir cap (--reservoir) before refreshing landmarks"
        );
    }
    let scale_g = seen as f64 / rx.rows() as f64;
    let gram = phi.matmul_tn(&phi).scale(scale_g);
    let mut sums = Mat::zeros(m, c);
    for r in 0..phi.rows() {
        let cls = ry[r];
        for i in 0..m {
            sums[(i, cls)] += phi[(r, i)];
        }
    }
    for cls in 0..c {
        if per_class[cls] > 0 {
            let s = counts[cls] as f64 / per_class[cls] as f64;
            for i in 0..m {
                sums[(i, cls)] *= s;
            }
        }
    }
    Ok((gram, sums))
}

fn update_approx(
    artifact: &ModelArtifact,
    r: ApproxResume,
    x_new: &Mat,
    y_new: &[usize],
    opts: &UpdateOptions,
) -> Result<(DetectorBank, ModelArtifact, UpdateReport)> {
    let proj = codec::decode_projection(artifact)?;
    let any = proj.as_any();
    let (map, block_rows): (Arc<dyn FeatureMap>, Option<usize>) =
        if let Some(p) = any.downcast_ref::<ApproxProjection>() {
            (p.map.clone(), None)
        } else if let Some(p) = any.downcast_ref::<BlockedProjection>() {
            (p.map.clone(), Some(p.block_rows))
        } else {
            bail!("approx resume state requires an approx/blocked projection")
        };

    // continue the labeled history reservoir over the new rows
    let mut reservoir = LabeledReservoir::from_parts(
        &r.reservoir,
        &r.reservoir_labels,
        r.seen,
        opts.reservoir_cap,
        opts.seed,
    )?;
    {
        let mut src = MemBlockSource::new(x_new, y_new, DEFAULT_BLOCK_ROWS);
        src.reset()?;
        while let Some(block) = src.next_block()? {
            reservoir.absorb(&block);
        }
    }

    // exact per-class counts (grow C if the update introduces new classes)
    let mut counts = r.counts.clone();
    for &l in y_new {
        if l >= counts.len() {
            counts.resize(l + 1, 0);
        }
        counts[l] += 1;
    }
    anyhow::ensure!(
        counts.len() >= 2 && counts.iter().all(|&c| c > 0),
        "updated class counts must cover every label in 0..C (counts {counts:?})"
    );

    let (map, gram, class_sums, refreshed): (Arc<dyn FeatureMap>, Mat, Mat, bool) =
        if opts.refresh_landmarks {
            let ny = map
                .as_any()
                .downcast_ref::<NystromMap>()
                .context("--refresh-landmarks applies to Nyström maps only (the RFF map is data-independent)")?;
            // Sec. 7 drift tracking: reservoir-sample the NEW data, pool it
            // with the labeled history reservoir, and warm-start k-means
            // from the current landmarks.
            let cap = (4 * ny.landmarks.rows()).max(256);
            let mut src = MemBlockSource::new(x_new, y_new, DEFAULT_BLOCK_ROWS);
            let new_sample =
                reservoir_sample(&mut src, cap, derive_seed(opts.seed, REFRESH_SAMPLE_STREAM))?;
            let (hist_x, hist_y) = reservoir.snapshot()?;
            let pool = vstack(&hist_x, &new_sample);
            let centroids = kmeans_warm(&pool, &ny.landmarks, opts.kmeans_iters).centroids;
            let new_map: Arc<dyn FeatureMap> =
                Arc::new(NystromMap::from_landmarks(centroids, ny.kernel)?);
            // the persisted aggregates live in the OLD feature basis —
            // re-estimate them in the refreshed basis from the history
            // reservoir (uniform over everything ever seen)
            let (g, s) =
                estimate_aggregates(new_map.as_ref(), &hist_x, &hist_y, &counts, reservoir.seen())?;
            (new_map, g, s, true)
        } else {
            // same map ⇒ the persisted aggregates continue exactly: G via
            // the order-preserving accumulator (bit-for-bit what a single
            // pass over the concatenated stream would produce), S via the
            // same per-row sequential additions.
            let m = map.dim();
            anyhow::ensure!(
                r.gram.rows() == m,
                "resume gram is {}x{} but the map has dimension {m}",
                r.gram.rows(),
                r.gram.cols()
            );
            let mut g = r.gram.clone();
            let mut sums: Vec<Vec<f64>> = (0..counts.len())
                .map(|c| {
                    if c < r.class_sums.cols() {
                        (0..m).map(|i| r.class_sums[(i, c)]).collect()
                    } else {
                        vec![0.0; m]
                    }
                })
                .collect();
            let mut src = MemBlockSource::new(x_new, y_new, DEFAULT_BLOCK_ROWS);
            src.reset()?;
            while let Some(block) = src.next_block()? {
                let phi = map.transform(&block.x);
                accumulate_tn(&mut g, &phi, &phi);
                for (row, &l) in block.labels.iter().enumerate() {
                    for (s, &v) in sums[l].iter_mut().zip(phi.row(row)) {
                        *s += v;
                    }
                }
            }
            let s = Mat::from_fn(m, counts.len(), |i, j| sums[j][i]);
            (map, g, s, false)
        };

    // re-solve the m×m system (the only factorization in this engine —
    // m ≪ N by construction, this is the cheap part)
    let mut sys = gram.clone();
    sys.add_ridge(r.eps);
    crate::obs::flight::record("eps", r.eps);
    let chol_start = std::time::Instant::now();
    let chol_l = chol::cholesky(&sys, chol::DEFAULT_BLOCK)
        .map_err(|e| anyhow::anyhow!("update m×m Cholesky failed: {e}"))?;
    crate::obs::flight::record("phase_chol_s", chol_start.elapsed().as_secs_f64());
    crate::da::akda::record_pivots(&chol_l);
    let rhs = multiclass_rhs(&class_sums, &counts);
    let y = chol::solve_lower(&chol_l, &rhs);
    let w = chol::solve_upper_from_lower(&chol_l, &y);

    let projection: Box<dyn Projection> = match block_rows {
        Some(b) => Box::new(BlockedProjection { map: map.clone(), w: w.clone(), block_rows: b }),
        None => Box::new(ApproxProjection { map: map.clone(), w: w.clone() }),
    };
    // SVM bank from the labeled reservoir: a bounded uniform sample of the
    // full history, new rows included — the full training set is gone.
    // Every populated class must have survived the reservoir's Algorithm-R
    // replacement, or its one-vs-rest SVM would train with zero positive
    // examples and silently always score negative (the refresh arm gets
    // the same guard from `estimate_aggregates`).
    let (rx, ry) = reservoir.snapshot()?;
    let mut in_reservoir = vec![0usize; counts.len()];
    for &l in &ry {
        anyhow::ensure!(l < counts.len(), "reservoir label {l} out of range 0..{}", counts.len());
        in_reservoir[l] += 1;
    }
    for (cls, (&have, &want)) in in_reservoir.iter().zip(&counts).enumerate() {
        anyhow::ensure!(
            have > 0 || want == 0,
            "the history reservoir lost every row of class {cls} — raise the \
             reservoir cap (--reservoir) and re-run the update"
        );
    }
    let z = projection.project(&rx);
    let svms = train_svm_bank(&z, &ry, counts.len());
    let bank = DetectorBank { projection, svms };

    let method = artifact.meta_str("method").unwrap_or("akda-nystrom").to_string();
    let mut new_art = codec::encode_bank(&bank, &method)?;
    let total_rows: usize = counts.iter().sum();
    codec::encode_resume(
        &mut new_art,
        &ResumeState::Approx(ApproxResume {
            gram,
            class_sums,
            counts: counts.clone(),
            reservoir: rx,
            reservoir_labels: ry,
            seen: reservoir.seen(),
            eps: r.eps,
        }),
    )?;
    let report = UpdateReport {
        kind: if refreshed { "approx-refresh" } else { "approx-accumulate" },
        appended: y_new.len(),
        total_rows,
        n_classes: counts.len(),
        bordered_growths: 0,
        full_refactorizations: 0,
        landmarks_refreshed: refreshed,
    };
    Ok((bank, new_art, report))
}

// ---------------------------------------------------------------------------
// Resume-state builders (used by `akda train` to embed the sections)
// ---------------------------------------------------------------------------

/// Approximate resume state from a dense training pass: the N×m feature
/// matrix Φ, its pre-ridge Gram G = ΦᵀΦ (already computed — and cached —
/// by `AkdaApprox::prepare`, so it is not recomputed here), the training
/// labels, and the raw training rows (for the labeled reservoir). The
/// aggregates are in the same row-sequential order as the tiled
/// accumulator, so a later [`apply_update`] continues them bit-for-bit.
pub fn approx_resume_from_phi(
    phi: &Mat,
    gram: &Mat,
    x_train: &Mat,
    labels: &[usize],
    n_classes: usize,
    eps: f64,
    cap: usize,
    seed: u64,
) -> Result<ApproxResume> {
    anyhow::ensure!(
        phi.rows() == labels.len() && x_train.rows() == labels.len(),
        "resume builder mismatch: {} features rows, {} data rows, {} labels",
        phi.rows(),
        x_train.rows(),
        labels.len()
    );
    let m = phi.cols();
    anyhow::ensure!(
        gram.shape() == (m, m),
        "resume builder mismatch: gram is {}x{} for m = {m}",
        gram.rows(),
        gram.cols()
    );
    let gram = gram.clone();
    let mut counts = vec![0usize; n_classes];
    let mut sums: Vec<Vec<f64>> = vec![vec![0.0; m]; n_classes];
    for r in 0..phi.rows() {
        let l = labels[r];
        anyhow::ensure!(l < n_classes, "label {l} out of range 0..{n_classes}");
        counts[l] += 1;
        for (s, &v) in sums[l].iter_mut().zip(phi.row(r)) {
            *s += v;
        }
    }
    let class_sums = Mat::from_fn(m, n_classes, |i, j| sums[j][i]);
    let mut src = MemBlockSource::new(x_train, labels, DEFAULT_BLOCK_ROWS);
    let (reservoir, reservoir_labels, seen) =
        crate::data::stream::reservoir_sample_labeled(&mut src, cap, seed)?;
    Ok(ApproxResume { gram, class_sums, counts, reservoir, reservoir_labels, seen, eps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::da::akda::Akda;
    use crate::da::akda_approx::AkdaApprox;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};
    use crate::kernels::Kernel;

    fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 5,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    fn exact_artifact(x: &Mat, labels: &[usize], c: usize) -> ModelArtifact {
        let akda = Akda::new(Kernel::Rbf { rho: 0.4 });
        let (proj, l) = akda.fit_with_factor(x, labels, c).unwrap();
        let z = proj.project(x);
        let svms = train_svm_bank(&z, labels, c);
        let bank = DetectorBank { projection: Box::new(proj), svms };
        let mut art = codec::encode_bank(&bank, "akda").unwrap();
        codec::encode_resume(
            &mut art,
            &ResumeState::Exact(ExactResume {
                chol_l: l,
                labels: labels.to_vec(),
                eps: akda.eps,
                n_classes: c,
            }),
        )
        .unwrap();
        art
    }

    #[test]
    fn exact_update_matches_from_scratch_fit() {
        let (x, labels) = toy(10, 3, 1);
        let (base_x, base_y) = (x.submatrix(0, 0, 18, x.cols()), &labels[..18]);
        let art = exact_artifact(&base_x, base_y, 3);
        let tail_x = x.submatrix(18, 0, x.rows() - 18, x.cols());
        let (bank, new_art, report) =
            apply_update(&art, &tail_x, &labels[18..], &UpdateOptions::default()).unwrap();
        assert_eq!(report.kind, "exact-bordered");
        assert_eq!(report.appended, 12);
        assert_eq!(report.bordered_growths, 12);
        assert_eq!(report.full_refactorizations, 0);
        // projected scores match a from-scratch fit on the concatenation
        use crate::da::DrMethod;
        let scratch = Akda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 3).unwrap();
        let (xt, _) = toy(6, 3, 9);
        let gap = bank.projection.project(&xt).sub(&scratch.project(&xt)).max_abs();
        assert!(gap < 1e-10, "update-vs-scratch projection gap {gap}");
        // the republished artifact still carries (grown) resume state
        match codec::decode_resume(&new_art).unwrap().unwrap() {
            ResumeState::Exact(r) => {
                assert_eq!(r.labels.len(), 30);
                assert_eq!(r.chol_l.shape(), (30, 30));
            }
            other => panic!("wrong resume kind {:?}", other.kind()),
        }
    }

    #[test]
    fn approx_update_continues_the_accumulator() {
        let (x, labels) = toy(12, 2, 2);
        let n0 = 16;
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 32);
        let base_x = x.submatrix(0, 0, n0, x.cols());
        let prep = cfg.prepare(&base_x).unwrap();
        let proj = prep.fit(&labels[..n0], 2).unwrap();
        let z = proj.project(&base_x);
        let svms = train_svm_bank(&z, &labels[..n0], 2);
        let bank = DetectorBank { projection: Box::new(proj), svms };
        let mut art = codec::encode_bank(&bank, "akda-rff").unwrap();
        let resume = approx_resume_from_phi(
            &prep.phi, prep.gram(), &base_x, &labels[..n0], 2, cfg.eps, 64, 3,
        )
        .unwrap();
        codec::encode_resume(&mut art, &ResumeState::Approx(resume)).unwrap();

        let tail_x = x.submatrix(n0, 0, x.rows() - n0, x.cols());
        let (bank2, _, report) =
            apply_update(&art, &tail_x, &labels[n0..], &UpdateOptions::default()).unwrap();
        assert_eq!(report.kind, "approx-accumulate");
        assert_eq!(report.total_rows, 24);
        // the continued solve equals a from-scratch streaming solve over
        // the concatenated data with the same (data-independent) map
        let mut src = MemBlockSource::new(&x, &labels, 7);
        let ps = crate::da::akda_stream::PreparedStream::accumulate(
            &cfg,
            bank2
                .projection
                .as_any()
                .downcast_ref::<ApproxProjection>()
                .unwrap()
                .map
                .clone(),
            &mut src,
        )
        .unwrap();
        let w_scratch = ps.solve_w_multiclass().unwrap();
        let w_cont = &bank2
            .projection
            .as_any()
            .downcast_ref::<ApproxProjection>()
            .unwrap()
            .w;
        assert!(
            w_cont.sub(&w_scratch).max_abs() == 0.0,
            "accumulator continuation must be bit-for-bit"
        );
    }

    #[test]
    fn refresh_rejects_rff_and_refreshes_nystrom() {
        let (x, labels) = toy(20, 2, 4);
        let n0 = 30;
        let base_x = x.submatrix(0, 0, n0, x.cols());
        let tail_x = x.submatrix(n0, 0, x.rows() - n0, x.cols());
        let opts = UpdateOptions { refresh_landmarks: true, ..Default::default() };

        // RFF: refusal (data-independent map)
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 16);
        let prep = cfg.prepare(&base_x).unwrap();
        let proj = prep.fit(&labels[..n0], 2).unwrap();
        let z = proj.project(&base_x);
        let svms = train_svm_bank(&z, &labels[..n0], 2);
        let bank = DetectorBank { projection: Box::new(proj), svms };
        let mut art = codec::encode_bank(&bank, "akda-rff").unwrap();
        let resume =
            approx_resume_from_phi(&prep.phi, prep.gram(), &base_x, &labels[..n0], 2, cfg.eps, 64, 5)
                .unwrap();
        codec::encode_resume(&mut art, &ResumeState::Approx(resume)).unwrap();
        assert!(apply_update(&art, &tail_x, &labels[n0..], &opts).is_err());

        // Nyström: landmarks move, model still separates
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, 8);
        let prep = cfg.prepare(&base_x).unwrap();
        let proj = prep.fit(&labels[..n0], 2).unwrap();
        let old_landmarks = proj
            .map
            .as_any()
            .downcast_ref::<NystromMap>()
            .unwrap()
            .landmarks
            .clone();
        let z = proj.project(&base_x);
        let svms = train_svm_bank(&z, &labels[..n0], 2);
        let bank = DetectorBank { projection: Box::new(proj), svms };
        let mut art = codec::encode_bank(&bank, "akda-nystrom").unwrap();
        let resume =
            approx_resume_from_phi(&prep.phi, prep.gram(), &base_x, &labels[..n0], 2, cfg.eps, 64, 5)
                .unwrap();
        codec::encode_resume(&mut art, &ResumeState::Approx(resume)).unwrap();
        let (bank2, _, report) = apply_update(&art, &tail_x, &labels[n0..], &opts).unwrap();
        assert_eq!(report.kind, "approx-refresh");
        assert!(report.landmarks_refreshed);
        let new_landmarks = &bank2
            .projection
            .as_any()
            .downcast_ref::<ApproxProjection>()
            .unwrap()
            .map
            .as_any()
            .downcast_ref::<NystromMap>()
            .unwrap()
            .landmarks;
        assert_eq!(new_landmarks.rows(), old_landmarks.rows());
        assert!(
            new_landmarks.sub(&old_landmarks).max_abs() > 0.0,
            "warm refresh should move at least one landmark"
        );
        // the refreshed bank still scores finitely
        assert!(bank2.score(&x).is_finite());
    }

    #[test]
    fn update_without_resume_state_is_rejected_with_guidance() {
        let (x, labels) = toy(10, 2, 6);
        use crate::da::DrMethod;
        let proj = Akda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        let svms = train_svm_bank(&z, &labels, 2);
        let bank = DetectorBank { projection: proj, svms };
        let art = codec::encode_bank(&bank, "akda").unwrap();
        let err = apply_update(&art, &x, &labels, &UpdateOptions::default())
            .expect_err("no resume state must be an error");
        assert!(format!("{err:#}").contains("resume"), "{err:#}");
    }
}
