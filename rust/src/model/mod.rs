//! Trained-model artifact subsystem: persist, version, and hot-serve AKDA
//! models without retraining.
//!
//! The paper makes *training* cheap (core-matrix NZEP + Cholesky instead
//! of simultaneous reduction), but a serving system also needs the result
//! of that training to be durable: a detector bank that took a training
//! pass to build should be loadable in milliseconds, rolled forward and
//! back by version, and replaceable under a live scoring service. This
//! module is that fourth layer — train → **publish → load** → serve:
//!
//! * [`artifact`] — the `.akda` on-disk format: a hand-rolled, versioned,
//!   checksummed binary container (magic, format version, string meta,
//!   named f64 tensor sections; per-section and whole-file FNV-1a 64
//!   checksums). No dependencies, bit-for-bit round-trips.
//! * [`codec`] — encode/decode between the trait objects the training
//!   paths produce (`Box<dyn Projection>`, the OvR `LinearSvm` bank) and
//!   artifacts, via the `Projection::as_any` / `FeatureMap::as_any`
//!   introspection hooks. Covers every servable state: exact kernel
//!   expansions (AKDA/AKSDA/KDA/GDA/SRKDA/KSDA, incl. PJRT-trained),
//!   linear projections (PCA/LDA), approximate W + Nyström/RFF maps, and
//!   the streaming `BlockedProjection`.
//! * [`registry`] — the models directory
//!   (`<dir>/<name>/<version>/{model.akda,MANIFEST}`): list/latest/
//!   resolve, atomic write-temp-then-rename publish, and an
//!   mtime/version-polling [`registry::HotReloader`] that swaps freshly
//!   published models into a running `ScoringService` through its
//!   [`coordinator::BankHandle`](crate::coordinator::BankHandle).
//!
//! * [`shard`] — distributed training by accumulator merge (L11):
//!   partial `.akda` shard artifacts (map + resume sections, fingerprinted
//!   landmark basis, no bank) and the [`shard::ShardSet`] merge algebra —
//!   set union with typed compatibility errors, plus a canonical
//!   ascending-stride fold so any merge tree is bit-identical. Feeds
//!   `akda train --shard i/k` → `akda merge`.
//! * [`update`] — the continual-learning engine (L5): `akda update`
//!   decodes a published artifact, grows it with new observations — a
//!   bordered-Cholesky extension for exact models
//!   (`da::incremental`), an accumulator continuation or warm
//!   landmark refresh for approximate ones — and returns the next
//!   version to publish, with zero full refits. `registry::prune`
//!   bounds the version history the loop produces.
//!
//! The CLI surface is `akda train` (fit → eval → publish), `akda models`
//! (list/inspect/diff/prune — prune auto-protects any version a live
//! serve process has marked with a [`registry::ServeMarker`] lease),
//! `akda serve --model NAME[@VERSION]` (load and serve with zero
//! training work; `--watch` hot-swaps new versions in), `akda update
//! NAME[@V] --data new.csv` (recursive learning → next version), and —
//! one layer up — `akda serve --fleet` / `akda daemon`
//! (`coordinator::fleet`), which serve every model here from one process
//! and apply drop-directory updates through the same
//! [`update::update_registry_model`] path. `tests/model_roundtrip.rs` pins the persistence
//! guarantee: for every servable method, a published-then-loaded model
//! scores the test set bit-for-bit identically to the freshly trained
//! one, and corrupt artifacts fail with checksum errors instead of
//! panics or silently wrong models. `tests/continual.rs` pins the
//! update guarantee: an incrementally grown model matches a from-scratch
//! fit on the concatenated data to ≤1e-10 in projected scores.

pub mod artifact;
pub mod codec;
pub mod registry;
pub mod shard;
pub mod update;

pub use artifact::ModelArtifact;
pub use codec::{decode_bank, encode_bank, ResumeState};
pub use registry::{
    HotReloader, ModelDiff, ModelManifest, ModelRegistry, ModelVersion, ServeMarker,
};
pub use shard::{decode_shard, encode_shard, MergedTrain, ShardPiece, ShardSet};
pub use update::{
    apply_update, update_registry_model, PublishedUpdate, UpdateOptions, UpdateReport,
};
