//! Directory-backed model registry: versioned publish, lookup, and
//! hot-reload of trained `.akda` artifacts.
//!
//! # On-disk layout
//!
//! ```text
//! <models-dir>/
//!   <name>/                 one directory per model name
//!     1/                    integer versions, monotonically increasing
//!       model.akda          the checksummed binary artifact
//!       MANIFEST            plain-text `key = value` metadata
//!     2/
//!       ...
//!     .tmp-<pid>-<nonce>/   in-flight publish staging (never read)
//! ```
//!
//! A publish stages the artifact + manifest into a hidden `.tmp-*`
//! directory and `rename`s it to the next version number — on POSIX a
//! same-filesystem rename is atomic, so readers either see a complete
//! version directory or none at all; a concurrent publisher losing the
//! rename race simply retries with the next number. Versions are
//! immutable once published.
//!
//! # Hot reload
//!
//! [`HotReloader`] polls a model's latest `(version, mtime)` pair on an
//! interval; when a newer version lands it decodes the artifact off the
//! serving thread and swaps it into the `ScoringService`'s
//! [`BankHandle`]. Swaps are rejected (with a logged reason) when the new
//! model's input dimensionality differs from what the running service
//! accepts, so a bad publish cannot wedge a live endpoint.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::artifact::{ModelArtifact, ARTIFACT_FILE};
use super::codec;
use crate::coordinator::{BankHandle, DetectorBank};

/// Manifest file name inside a version directory.
pub const MANIFEST_FILE: &str = "MANIFEST";

/// File-name prefix of the serve markers ([`ServeMarker`]) a serving
/// process drops inside `<root>/<name>/` so out-of-process GC
/// ([`ModelRegistry::prune`]) can see which versions are live.
pub const SERVE_MARKER_PREFIX: &str = ".served-";

/// Plain-text metadata published next to every artifact. Everything here
/// is informational (the binary artifact is self-contained); the manifest
/// exists so `akda models` and humans can inspect a registry with `cat`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelManifest {
    /// Model name (set by `publish`).
    pub name: String,
    /// Version number (set by `publish`).
    pub version: u32,
    /// Training method id (`akda`, `aksda`, `akda-nystrom`, ...).
    pub method: String,
    /// Registry dataset the model was trained on.
    pub dataset: String,
    /// Condition name (`10Ex` / `100Ex`).
    pub condition: String,
    /// Hyper-parameters of the final fit.
    pub rho: f64,
    pub c: f64,
    pub h: usize,
    pub m: usize,
    /// Streaming tile height, when trained out of core.
    pub stream_block: Option<usize>,
    pub n_classes: usize,
    pub input_dim: usize,
    /// Wall-clock training seconds (fit + SVM bank).
    pub train_s: f64,
    /// Linalg backend kind the training run selected (`scalar` /
    /// `blocked` / `parallel` / `auto`; see `linalg::backend`). Purely
    /// informational — backends are bit-for-bit equivalent, so this
    /// explains the `train_s` next to it, never the scores. Empty for
    /// versions published before the backend seam existed.
    pub backend: String,
    /// Train-time evaluation on the held-out test split. By convention
    /// `0.0` in BOTH fields means "no evaluation ran" (e.g. an `akda
    /// update` against a dataset not in the registry) — [`ModelRegistry::diff`]
    /// reports eval drift only when both sides carry a non-zero pair.
    pub map: f64,
    pub accuracy: f64,
    /// Publish time, seconds since the Unix epoch.
    pub created_unix: u64,
    /// For versions produced by `akda update`: the `name@version` spec the
    /// recursive update started from (provenance of the continual-learning
    /// chain).
    pub updated_from: Option<String>,
    /// Numerical-health facts captured by the training flight recorder
    /// (`obs::flight`) during the fit/update that produced this version:
    /// Cholesky pivot extremes, ε applied, NZEP eigenvalue extremes,
    /// per-phase durations. Serialized as `health.<key> = <value>`
    /// lines; `akda models --inspect` surfaces them and `models --diff`
    /// flags deltas, so a republish that degrades conditioning is
    /// visible before it serves.
    pub health: std::collections::BTreeMap<String, f64>,
}

impl ModelManifest {
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let mut kv = |k: &str, v: String| {
            s.push_str(k);
            s.push_str(" = ");
            s.push_str(&v);
            s.push('\n');
        };
        kv("name", self.name.clone());
        kv("version", self.version.to_string());
        kv("method", self.method.clone());
        kv("dataset", self.dataset.clone());
        kv("condition", self.condition.clone());
        kv("rho", self.rho.to_string());
        kv("c", self.c.to_string());
        kv("h", self.h.to_string());
        kv("m", self.m.to_string());
        if let Some(b) = self.stream_block {
            kv("stream_block", b.to_string());
        }
        kv("n_classes", self.n_classes.to_string());
        kv("input_dim", self.input_dim.to_string());
        kv("train_s", self.train_s.to_string());
        if !self.backend.is_empty() {
            kv("backend", self.backend.clone());
        }
        kv("map", self.map.to_string());
        kv("accuracy", self.accuracy.to_string());
        kv("created_unix", self.created_unix.to_string());
        if let Some(from) = &self.updated_from {
            kv("updated_from", from.clone());
        }
        for (key, value) in &self.health {
            kv(&format!("health.{key}"), value.to_string());
        }
        s
    }

    /// Parse a manifest; unknown keys are ignored (newer writers may add
    /// fields), missing keys keep their defaults.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut m = ModelManifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("manifest line {}: expected key = value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let ctx = || format!("manifest key {k:?}");
            match k {
                "name" => m.name = v.to_string(),
                "version" => m.version = v.parse().with_context(ctx)?,
                "method" => m.method = v.to_string(),
                "dataset" => m.dataset = v.to_string(),
                "condition" => m.condition = v.to_string(),
                "rho" => m.rho = v.parse().with_context(ctx)?,
                "c" => m.c = v.parse().with_context(ctx)?,
                "h" => m.h = v.parse().with_context(ctx)?,
                "m" => m.m = v.parse().with_context(ctx)?,
                "stream_block" => m.stream_block = Some(v.parse().with_context(ctx)?),
                "n_classes" => m.n_classes = v.parse().with_context(ctx)?,
                "input_dim" => m.input_dim = v.parse().with_context(ctx)?,
                "train_s" => m.train_s = v.parse().with_context(ctx)?,
                "backend" => m.backend = v.to_string(),
                "map" => m.map = v.parse().with_context(ctx)?,
                "accuracy" => m.accuracy = v.parse().with_context(ctx)?,
                "created_unix" => m.created_unix = v.parse().with_context(ctx)?,
                "updated_from" => m.updated_from = Some(v.to_string()),
                _ => {
                    if let Some(key) = k.strip_prefix("health.") {
                        m.health.insert(key.to_string(), v.parse().with_context(ctx)?);
                    }
                    // other unknown keys: forward compatibility
                }
            }
        }
        Ok(m)
    }
}

/// One published model version on disk.
#[derive(Debug, Clone)]
pub struct ModelVersion {
    pub name: String,
    pub version: u32,
    /// The version directory (`<root>/<name>/<version>`).
    pub dir: PathBuf,
    pub manifest: ModelManifest,
}

impl ModelVersion {
    pub fn artifact_path(&self) -> PathBuf {
        self.dir.join(ARTIFACT_FILE)
    }

    /// `name@version` — the spec string `resolve` accepts.
    pub fn spec(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }
}

/// A models directory. Cheap to construct; every operation re-reads the
/// filesystem so concurrent publishers/consumers stay coherent.
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    root: PathBuf,
}

impl ModelRegistry {
    pub fn open(root: impl Into<PathBuf>) -> Self {
        ModelRegistry { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Model names with at least one published version, sorted.
    pub fn models(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(names),
            Err(e) => return Err(e).context("reading models dir"),
        };
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if entry.file_type()?.is_dir()
                && !name.starts_with('.')
                && !self.versions(&name)?.is_empty()
            {
                names.push(name);
            }
        }
        names.sort();
        Ok(names)
    }

    /// Published version numbers of `name`, ascending (empty if none).
    pub fn versions(&self, name: &str) -> Result<Vec<u32>> {
        let dir = self.root.join(name);
        let mut versions = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(versions),
            Err(e) => return Err(e).with_context(|| format!("reading model dir {dir:?}")),
        };
        for entry in entries {
            let entry = entry?;
            if !entry.file_type()?.is_dir() {
                continue;
            }
            if let Ok(v) = entry.file_name().to_string_lossy().parse::<u32>() {
                // only count complete versions (artifact present)
                if entry.path().join(ARTIFACT_FILE).is_file() {
                    versions.push(v);
                }
            }
        }
        versions.sort_unstable();
        Ok(versions)
    }

    fn version_entry(&self, name: &str, version: u32) -> Result<ModelVersion> {
        let dir = self.root.join(name).join(version.to_string());
        let manifest_text = std::fs::read_to_string(dir.join(MANIFEST_FILE))
            .with_context(|| format!("reading manifest for {name}@{version}"))?;
        Ok(ModelVersion {
            name: name.to_string(),
            version,
            dir,
            manifest: ModelManifest::from_text(&manifest_text)?,
        })
    }

    /// The newest published version of `name`.
    pub fn latest(&self, name: &str) -> Result<ModelVersion> {
        self.latest_with_count(name).map(|(entry, _)| entry)
    }

    /// The newest published version plus the total version count, from one
    /// directory scan (what `akda models` lists per row).
    pub fn latest_with_count(&self, name: &str) -> Result<(ModelVersion, usize)> {
        let versions = self.versions(name)?;
        let &v = versions
            .last()
            .with_context(|| format!("no published versions of model {name:?}"))?;
        Ok((self.version_entry(name, v)?, versions.len()))
    }

    /// Resolve a `NAME` or `NAME@VERSION` spec. Names are validated on
    /// this read path too (symmetric with `publish`), so a spec can never
    /// traverse outside the registry root.
    pub fn resolve(&self, spec: &str) -> Result<ModelVersion> {
        match spec.split_once('@') {
            Some((name, v)) => {
                validate_name(name)?;
                let version: u32 = v
                    .parse()
                    .with_context(|| format!("bad version in model spec {spec:?}"))?;
                ensure!(
                    self.versions(name)?.contains(&version),
                    "model {name:?} has no published version {version}"
                );
                self.version_entry(name, version)
            }
            None => {
                validate_name(spec)?;
                self.latest(spec)
            }
        }
    }

    /// Load and fully verify the artifact of a resolved version.
    pub fn load_artifact(&self, spec: &str) -> Result<(ModelVersion, ModelArtifact)> {
        let entry = self.resolve(spec)?;
        let artifact = ModelArtifact::load(&entry.artifact_path())?;
        Ok((entry, artifact))
    }

    /// Load a servable detector bank: resolve, verify checksums, decode.
    /// Pure deserialization — no training anywhere on this path.
    pub fn load_bank(&self, spec: &str) -> Result<(ModelVersion, DetectorBank)> {
        let (entry, artifact) = self.load_artifact(spec)?;
        let bank = codec::decode_bank(&artifact)
            .with_context(|| format!("decoding model {}", entry.spec()))?;
        Ok((entry, bank))
    }

    /// Atomically publish `artifact` as the next version of `name`:
    /// stage into a hidden temp directory, then rename it to the version
    /// number. Returns the published entry. The `name`/`version`/
    /// `created_unix` fields of `manifest` are filled in here.
    pub fn publish(
        &self,
        name: &str,
        artifact: &ModelArtifact,
        manifest: &ModelManifest,
    ) -> Result<ModelVersion> {
        validate_name(name)?;
        let model_dir = self.root.join(name);
        std::fs::create_dir_all(&model_dir)
            .with_context(|| format!("creating model dir {model_dir:?}"))?;
        let bytes = artifact.to_bytes();
        let created_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);

        // stage once, then race on the rename: losing just means another
        // publisher took our number — retry with the next one
        let nonce = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let tmp = model_dir.join(format!(".tmp-{}-{nonce}", std::process::id()));
        std::fs::create_dir(&tmp).with_context(|| format!("staging dir {tmp:?}"))?;
        // the artifact bytes are version-independent: stage them once; a
        // version-collision retry only needs to rewrite the MANIFEST
        if let Err(e) = std::fs::write(tmp.join(ARTIFACT_FILE), &bytes) {
            let _ = std::fs::remove_dir_all(&tmp);
            return Err(e).with_context(|| format!("staging artifact for {name:?}"));
        }
        let publish_attempt = |version: u32| -> Result<Option<ModelVersion>> {
            let mut mf = manifest.clone();
            mf.name = name.to_string();
            mf.version = version;
            mf.created_unix = created_unix;
            std::fs::write(tmp.join(MANIFEST_FILE), mf.to_text())?;
            let dst = model_dir.join(version.to_string());
            match std::fs::rename(&tmp, &dst) {
                Ok(()) => Ok(Some(ModelVersion {
                    name: name.to_string(),
                    version,
                    dir: dst,
                    manifest: mf,
                })),
                // the version dir appeared between our scan and the rename
                // (EEXIST/ENOTEMPTY — detected portably via the dst probe
                // rather than ErrorKind, which only gained DirectoryNotEmpty
                // in recent Rust)
                Err(_) if dst.exists() => Ok(None),
                Err(e) => Err(e).with_context(|| format!("publishing {name}@{version}")),
            }
        };

        let mut version = self.versions(name)?.last().copied().unwrap_or(0) + 1;
        for _ in 0..64 {
            match publish_attempt(version) {
                Ok(Some(entry)) => {
                    crate::obs::counter("akda_registry_publishes_total").inc();
                    return Ok(entry);
                }
                Ok(None) => version += 1,
                Err(e) => {
                    let _ = std::fs::remove_dir_all(&tmp);
                    return Err(e);
                }
            }
        }
        let _ = std::fs::remove_dir_all(&tmp);
        bail!("could not claim a version slot for model {name:?} after 64 attempts")
    }

    /// Versions of `name` that some process has marked as currently
    /// served (its [`ServeMarker`] files), ascending and deduplicated.
    /// [`ModelRegistry::prune`] auto-protects every version returned
    /// here, so a fleet serving ten tenants does not need ten `--protect`
    /// flags — each tenant's marker shields its own served version.
    ///
    /// Markers whose writer is provably dead (the pid embedded in the
    /// file name no longer exists in `/proc` — serving CLIs usually exit
    /// via Ctrl-C/SIGTERM, which skips the RAII cleanup) are
    /// garbage-collected here instead of shielding old versions forever.
    /// Where liveness cannot be established (no procfs, unparsable
    /// name), the marker counts as live: the failure mode stays
    /// over-protection, never deleting a served model.
    pub fn served_versions(&self, name: &str) -> Result<Vec<u32>> {
        let dir = self.root.join(name);
        let mut versions = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(versions),
            Err(e) => return Err(e).with_context(|| format!("reading model dir {dir:?}")),
        };
        for entry in entries {
            let entry = entry?;
            let fname = entry.file_name().to_string_lossy().to_string();
            if !entry.file_type()?.is_file() || !fname.starts_with(SERVE_MARKER_PREFIX) {
                continue;
            }
            if let Some(pid) = marker_pid(&fname) {
                if marker_writer_dead(pid) {
                    // a lease whose holder is gone: collect the file and
                    // skip it (best-effort — a failed delete just means
                    // the next pass tries again)
                    let _ = std::fs::remove_file(entry.path());
                    continue;
                }
            }
            // a marker we cannot parse is treated as absent (a crashed
            // writer at worst under-protects its own version)
            if let Ok(text) = std::fs::read_to_string(entry.path()) {
                if let Ok(v) = text.trim().parse::<u32>() {
                    versions.push(v);
                }
            }
        }
        versions.sort_unstable();
        versions.dedup();
        Ok(versions)
    }

    /// Retention policy: delete old versions of `name`, keeping the newest
    /// `keep_last` (≥ 1 — the latest version is never deletable) plus, if
    /// given, the explicitly `protect`ed version — pass the version a
    /// running service currently serves so a GC pass can never delete a
    /// model out from under it. Every version some process has marked
    /// live with a [`ServeMarker`] (see [`ModelRegistry::served_versions`])
    /// is auto-protected the same way, so pruning a registry a fleet is
    /// serving never deletes any tenant's served version. Returns the
    /// pruned version numbers.
    ///
    /// # Examples
    ///
    /// ```
    /// use akda::model::{ModelArtifact, ModelManifest, ModelRegistry};
    /// use akda::linalg::Mat;
    ///
    /// let root = std::env::temp_dir().join(format!("akda_prune_doc_{}", std::process::id()));
    /// let _ = std::fs::remove_dir_all(&root);
    /// let reg = ModelRegistry::open(&root);
    /// let mut art = ModelArtifact::new();
    /// art.push_tensor("t", Mat::zeros(1, 1));
    /// for _ in 0..4 {
    ///     reg.publish("demo", &art, &ModelManifest::default()).unwrap();
    /// }
    /// // keep the newest two, but protect v1 (say a service still serves it)
    /// let pruned = reg.prune("demo", 2, Some(1)).unwrap();
    /// assert_eq!(pruned, vec![2]);
    /// assert_eq!(reg.versions("demo").unwrap(), vec![1, 3, 4]);
    /// # let _ = std::fs::remove_dir_all(&root);
    /// ```
    pub fn prune(&self, name: &str, keep_last: usize, protect: Option<u32>) -> Result<Vec<u32>> {
        validate_name(name)?;
        ensure!(keep_last >= 1, "prune must keep at least one version");
        let versions = self.versions(name)?;
        if versions.len() <= keep_last {
            return Ok(Vec::new());
        }
        let cut = versions.len() - keep_last;
        // union of the explicit shield and every live serve marker
        let served = self.served_versions(name)?;
        let mut pruned = Vec::new();
        let mut shielded = 0u64;
        for &v in &versions[..cut] {
            if Some(v) == protect || served.contains(&v) {
                shielded += 1;
                continue; // never delete a version a service still serves
            }
            let dir = self.root.join(name).join(v.to_string());
            std::fs::remove_dir_all(&dir).with_context(|| format!("pruning {name}@{v}"))?;
            pruned.push(v);
        }
        crate::obs::counter("akda_registry_prunes_total").add(pruned.len() as u64);
        crate::obs::counter("akda_registry_shielded_total").add(shielded);
        Ok(pruned)
    }

    /// Compare two published versions: manifest field changes, artifact
    /// section drift (shapes + per-section checksums), and — when both
    /// manifests carry a train-time evaluation — the accuracy/MAP drift.
    /// Both artifacts are fully checksum-verified by the load.
    pub fn diff(&self, spec_a: &str, spec_b: &str) -> Result<ModelDiff> {
        let (entry_a, art_a) = self.load_artifact(spec_a)?;
        let (entry_b, art_b) = self.load_artifact(spec_b)?;
        let (ma, mb) = (&entry_a.manifest, &entry_b.manifest);
        let mut fields = Vec::new();
        let mut field = |k: &str, a: String, b: String| {
            if a != b {
                fields.push((k.to_string(), a, b));
            }
        };
        field("method", ma.method.clone(), mb.method.clone());
        field("dataset", ma.dataset.clone(), mb.dataset.clone());
        field("condition", ma.condition.clone(), mb.condition.clone());
        field("rho", ma.rho.to_string(), mb.rho.to_string());
        field("c", ma.c.to_string(), mb.c.to_string());
        field("h", ma.h.to_string(), mb.h.to_string());
        field("m", ma.m.to_string(), mb.m.to_string());
        field("n_classes", ma.n_classes.to_string(), mb.n_classes.to_string());
        field("input_dim", ma.input_dim.to_string(), mb.input_dim.to_string());
        field("backend", ma.backend.clone(), mb.backend.clone());
        field(
            "updated_from",
            ma.updated_from.clone().unwrap_or_default(),
            mb.updated_from.clone().unwrap_or_default(),
        );
        // flight-recorder health keys: diff over the union so a key
        // appearing or vanishing is reported, not just value changes
        let health_keys: std::collections::BTreeSet<&String> =
            ma.health.keys().chain(mb.health.keys()).collect();
        for key in health_keys {
            let render = |m: &ModelManifest| {
                m.health.get(key.as_str()).map(|v| v.to_string()).unwrap_or_default()
            };
            field(&format!("health.{key}"), render(ma), render(mb));
        }

        // section inventory drift, keyed on the artifact checksums
        let (da, db) = (art_a.section_digests(), art_b.section_digests());
        let mut sections = Vec::new();
        for (name, rows, cols, sum) in &da {
            match db.iter().find(|(n, _, _, _)| n == name) {
                None => sections.push(format!("- {name} ({rows}x{cols}) only in {}", entry_a.spec())),
                Some((_, r2, c2, _)) if (rows, cols) != (r2, c2) => sections.push(format!(
                    "~ {name} shape {rows}x{cols} -> {r2}x{c2}"
                )),
                Some((_, _, _, s2)) if sum != s2 => {
                    sections.push(format!("~ {name} ({rows}x{cols}) payload changed"))
                }
                Some(_) => {}
            }
        }
        for (name, rows, cols, _) in &db {
            if !da.iter().any(|(n, _, _, _)| n == name) {
                sections.push(format!("+ {name} ({rows}x{cols}) only in {}", entry_b.spec()));
            }
        }

        // eval drift (manifests store 0.0 when no evaluation ran)
        let evaluated = |m: &ModelManifest| m.accuracy > 0.0 || m.map > 0.0;
        let (accuracy_drift, map_drift) = if evaluated(ma) && evaluated(mb) {
            (Some(mb.accuracy - ma.accuracy), Some(mb.map - ma.map))
        } else {
            (None, None)
        };
        Ok(ModelDiff {
            a: entry_a,
            b: entry_b,
            fields,
            sections,
            accuracy_drift,
            map_drift,
        })
    }
}

/// Result of [`ModelRegistry::diff`] — render it with `{}` (`Display`).
#[derive(Debug)]
pub struct ModelDiff {
    pub a: ModelVersion,
    pub b: ModelVersion,
    /// Manifest fields that changed: `(field, value in a, value in b)`.
    pub fields: Vec<(String, String, String)>,
    /// Human-readable artifact section drift lines.
    pub sections: Vec<String>,
    /// `accuracy(b) − accuracy(a)`, when both versions were evaluated.
    pub accuracy_drift: Option<f64>,
    /// `MAP(b) − MAP(a)`, when both versions were evaluated.
    pub map_drift: Option<f64>,
}

impl std::fmt::Display for ModelDiff {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "diff {} -> {}", self.a.spec(), self.b.spec())?;
        if self.fields.is_empty() {
            writeln!(f, "  manifest: no field changes")?;
        } else {
            for (k, a, b) in &self.fields {
                writeln!(f, "  manifest: {k}: {a:?} -> {b:?}")?;
            }
        }
        if self.sections.is_empty() {
            writeln!(f, "  sections: identical (names, shapes, checksums)")?;
        } else {
            for line in &self.sections {
                writeln!(f, "  sections: {line}")?;
            }
        }
        match (self.accuracy_drift, self.map_drift) {
            (Some(da), Some(dm)) => writeln!(
                f,
                "  eval drift: accuracy {:+.2}% ({:.2}% -> {:.2}%), MAP {:+.2}%",
                100.0 * da,
                100.0 * self.a.manifest.accuracy,
                100.0 * self.b.manifest.accuracy,
                100.0 * dm
            ),
            _ => writeln!(f, "  eval drift: n/a (one side stores no evaluation)"),
        }
    }
}

/// The writer pid embedded in a serve-marker file name
/// (`.served-<pid>-<seq>`), if it parses.
fn marker_pid(fname: &str) -> Option<u32> {
    fname
        .strip_prefix(SERVE_MARKER_PREFIX)?
        .split('-')
        .next()?
        .parse()
        .ok()
}

/// Whether a marker's writer is *provably* dead: procfs is available and
/// has no entry for the pid. Without procfs (non-Linux) this returns
/// false, so markers are conservatively treated as live.
fn marker_writer_dead(pid: u32) -> bool {
    Path::new("/proc").is_dir() && !Path::new(&format!("/proc/{pid}")).exists()
}

fn validate_name(name: &str) -> Result<()> {
    ensure!(!name.is_empty(), "model name must not be empty");
    ensure!(
        name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
        "model name {name:?} must be [A-Za-z0-9_-] (it becomes a directory \
         name and the @-spec syntax)"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Serve markers (cross-process GC shield)
// ---------------------------------------------------------------------------

/// RAII "this version is live" lease: a serving process (the fleet, or a
/// `serve --model` process) drops a `<root>/<name>/.served-<pid>-<seq>`
/// file holding the version it serves (`<seq>` is a process-wide counter,
/// so several services in one process never clobber each other's lease);
/// [`ModelRegistry::prune`] auto-protects every marked version, so
/// `akda models --prune` run from another process cannot delete a model
/// out from under a live endpoint. The marker is rewritten on hot-swap
/// ([`ServeMarker::update`]) and removed on drop.
///
/// A marker left behind by a killed or crashed process (RAII cleanup
/// skipped) only ever *over*-protects — fail-safe in the direction that
/// matters — and is garbage-collected by the next
/// [`ModelRegistry::served_versions`] pass once its writer pid is
/// provably gone (procfs check), so restart churn cannot accumulate
/// shields forever.
///
/// ```
/// use akda::model::{ModelArtifact, ModelManifest, ModelRegistry, ServeMarker};
/// use akda::linalg::Mat;
///
/// let root = std::env::temp_dir().join(format!("akda_marker_doc_{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&root);
/// let reg = ModelRegistry::open(&root);
/// let mut art = ModelArtifact::new();
/// art.push_tensor("t", Mat::zeros(1, 1));
/// for _ in 0..3 {
///     reg.publish("demo", &art, &ModelManifest::default()).unwrap();
/// }
/// let marker = ServeMarker::publish(&reg, "demo", 1).unwrap();
/// // prune wants to keep only v3, but v1 is marked live
/// assert_eq!(reg.prune("demo", 1, None).unwrap(), vec![2]);
/// assert_eq!(reg.versions("demo").unwrap(), vec![1, 3]);
/// drop(marker); // lease released: v1 is now collectable
/// assert_eq!(reg.prune("demo", 1, None).unwrap(), vec![1]);
/// # let _ = std::fs::remove_dir_all(&root);
/// ```
#[derive(Debug)]
pub struct ServeMarker {
    path: PathBuf,
}

impl ServeMarker {
    /// Mark `name@version` as served by this process. The model directory
    /// is created if needed (serving an about-to-be-published model is
    /// not an error — the marker just protects nothing yet).
    pub fn publish(registry: &ModelRegistry, name: &str, version: u32) -> Result<ServeMarker> {
        // pid alone is not unique enough: one process may serve the same
        // model through several services (two fleets, embedders, tests)
        static MARKER_SEQ: AtomicU64 = AtomicU64::new(0);
        validate_name(name)?;
        let dir = registry.root().join(name);
        std::fs::create_dir_all(&dir).with_context(|| format!("creating model dir {dir:?}"))?;
        let seq = MARKER_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("{SERVE_MARKER_PREFIX}{}-{seq}", std::process::id()));
        std::fs::write(&path, format!("{version}\n"))
            .with_context(|| format!("writing serve marker {path:?}"))?;
        Ok(ServeMarker { path })
    }

    /// Re-point the lease after a hot-swap: the old version becomes
    /// collectable, the new one is shielded.
    pub fn update(&self, version: u32) -> Result<()> {
        std::fs::write(&self.path, format!("{version}\n"))
            .with_context(|| format!("updating serve marker {:?}", self.path))
    }
}

impl Drop for ServeMarker {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------------
// Hot reload
// ---------------------------------------------------------------------------

/// Background watcher that polls the registry and swaps newly published
/// versions of one model into a [`BankHandle`] — the serving side of the
/// train → publish → load loop. Drop (or `stop`) to halt the watcher; the
/// scoring service itself is untouched either way.
pub struct HotReloader {
    stop: Arc<AtomicBool>,
    reloads: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HotReloader {
    /// Watch `name` in `registry`, swapping newer versions into `bank`.
    /// `loaded_version` is what the service currently serves;
    /// `expected_input_dim` guards against swapping in a model the running
    /// clients cannot feed. Polls every `poll` (artifact decode happens on
    /// the watcher thread, never blocking the scoring loop). When the
    /// serving process holds a [`ServeMarker`] lease, pass it here: the
    /// watcher re-points it to every version it swaps in, keeping the GC
    /// shield aligned with what is actually served; the lease is released
    /// when the watcher stops.
    pub fn start(
        registry: ModelRegistry,
        name: String,
        bank: BankHandle,
        loaded_version: u32,
        expected_input_dim: usize,
        poll: Duration,
        marker: Option<ServeMarker>,
    ) -> HotReloader {
        let stop = Arc::new(AtomicBool::new(false));
        let reloads = Arc::new(AtomicUsize::new(0));
        let (stop2, reloads2) = (stop.clone(), reloads.clone());
        let handle = std::thread::Builder::new()
            .name("akda-model-watch".into())
            .spawn(move || {
                // (version, artifact mtime) last examined — starts at what
                // the service loaded; versions are immutable so version
                // alone almost always suffices, the mtime catches a
                // replaced artifact file
                let mut current: (u32, Option<std::time::SystemTime>) =
                    (loaded_version, None);
                while !stop2.load(Ordering::Relaxed) {
                    match Self::poll_once(
                        &registry,
                        &name,
                        &bank,
                        expected_input_dim,
                        &mut current,
                    ) {
                        Ok(true) => {
                            reloads2.fetch_add(1, Ordering::SeqCst);
                            if let Some(m) = &marker {
                                if let Err(e) = m.update(bank.served_version()) {
                                    eprintln!(
                                        "model watch: serve-marker update for \
                                         {name:?}: {e:#}"
                                    );
                                }
                            }
                        }
                        Ok(false) => {}
                        Err(e) => {
                            eprintln!("model watch: reload of {name:?} failed: {e:#}");
                        }
                    }
                    // interruptible pacing: stop()/Drop returns within
                    // ~50ms even under a very long --watch interval
                    crate::coordinator::fleet::sleep_until_stopped(&stop2, poll);
                }
                // `marker` (if any) drops here: lease released with the watch
            })
            .expect("spawn model watcher");
        HotReloader { stop, reloads, handle: Some(handle) }
    }

    /// One poll step: returns whether a swap happened. `examined` is the
    /// (version, artifact mtime) pair last looked at — it is advanced
    /// *before* the load/decode attempt, so a version that fails to load
    /// or is rejected is examined (and logged) once, not re-read and
    /// re-checksummed on every poll; a republished artifact changes the
    /// mtime and is picked up again. Crate-visible because the fleet's
    /// multi-tenant watcher (`coordinator::fleet`) runs this same step
    /// once per tenant from a single thread.
    pub(crate) fn poll_once(
        registry: &ModelRegistry,
        name: &str,
        bank: &BankHandle,
        expected_input_dim: usize,
        examined: &mut (u32, Option<std::time::SystemTime>),
    ) -> Result<bool> {
        let latest = match registry.latest(name) {
            Ok(l) => l,
            // a registry that is momentarily empty (e.g. being re-created)
            // is not an error worth spamming the log for
            Err(_) => return Ok(false),
        };
        let mtime = std::fs::metadata(latest.artifact_path())
            .and_then(|m| m.modified())
            .ok();
        // never auto-downgrade: if version dirs were deleted so the latest
        // is older than what we serve, keep serving what we have
        if latest.version < examined.0 {
            return Ok(false);
        }
        if latest.version == examined.0 {
            match (examined.1, mtime) {
                // first sighting: record the mtime, nothing changed
                (None, m) => {
                    examined.1 = m;
                    return Ok(false);
                }
                // transient metadata failure on an unchanged version is
                // "unchanged", not a reload trigger (avoids oscillating
                // re-decodes when mtime is briefly unreadable)
                (Some(_), None) => return Ok(false),
                (Some(a), Some(b)) if a == b => return Ok(false),
                // genuinely replaced artifact file: fall through and reload
                _ => {}
            }
        }
        *examined = (latest.version, mtime);
        let (entry, artifact) = registry.load_artifact(&latest.spec())?;
        let dim = codec::input_dim(&artifact)?;
        ensure!(
            dim == expected_input_dim,
            "refusing to hot-swap {}: input dim {} != served dim {}",
            entry.spec(),
            dim,
            expected_input_dim
        );
        let new_bank = codec::decode_bank(&artifact)?;
        bank.swap_versioned(Arc::new(new_bank), entry.version);
        eprintln!("model watch: hot-reloaded {}", entry.spec());
        Ok(true)
    }

    /// Number of successful hot swaps so far.
    pub fn reloads(&self) -> usize {
        self.reloads.load(Ordering::SeqCst)
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HotReloader {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("akda_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_artifact(seed: f64) -> ModelArtifact {
        let mut a = ModelArtifact::new();
        a.set_meta("method", "test");
        a.push_tensor("t", Mat::from_fn(2, 2, |r, c| seed + (r * 2 + c) as f64));
        a
    }

    #[test]
    fn manifest_text_roundtrips() {
        let mf = ModelManifest {
            name: "demo".into(),
            version: 3,
            method: "akda-nystrom".into(),
            dataset: "eth80".into(),
            condition: "100Ex".into(),
            rho: 0.05,
            c: 1.0,
            h: 2,
            m: 64,
            stream_block: Some(256),
            n_classes: 8,
            input_dim: 64,
            train_s: 1.25,
            backend: "parallel".into(),
            map: 0.97,
            accuracy: 0.95,
            created_unix: 1_760_000_000,
            updated_from: Some("demo@2".into()),
            health: [
                ("chol_pivot_min".to_string(), 0.125),
                ("chol_pivot_max".to_string(), 4.5),
                ("eps".to_string(), 0.001),
            ]
            .into_iter()
            .collect(),
        };
        let text = mf.to_text();
        assert!(text.contains("health.chol_pivot_min = 0.125"), "{text}");
        assert!(text.contains("health.eps = 0.001"), "{text}");
        assert!(text.contains("backend = parallel"), "{text}");
        let back = ModelManifest::from_text(&text).unwrap();
        assert_eq!(mf, back);
        // no stream_block / updated_from / health / backend lines when
        // not applicable
        let mf2 = ModelManifest {
            stream_block: None,
            updated_from: None,
            health: Default::default(),
            backend: String::new(),
            ..mf
        };
        let text = mf2.to_text();
        assert!(!text.contains("stream_block"));
        assert!(!text.contains("updated_from"));
        assert!(!text.contains("health."));
        assert!(!text.contains("backend"));
        let back2 = ModelManifest::from_text(&text).unwrap();
        assert_eq!(back2.stream_block, None);
        assert_eq!(back2.updated_from, None);
        assert!(back2.health.is_empty());
        assert!(back2.backend.is_empty());
    }

    #[test]
    fn prune_keeps_latest_and_protected_versions() {
        let root = tmpdir("prune");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        for i in 0..5 {
            reg.publish("m", &tiny_artifact(i as f64), &mf).unwrap();
        }
        // keep_last 0 is rejected, nothing to prune when all fit
        assert!(reg.prune("m", 0, None).is_err());
        assert!(reg.prune("m", 5, None).unwrap().is_empty());
        // keep newest 2, protect v2 (a service still serves it)
        let pruned = reg.prune("m", 2, Some(2)).unwrap();
        assert_eq!(pruned, vec![1, 3]);
        assert_eq!(reg.versions("m").unwrap(), vec![2, 4, 5]);
        // the latest version survives even keep_last = 1
        let pruned = reg.prune("m", 1, None).unwrap();
        assert_eq!(pruned, vec![2, 4]);
        assert_eq!(reg.versions("m").unwrap(), vec![5]);
        assert_eq!(reg.latest("m").unwrap().version, 5);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn prune_auto_protects_marked_served_versions() {
        let root = tmpdir("marker");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        for i in 0..4 {
            reg.publish("m", &tiny_artifact(i as f64), &mf).unwrap();
        }
        // two processes' worth of markers on v1 and v2 (simulated: our pid
        // plus a hand-written stale marker from a "crashed" fleet)
        let marker = ServeMarker::publish(&reg, "m", 2).unwrap();
        std::fs::write(root.join("m").join(".served-stale"), "1\n").unwrap();
        assert_eq!(reg.served_versions("m").unwrap(), vec![1, 2]);
        // keep_last 1 would delete v1..v3, but both marked versions survive
        assert_eq!(reg.prune("m", 1, None).unwrap(), vec![3]);
        assert_eq!(reg.versions("m").unwrap(), vec![1, 2, 4]);
        // swap the lease to v4 and drop the stale marker: v1/v2 collectable
        marker.update(4).unwrap();
        std::fs::remove_file(root.join("m").join(".served-stale")).unwrap();
        assert_eq!(reg.prune("m", 1, None).unwrap(), vec![1, 2]);
        // dropping the lease removes the marker file
        drop(marker);
        assert!(reg.served_versions("m").unwrap().is_empty());
        // an unparsable marker is ignored rather than an error
        std::fs::write(root.join("m").join(".served-1"), "not a version").unwrap();
        assert!(reg.served_versions("m").unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn diff_reports_manifest_section_and_eval_drift() {
        let root = tmpdir("diff");
        let reg = ModelRegistry::open(&root);
        let mf1 = ModelManifest {
            method: "akda".into(),
            accuracy: 0.90,
            map: 0.92,
            ..Default::default()
        };
        reg.publish("m", &tiny_artifact(0.0), &mf1).unwrap();
        let mut art2 = tiny_artifact(5.0); // same shape, different payload
        art2.push_tensor("extra", Mat::zeros(2, 3));
        let mf2 = ModelManifest {
            method: "akda".into(),
            accuracy: 0.95,
            map: 0.97,
            updated_from: Some("m@1".into()),
            ..Default::default()
        };
        reg.publish("m", &art2, &mf2).unwrap();

        let diff = reg.diff("m@1", "m@2").unwrap();
        assert!(diff.fields.iter().any(|(k, _, _)| k == "updated_from"));
        assert!(diff.sections.iter().any(|s| s.contains("t") && s.contains("payload")));
        assert!(diff.sections.iter().any(|s| s.contains("extra")));
        assert!((diff.accuracy_drift.unwrap() - 0.05).abs() < 1e-12);
        let text = format!("{diff}");
        assert!(text.contains("m@1 -> m@2"), "{text}");
        assert!(text.contains("eval drift"), "{text}");

        // identical versions diff clean
        reg.publish("n", &tiny_artifact(1.0), &ModelManifest::default()).unwrap();
        reg.publish("n", &tiny_artifact(1.0), &ModelManifest::default()).unwrap();
        let diff = reg.diff("n@1", "n@2").unwrap();
        assert!(diff.sections.is_empty());
        assert!(diff.accuracy_drift.is_none(), "unevaluated manifests report no drift");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn publish_assigns_increasing_versions_and_latest_wins() {
        let root = tmpdir("versions");
        let reg = ModelRegistry::open(&root);
        assert!(reg.models().unwrap().is_empty());
        assert!(reg.latest("demo").is_err());

        let mf = ModelManifest { method: "akda".into(), ..Default::default() };
        let v1 = reg.publish("demo", &tiny_artifact(0.0), &mf).unwrap();
        let v2 = reg.publish("demo", &tiny_artifact(10.0), &mf).unwrap();
        assert_eq!((v1.version, v2.version), (1, 2));
        assert_eq!(reg.versions("demo").unwrap(), vec![1, 2]);
        assert_eq!(reg.models().unwrap(), vec!["demo".to_string()]);

        let latest = reg.latest("demo").unwrap();
        assert_eq!(latest.version, 2);
        assert_eq!(latest.manifest.name, "demo");
        // resolve both spec forms
        assert_eq!(reg.resolve("demo").unwrap().version, 2);
        assert_eq!(reg.resolve("demo@1").unwrap().version, 1);
        assert!(reg.resolve("demo@9").is_err());

        // artifacts round-trip through the registry path
        let (_, art) = reg.load_artifact("demo@1").unwrap();
        assert_eq!(art.tensor("t").unwrap()[(0, 0)], 0.0);
        let (_, art) = reg.load_artifact("demo").unwrap();
        assert_eq!(art.tensor("t").unwrap()[(0, 0)], 10.0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn publish_is_staged_no_partial_version_dirs() {
        let root = tmpdir("staging");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        reg.publish("m", &tiny_artifact(1.0), &mf).unwrap();
        // no stray staging dirs survive a successful publish
        let leftovers: Vec<_> = std::fs::read_dir(root.join("m"))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .filter(|n| n.starts_with('.'))
            .collect();
        assert!(leftovers.is_empty(), "staging dirs left behind: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bad_model_names_are_rejected() {
        let root = tmpdir("names");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        for bad in ["", "a/b", "a@1", "a b", "..", ".hidden"] {
            assert!(reg.publish(bad, &tiny_artifact(0.0), &mf).is_err(), "{bad:?}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn read_path_rejects_traversal_specs() {
        let root = tmpdir("traversal");
        let reg = ModelRegistry::open(&root);
        reg.publish("good", &tiny_artifact(0.0), &ModelManifest::default()).unwrap();
        for bad in ["../good", "..", "a/b", "../good@1", "a/b@2"] {
            assert!(reg.resolve(bad).is_err(), "{bad:?} must not resolve");
            assert!(reg.load_artifact(bad).is_err(), "{bad:?} must not load");
        }
        assert!(reg.resolve("good").is_ok());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn poll_once_examines_a_bad_version_only_once() {
        use crate::coordinator::DetectorBank;
        use crate::da::IdentityProjection;
        use crate::svm::LinearSvm;

        let root = tmpdir("badpoll");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        reg.publish("m", &tiny_artifact(1.0), &mf).unwrap(); // v1 = "served"
        let bank = DetectorBank {
            projection: Box::new(IdentityProjection::new(2)),
            svms: vec![("c0".into(), LinearSvm { w: vec![0.0; 2], b: 0.0 })],
        };
        let handle = BankHandle::new(Arc::new(bank));
        let mut examined = (1u32, None);
        // same version: records the mtime, no swap
        assert!(!HotReloader::poll_once(&reg, "m", &handle, 2, &mut examined).unwrap());
        // v2 is not a decodable bank (tiny_artifact has no projection/meta)
        reg.publish("m", &tiny_artifact(2.0), &mf).unwrap();
        assert!(HotReloader::poll_once(&reg, "m", &handle, 2, &mut examined).is_err());
        // the bad version was marked examined: no re-read, no error loop
        assert!(!HotReloader::poll_once(&reg, "m", &handle, 2, &mut examined).unwrap());
        assert_eq!(handle.generation(), 0, "bad version must never swap in");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn incomplete_version_dirs_are_invisible() {
        let root = tmpdir("incomplete");
        let reg = ModelRegistry::open(&root);
        let mf = ModelManifest::default();
        reg.publish("m", &tiny_artifact(1.0), &mf).unwrap();
        // a version dir without an artifact (crashed publisher simulation)
        std::fs::create_dir_all(root.join("m").join("7")).unwrap();
        assert_eq!(reg.versions("m").unwrap(), vec![1]);
        assert_eq!(reg.latest("m").unwrap().version, 1);
        // the next publish must not collide with the junk dir either
        let v = reg.publish("m", &tiny_artifact(2.0), &mf).unwrap();
        assert!(v.version >= 2, "got {}", v.version);
        let _ = std::fs::remove_dir_all(&root);
    }
}
