//! Encode/decode trained state to and from [`ModelArtifact`]s.
//!
//! Every servable trained state has a `projection` meta kind and a fixed
//! set of tensor sections:
//!
//! | kind        | concrete type                     | sections |
//! |-------------|-----------------------------------|----------|
//! | `identity`  | `da::IdentityProjection`          | — (dims in meta) |
//! | `kernel`    | `da::KernelProjection` (also saves `runtime::PjrtProjection`) | `kernel.x_train`, `kernel.psi`, optional `kernel.center`, `kernel.params` |
//! | `linear`    | `da::LinearProjection`            | `linear.w`, `linear.mean` |
//! | `approx`    | `da::akda_approx::ApproxProjection` | `approx.w` + map sections |
//! | `blocked`   | `da::akda_stream::BlockedProjection` | `approx.w` + map sections + `blocked.rows` meta |
//!
//! Feature maps (meta `approx.map`): `nystrom` saves `map.landmarks` +
//! `map.whitening` + its kernel; `rff` saves `map.omega` + `map.scale`.
//! Kernels are a meta kind (`linear`/`rbf`/`poly`) plus a 1×2 f64
//! parameter section (`<prefix>.params` = `[rho, 0]` for RBF,
//! `[degree, c]` for poly) so bandwidths round-trip bit-for-bit.
//!
//! The detector bank adds the one-vs-rest LSVM state: `svm.w` (C×D) and
//! `svm.b` (1×C), with class names in `class.<i>.name` meta keys.
//!
//! Decoding is the artifact mirror of `coordinator::build_dr`: a
//! `projection`-kind dispatch that reconstructs the exact concrete type,
//! so a loaded bank scores bit-for-bit identically to the bank that was
//! saved (pinned by `tests/model_roundtrip.rs`). Encoding uses the
//! `Projection::as_any` / `FeatureMap::as_any` introspection hooks to
//! recover the concrete types from the trait objects the training paths
//! return.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::artifact::ModelArtifact;
use crate::approx::{FeatureMap, NystromMap, RffMap};
use crate::coordinator::DetectorBank;
use crate::da::akda_approx::ApproxProjection;
use crate::da::akda_stream::BlockedProjection;
use crate::da::{IdentityProjection, KernelProjection, LinearProjection, Projection};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::PjrtProjection;
use crate::svm::LinearSvm;

/// Meta key naming the projection kind (the decode dispatch tag).
pub const PROJECTION_KEY: &str = "projection";
/// Meta key for the input dimensionality the projection consumes.
pub const INPUT_DIM_KEY: &str = "input_dim";

// ---------------------------------------------------------------------------
// Kernel <-> sections
// ---------------------------------------------------------------------------

fn encode_kernel(art: &mut ModelArtifact, prefix: &str, kernel: Kernel) {
    let (kind, p0, p1) = match kernel {
        Kernel::Linear => ("linear", 0.0, 0.0),
        Kernel::Rbf { rho } => ("rbf", rho, 0.0),
        Kernel::Poly { degree, c } => ("poly", degree as f64, c),
    };
    art.set_meta(&format!("{prefix}.kind"), kind);
    art.push_tensor(&format!("{prefix}.params"), Mat::from_vec(1, 2, vec![p0, p1]));
}

fn decode_kernel(art: &ModelArtifact, prefix: &str) -> Result<Kernel> {
    let kind = art.meta_str(&format!("{prefix}.kind"))?;
    let params = art.tensor(&format!("{prefix}.params"))?;
    ensure!(params.shape() == (1, 2), "{prefix}.params must be 1x2");
    Ok(match kind {
        "linear" => Kernel::Linear,
        "rbf" => Kernel::Rbf { rho: params[(0, 0)] },
        "poly" => Kernel::Poly { degree: params[(0, 0)] as i32, c: params[(0, 1)] },
        other => bail!("unknown kernel kind {other:?} in artifact"),
    })
}

// ---------------------------------------------------------------------------
// Feature map <-> sections
// ---------------------------------------------------------------------------

fn encode_map(art: &mut ModelArtifact, map: &dyn FeatureMap) -> Result<()> {
    if let Some(ny) = map.as_any().downcast_ref::<NystromMap>() {
        art.set_meta("approx.map", "nystrom");
        encode_kernel(art, "map.kernel", ny.kernel);
        art.push_tensor("map.landmarks", ny.landmarks.clone());
        art.push_tensor("map.whitening", ny.whitening().clone());
    } else if let Some(rff) = map.as_any().downcast_ref::<RffMap>() {
        art.set_meta("approx.map", "rff");
        art.push_tensor("map.omega", rff.omega().clone());
        art.push_tensor("map.scale", Mat::from_vec(1, 1, vec![rff.scale()]));
    } else {
        bail!("feature map {:?} has no artifact encoding", map.name());
    }
    Ok(())
}

fn decode_map(art: &ModelArtifact) -> Result<Arc<dyn FeatureMap>> {
    Ok(match art.meta_str("approx.map")? {
        "nystrom" => {
            let kernel = decode_kernel(art, "map.kernel")?;
            let landmarks = art.tensor("map.landmarks")?.clone();
            let whitening = art.tensor("map.whitening")?.clone();
            Arc::new(NystromMap::from_parts(landmarks, kernel, whitening)?)
        }
        "rff" => {
            let omega = art.tensor("map.omega")?.clone();
            let scale = art.tensor("map.scale")?;
            ensure!(scale.shape() == (1, 1), "map.scale must be 1x1");
            Arc::new(RffMap::from_parts(omega, scale[(0, 0)])?)
        }
        other => bail!("unknown feature-map kind {other:?} in artifact"),
    })
}

fn map_input_dim(map: &dyn FeatureMap) -> Result<usize> {
    if let Some(ny) = map.as_any().downcast_ref::<NystromMap>() {
        Ok(ny.landmarks.cols())
    } else if let Some(rff) = map.as_any().downcast_ref::<RffMap>() {
        Ok(rff.omega().rows())
    } else {
        bail!("feature map {:?} has no artifact encoding", map.name())
    }
}

// ---------------------------------------------------------------------------
// Projection <-> artifact
// ---------------------------------------------------------------------------

/// Serialize a fitted projection into `art` (kind tag, input dim, tensor
/// sections). Fails on projection types with no on-disk representation.
pub fn encode_projection(art: &mut ModelArtifact, proj: &dyn Projection) -> Result<()> {
    let any = proj.as_any();
    if let Some(p) = any.downcast_ref::<KernelProjection>() {
        encode_kernel_expansion(art, &p.x_train, &p.psi, p.kernel, p.center_against.as_ref());
    } else if let Some(p) = any.downcast_ref::<PjrtProjection>() {
        // the f32 PJRT engine accelerates training; the persisted model is
        // the plain kernel expansion it produced, served natively on load
        let (x_train, psi, kernel) = p.expansion_state();
        encode_kernel_expansion(art, x_train, psi, kernel, None);
    } else if let Some(p) = any.downcast_ref::<LinearProjection>() {
        art.set_meta(PROJECTION_KEY, "linear");
        art.set_meta(INPUT_DIM_KEY, p.mean.len().to_string());
        art.push_tensor("linear.w", p.w.clone());
        art.push_tensor("linear.mean", Mat::from_vec(1, p.mean.len(), p.mean.clone()));
    } else if let Some(p) = any.downcast_ref::<ApproxProjection>() {
        art.set_meta(PROJECTION_KEY, "approx");
        art.set_meta(INPUT_DIM_KEY, map_input_dim(p.map.as_ref())?.to_string());
        encode_map(art, p.map.as_ref())?;
        art.push_tensor("approx.w", p.w.clone());
    } else if let Some(p) = any.downcast_ref::<BlockedProjection>() {
        art.set_meta(PROJECTION_KEY, "blocked");
        art.set_meta(INPUT_DIM_KEY, map_input_dim(p.map.as_ref())?.to_string());
        art.set_meta("blocked.rows", p.block_rows.to_string());
        encode_map(art, p.map.as_ref())?;
        art.push_tensor("approx.w", p.w.clone());
    } else if let Some(p) = any.downcast_ref::<IdentityProjection>() {
        art.set_meta(PROJECTION_KEY, "identity");
        art.set_meta(INPUT_DIM_KEY, p.dim().to_string());
    } else {
        bail!("projection type has no artifact encoding (unknown concrete type)");
    }
    Ok(())
}

fn encode_kernel_expansion(
    art: &mut ModelArtifact,
    x_train: &Mat,
    psi: &Mat,
    kernel: Kernel,
    center: Option<&Mat>,
) {
    art.set_meta(PROJECTION_KEY, "kernel");
    art.set_meta(INPUT_DIM_KEY, x_train.cols().to_string());
    encode_kernel(art, "kernel", kernel);
    art.push_tensor("kernel.x_train", x_train.clone());
    art.push_tensor("kernel.psi", psi.clone());
    if let Some(k_train) = center {
        art.push_tensor("kernel.center", k_train.clone());
    }
}

/// Reconstruct the concrete projection from an artifact — the load-path
/// mirror of `coordinator::build_dr`'s method dispatch, keyed on the
/// `projection` meta kind instead of a `MethodId`. Performs no training:
/// every tensor is used exactly as stored.
pub fn decode_projection(art: &ModelArtifact) -> Result<Box<dyn Projection>> {
    Ok(match art.meta_str(PROJECTION_KEY)? {
        "kernel" => {
            let x_train = art.tensor("kernel.x_train")?.clone();
            let psi = art.tensor("kernel.psi")?.clone();
            ensure!(
                x_train.rows() == psi.rows(),
                "kernel expansion mismatch: {} support points vs {} psi rows",
                x_train.rows(),
                psi.rows()
            );
            let center_against = if art.has_tensor("kernel.center") {
                Some(art.tensor("kernel.center")?.clone())
            } else {
                None
            };
            Box::new(KernelProjection {
                x_train,
                psi,
                kernel: decode_kernel(art, "kernel")?,
                center_against,
            })
        }
        "linear" => {
            let w = art.tensor("linear.w")?.clone();
            let mean = art.tensor("linear.mean")?;
            ensure!(
                mean.rows() == 1 && mean.cols() == w.rows(),
                "linear projection mismatch: mean 1x{} vs w {}x{}",
                mean.cols(),
                w.rows(),
                w.cols()
            );
            Box::new(LinearProjection { w, mean: mean.data().to_vec() })
        }
        "approx" => {
            let map = decode_map(art)?;
            let w = decode_approx_w(art, map.as_ref())?;
            Box::new(ApproxProjection { map, w })
        }
        "blocked" => {
            let map = decode_map(art)?;
            let w = decode_approx_w(art, map.as_ref())?;
            let block_rows = art.meta_usize("blocked.rows")?.max(1);
            Box::new(BlockedProjection { map, w, block_rows })
        }
        "identity" => Box::new(IdentityProjection::new(art.meta_usize(INPUT_DIM_KEY)?)),
        other => bail!("unknown projection kind {other:?} in artifact"),
    })
}

fn decode_approx_w(art: &ModelArtifact, map: &dyn FeatureMap) -> Result<Mat> {
    let w = art.tensor("approx.w")?.clone();
    ensure!(
        w.rows() == map.dim(),
        "approx weights mismatch: map dim {} vs w rows {}",
        map.dim(),
        w.rows()
    );
    Ok(w)
}

// ---------------------------------------------------------------------------
// Detector bank <-> artifact
// ---------------------------------------------------------------------------

/// Serialize a full trained detector bank (projection + OvR LSVM bank)
/// into a fresh artifact. `method` is the training `MethodId` name,
/// recorded for inspection and manifest generation.
pub fn encode_bank(bank: &DetectorBank, method: &str) -> Result<ModelArtifact> {
    let mut art = ModelArtifact::new();
    art.set_meta("method", method);
    encode_projection(&mut art, bank.projection.as_ref())?;
    let c = bank.svms.len();
    ensure!(c > 0, "cannot save a detector bank with no detectors");
    let d = bank.svms[0].1.w.len();
    ensure!(
        bank.svms.iter().all(|(_, s)| s.w.len() == d),
        "all OvR detectors must share the projected dimensionality"
    );
    art.set_meta("classes", c.to_string());
    for (i, (name, _)) in bank.svms.iter().enumerate() {
        art.set_meta(&format!("class.{i}.name"), name.clone());
    }
    art.push_tensor("svm.w", Mat::from_fn(c, d, |i, j| bank.svms[i].1.w[j]));
    art.push_tensor(
        "svm.b",
        Mat::from_fn(1, c, |_, j| bank.svms[j].1.b),
    );
    Ok(art)
}

/// Reconstruct a detector bank from an artifact. Pure deserialization —
/// no `fit` call anywhere on this path (the `serve --model` guarantee).
pub fn decode_bank(art: &ModelArtifact) -> Result<DetectorBank> {
    let projection = decode_projection(art)?;
    let c = art.meta_usize("classes")?;
    let w = art.tensor("svm.w")?;
    let b = art.tensor("svm.b")?;
    ensure!(
        w.rows() == c && b.shape() == (1, c),
        "SVM bank mismatch: classes={c}, svm.w {}x{}, svm.b {}x{}",
        w.rows(),
        w.cols(),
        b.rows(),
        b.cols()
    );
    ensure!(
        w.cols() == projection.dim(),
        "SVM bank dimensionality {} does not match projection dim {}",
        w.cols(),
        projection.dim()
    );
    let svms = (0..c)
        .map(|i| {
            let name = art
                .meta_str(&format!("class.{i}.name"))
                .map(|s| s.to_string())
                .unwrap_or_else(|_| format!("class{i}"));
            Ok((name, LinearSvm { w: w.row(i).to_vec(), b: b[(0, i)] }))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DetectorBank { projection, svms })
}

/// The input dimensionality a decoded bank's scoring service must accept.
pub fn input_dim(art: &ModelArtifact) -> Result<usize> {
    art.meta_usize(INPUT_DIM_KEY)
        .context("artifact has no input_dim — not a bank artifact?")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::da::DrMethod;

    fn roundtrip(proj: &dyn Projection, x: &Mat) {
        let mut art = ModelArtifact::new();
        encode_projection(&mut art, proj).unwrap();
        let art = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        let loaded = decode_projection(&art).unwrap();
        assert_eq!(loaded.dim(), proj.dim());
        let (a, b) = (proj.project(x), loaded.project(x));
        assert_eq!(a, b, "projection must round-trip bit-for-bit");
    }

    fn toy() -> (Mat, Vec<usize>) {
        let mut rng = crate::util::rng::Rng::new(9);
        let x = Mat::from_fn(26, 5, |r, _| (r % 2) as f64 * 3.0 + rng.normal());
        let labels = (0..26).map(|i| i % 2).collect();
        (x, labels)
    }

    #[test]
    fn kernel_projection_roundtrips_bitwise() {
        let (x, labels) = toy();
        let proj = crate::da::akda::Akda::new(Kernel::Rbf { rho: 0.37 })
            .fit(&x, &labels, 2)
            .unwrap();
        roundtrip(proj.as_ref(), &x);
    }

    #[test]
    fn centered_kernel_projection_keeps_its_centering() {
        let (x, labels) = toy();
        let proj = crate::da::gda::Gda { kernel: Kernel::Rbf { rho: 0.3 }, eps: 1e-3 }
            .fit(&x, &labels, 2)
            .unwrap();
        let mut art = ModelArtifact::new();
        encode_projection(&mut art, proj.as_ref()).unwrap();
        assert!(art.has_tensor("kernel.center"));
        roundtrip(proj.as_ref(), &x);
    }

    #[test]
    fn linear_and_identity_projections_roundtrip() {
        let (x, labels) = toy();
        let proj = crate::da::pca::Pca::new().fit(&x, &labels, 2).unwrap();
        roundtrip(proj.as_ref(), &x);
        let ident = IdentityProjection::new(5);
        roundtrip(&ident, &x);
    }

    #[test]
    fn poly_and_linear_kernels_roundtrip_through_params() {
        let (x, labels) = toy();
        for kernel in [Kernel::Linear, Kernel::Poly { degree: 3, c: 1.25 }] {
            let proj = crate::da::akda::Akda::new(kernel).fit(&x, &labels, 2).unwrap();
            roundtrip(proj.as_ref(), &x);
        }
    }

    #[test]
    fn approx_and_blocked_projections_roundtrip() {
        use crate::da::akda_approx::AkdaApprox;
        let (x, labels) = toy();
        for cfg in [
            AkdaApprox::nystrom(Kernel::Rbf { rho: 0.4 }, 8),
            AkdaApprox::rff(Kernel::Rbf { rho: 0.4 }, 32),
        ] {
            let proj = cfg.fit(&x, &labels, 2).unwrap();
            roundtrip(proj.as_ref(), &x);
            // the same state served through the tiled projection
            let ap = proj.as_any().downcast_ref::<ApproxProjection>().unwrap();
            let blocked = BlockedProjection {
                map: ap.map.clone(),
                w: ap.w.clone(),
                block_rows: 7,
            };
            roundtrip(&blocked, &x);
        }
    }

    #[test]
    fn decode_rejects_cross_wired_sections() {
        // a kernel artifact with psi rows != support points must not load
        let mut art = ModelArtifact::new();
        art.set_meta(PROJECTION_KEY, "kernel");
        art.set_meta(INPUT_DIM_KEY, "3");
        encode_kernel(&mut art, "kernel", Kernel::Rbf { rho: 0.5 });
        art.push_tensor("kernel.x_train", Mat::zeros(4, 3));
        art.push_tensor("kernel.psi", Mat::zeros(5, 1));
        assert!(decode_projection(&art).is_err());
    }
}
