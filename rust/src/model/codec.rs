//! Encode/decode trained state to and from [`ModelArtifact`]s.
//!
//! Every servable trained state has a `projection` meta kind and a fixed
//! set of tensor sections:
//!
//! | kind        | concrete type                     | sections |
//! |-------------|-----------------------------------|----------|
//! | `identity`  | `da::IdentityProjection`          | — (dims in meta) |
//! | `kernel`    | `da::KernelProjection` (also saves `runtime::PjrtProjection`) | `kernel.x_train`, `kernel.psi`, optional `kernel.center`, `kernel.params` |
//! | `linear`    | `da::LinearProjection`            | `linear.w`, `linear.mean` |
//! | `approx`    | `da::akda_approx::ApproxProjection` | `approx.w` + map sections |
//! | `blocked`   | `da::akda_stream::BlockedProjection` | `approx.w` + map sections + `blocked.rows` meta |
//!
//! Feature maps (meta `approx.map`): `nystrom` saves `map.landmarks` +
//! `map.whitening` + its kernel; `rff` saves `map.omega` + `map.scale`.
//! Kernels are a meta kind (`linear`/`rbf`/`poly`) plus a 1×2 f64
//! parameter section (`<prefix>.params` = `[rho, 0]` for RBF,
//! `[degree, c]` for poly) so bandwidths round-trip bit-for-bit.
//!
//! The detector bank adds the one-vs-rest LSVM state: `svm.w` (C×D) and
//! `svm.b` (1×C), with class names in `class.<i>.name` meta keys.
//!
//! # Resume sections (continual learning)
//!
//! A model published by `akda train` can additionally carry the state
//! `akda update` needs to *continue* training without a full refit
//! ([`ResumeState`], Sec. 7 recursive learning):
//!
//! | `resume.kind` | sections | consumed by |
//! |---------------|----------|-------------|
//! | `exact`  | `resume.chol_l` (N×N factor of K+εI), `resume.labels` (1×N), `resume.eps` (1×1), meta `resume.n_classes` | `da::incremental::IncrementalAkda::from_parts` → bordered-Cholesky growth |
//! | `approx` | `resume.gram` (m×m ΦᵀΦ), `resume.class_sums` (m×C), `resume.counts` (1×C), `resume.reservoir` (r×F), `resume.reservoir_labels` (1×r), `resume.eps` (1×1), meta `resume.seen` | `model::update` → accumulator continuation / landmark refresh |
//!
//! Resume state is optional: [`decode_resume`] returns `None` for
//! artifacts that never stored it (they still serve, they just cannot be
//! updated in place).
//!
//! Decoding is the artifact mirror of `coordinator::build_dr`: a
//! `projection`-kind dispatch that reconstructs the exact concrete type,
//! so a loaded bank scores bit-for-bit identically to the bank that was
//! saved (pinned by `tests/model_roundtrip.rs`). Encoding uses the
//! `Projection::as_any` / `FeatureMap::as_any` introspection hooks to
//! recover the concrete types from the trait objects the training paths
//! return.
//!
//! # Examples
//!
//! A fitted projection round-trips through artifact bytes without loss:
//!
//! ```
//! use akda::da::{DrMethod, Projection};
//! use akda::kernels::Kernel;
//! use akda::linalg::Mat;
//! use akda::model::ModelArtifact;
//! use akda::model::codec::{decode_projection, encode_projection};
//! use akda::util::rng::Rng;
//!
//! let mut rng = Rng::new(2);
//! let x = Mat::from_fn(20, 4, |r, _| (r % 2) as f64 * 3.0 + rng.normal());
//! let labels: Vec<usize> = (0..20).map(|i| i % 2).collect();
//! let proj = akda::da::akda::Akda::new(Kernel::Rbf { rho: 0.4 })
//!     .fit(&x, &labels, 2)
//!     .unwrap();
//!
//! let mut art = ModelArtifact::new();
//! encode_projection(&mut art, proj.as_ref()).unwrap();
//! let loaded = decode_projection(&ModelArtifact::from_bytes(&art.to_bytes()).unwrap()).unwrap();
//! assert_eq!(loaded.project(&x), proj.project(&x)); // bit-for-bit
//! ```

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::artifact::ModelArtifact;
use crate::approx::{FeatureMap, NystromMap, RffMap};
use crate::coordinator::DetectorBank;
use crate::da::akda_approx::ApproxProjection;
use crate::da::akda_stream::BlockedProjection;
use crate::da::{IdentityProjection, KernelProjection, LinearProjection, Projection};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::PjrtProjection;
use crate::svm::LinearSvm;

/// Meta key naming the projection kind (the decode dispatch tag).
pub const PROJECTION_KEY: &str = "projection";
/// Meta key for the input dimensionality the projection consumes.
pub const INPUT_DIM_KEY: &str = "input_dim";

// ---------------------------------------------------------------------------
// Kernel <-> sections
// ---------------------------------------------------------------------------

fn encode_kernel(art: &mut ModelArtifact, prefix: &str, kernel: Kernel) {
    let (kind, p0, p1) = match kernel {
        Kernel::Linear => ("linear", 0.0, 0.0),
        Kernel::Rbf { rho } => ("rbf", rho, 0.0),
        Kernel::Poly { degree, c } => ("poly", degree as f64, c),
    };
    art.set_meta(&format!("{prefix}.kind"), kind);
    art.push_tensor(&format!("{prefix}.params"), Mat::from_vec(1, 2, vec![p0, p1]));
}

fn decode_kernel(art: &ModelArtifact, prefix: &str) -> Result<Kernel> {
    let kind = art.meta_str(&format!("{prefix}.kind"))?;
    let params = art.tensor(&format!("{prefix}.params"))?;
    ensure!(params.shape() == (1, 2), "{prefix}.params must be 1x2");
    Ok(match kind {
        "linear" => Kernel::Linear,
        "rbf" => Kernel::Rbf { rho: params[(0, 0)] },
        "poly" => Kernel::Poly { degree: params[(0, 0)] as i32, c: params[(0, 1)] },
        other => bail!("unknown kernel kind {other:?} in artifact"),
    })
}

// ---------------------------------------------------------------------------
// Feature map <-> sections
// ---------------------------------------------------------------------------

pub(crate) fn encode_map(art: &mut ModelArtifact, map: &dyn FeatureMap) -> Result<()> {
    if let Some(ny) = map.as_any().downcast_ref::<NystromMap>() {
        art.set_meta("approx.map", "nystrom");
        encode_kernel(art, "map.kernel", ny.kernel);
        art.push_tensor("map.landmarks", ny.landmarks.clone());
        art.push_tensor("map.whitening", ny.whitening().clone());
    } else if let Some(rff) = map.as_any().downcast_ref::<RffMap>() {
        art.set_meta("approx.map", "rff");
        art.push_tensor("map.omega", rff.omega().clone());
        art.push_tensor("map.scale", Mat::from_vec(1, 1, vec![rff.scale()]));
    } else {
        bail!("feature map {:?} has no artifact encoding", map.name());
    }
    Ok(())
}

pub(crate) fn decode_map(art: &ModelArtifact) -> Result<Arc<dyn FeatureMap>> {
    Ok(match art.meta_str("approx.map")? {
        "nystrom" => {
            let kernel = decode_kernel(art, "map.kernel")?;
            let landmarks = art.tensor("map.landmarks")?.clone();
            let whitening = art.tensor("map.whitening")?.clone();
            Arc::new(NystromMap::from_parts(landmarks, kernel, whitening)?)
        }
        "rff" => {
            let omega = art.tensor("map.omega")?.clone();
            let scale = art.tensor("map.scale")?;
            ensure!(scale.shape() == (1, 1), "map.scale must be 1x1");
            Arc::new(RffMap::from_parts(omega, scale[(0, 0)])?)
        }
        other => bail!("unknown feature-map kind {other:?} in artifact"),
    })
}

fn map_input_dim(map: &dyn FeatureMap) -> Result<usize> {
    if let Some(ny) = map.as_any().downcast_ref::<NystromMap>() {
        Ok(ny.landmarks.cols())
    } else if let Some(rff) = map.as_any().downcast_ref::<RffMap>() {
        Ok(rff.omega().rows())
    } else {
        bail!("feature map {:?} has no artifact encoding", map.name())
    }
}

// ---------------------------------------------------------------------------
// Projection <-> artifact
// ---------------------------------------------------------------------------

/// Serialize a fitted projection into `art` (kind tag, input dim, tensor
/// sections). Fails on projection types with no on-disk representation.
pub fn encode_projection(art: &mut ModelArtifact, proj: &dyn Projection) -> Result<()> {
    let any = proj.as_any();
    if let Some(p) = any.downcast_ref::<KernelProjection>() {
        encode_kernel_expansion(art, &p.x_train, &p.psi, p.kernel, p.center_against.as_ref());
    } else if let Some(p) = any.downcast_ref::<PjrtProjection>() {
        // the f32 PJRT engine accelerates training; the persisted model is
        // the plain kernel expansion it produced, served natively on load
        let (x_train, psi, kernel) = p.expansion_state();
        encode_kernel_expansion(art, x_train, psi, kernel, None);
    } else if let Some(p) = any.downcast_ref::<LinearProjection>() {
        art.set_meta(PROJECTION_KEY, "linear");
        art.set_meta(INPUT_DIM_KEY, p.mean.len().to_string());
        art.push_tensor("linear.w", p.w.clone());
        art.push_tensor("linear.mean", Mat::from_vec(1, p.mean.len(), p.mean.clone()));
    } else if let Some(p) = any.downcast_ref::<ApproxProjection>() {
        art.set_meta(PROJECTION_KEY, "approx");
        art.set_meta(INPUT_DIM_KEY, map_input_dim(p.map.as_ref())?.to_string());
        encode_map(art, p.map.as_ref())?;
        art.push_tensor("approx.w", p.w.clone());
    } else if let Some(p) = any.downcast_ref::<BlockedProjection>() {
        art.set_meta(PROJECTION_KEY, "blocked");
        art.set_meta(INPUT_DIM_KEY, map_input_dim(p.map.as_ref())?.to_string());
        art.set_meta("blocked.rows", p.block_rows.to_string());
        encode_map(art, p.map.as_ref())?;
        art.push_tensor("approx.w", p.w.clone());
    } else if let Some(p) = any.downcast_ref::<IdentityProjection>() {
        art.set_meta(PROJECTION_KEY, "identity");
        art.set_meta(INPUT_DIM_KEY, p.dim().to_string());
    } else {
        bail!("projection type has no artifact encoding (unknown concrete type)");
    }
    Ok(())
}

fn encode_kernel_expansion(
    art: &mut ModelArtifact,
    x_train: &Mat,
    psi: &Mat,
    kernel: Kernel,
    center: Option<&Mat>,
) {
    art.set_meta(PROJECTION_KEY, "kernel");
    art.set_meta(INPUT_DIM_KEY, x_train.cols().to_string());
    encode_kernel(art, "kernel", kernel);
    art.push_tensor("kernel.x_train", x_train.clone());
    art.push_tensor("kernel.psi", psi.clone());
    if let Some(k_train) = center {
        art.push_tensor("kernel.center", k_train.clone());
    }
}

/// Reconstruct the concrete projection from an artifact — the load-path
/// mirror of `coordinator::build_dr`'s method dispatch, keyed on the
/// `projection` meta kind instead of a `MethodId`. Performs no training:
/// every tensor is used exactly as stored.
pub fn decode_projection(art: &ModelArtifact) -> Result<Box<dyn Projection>> {
    Ok(match art.meta_str(PROJECTION_KEY)? {
        "kernel" => {
            let x_train = art.tensor("kernel.x_train")?.clone();
            let psi = art.tensor("kernel.psi")?.clone();
            ensure!(
                x_train.rows() == psi.rows(),
                "kernel expansion mismatch: {} support points vs {} psi rows",
                x_train.rows(),
                psi.rows()
            );
            let center_against = if art.has_tensor("kernel.center") {
                Some(art.tensor("kernel.center")?.clone())
            } else {
                None
            };
            Box::new(KernelProjection {
                x_train,
                psi,
                kernel: decode_kernel(art, "kernel")?,
                center_against,
            })
        }
        "linear" => {
            let w = art.tensor("linear.w")?.clone();
            let mean = art.tensor("linear.mean")?;
            ensure!(
                mean.rows() == 1 && mean.cols() == w.rows(),
                "linear projection mismatch: mean 1x{} vs w {}x{}",
                mean.cols(),
                w.rows(),
                w.cols()
            );
            Box::new(LinearProjection { w, mean: mean.data().to_vec() })
        }
        "approx" => {
            let map = decode_map(art)?;
            let w = decode_approx_w(art, map.as_ref())?;
            Box::new(ApproxProjection { map, w })
        }
        "blocked" => {
            let map = decode_map(art)?;
            let w = decode_approx_w(art, map.as_ref())?;
            let block_rows = art.meta_usize("blocked.rows")?.max(1);
            Box::new(BlockedProjection { map, w, block_rows })
        }
        "identity" => Box::new(IdentityProjection::new(art.meta_usize(INPUT_DIM_KEY)?)),
        other => bail!("unknown projection kind {other:?} in artifact"),
    })
}

fn decode_approx_w(art: &ModelArtifact, map: &dyn FeatureMap) -> Result<Mat> {
    let w = art.tensor("approx.w")?.clone();
    ensure!(
        w.rows() == map.dim(),
        "approx weights mismatch: map dim {} vs w rows {}",
        map.dim(),
        w.rows()
    );
    Ok(w)
}

// ---------------------------------------------------------------------------
// Detector bank <-> artifact
// ---------------------------------------------------------------------------

/// Serialize a full trained detector bank (projection + OvR LSVM bank)
/// into a fresh artifact. `method` is the training `MethodId` name,
/// recorded for inspection and manifest generation.
pub fn encode_bank(bank: &DetectorBank, method: &str) -> Result<ModelArtifact> {
    let mut art = ModelArtifact::new();
    art.set_meta("method", method);
    encode_projection(&mut art, bank.projection.as_ref())?;
    let c = bank.svms.len();
    ensure!(c > 0, "cannot save a detector bank with no detectors");
    let d = bank.svms[0].1.w.len();
    ensure!(
        bank.svms.iter().all(|(_, s)| s.w.len() == d),
        "all OvR detectors must share the projected dimensionality"
    );
    art.set_meta("classes", c.to_string());
    for (i, (name, _)) in bank.svms.iter().enumerate() {
        art.set_meta(&format!("class.{i}.name"), name.clone());
    }
    art.push_tensor("svm.w", Mat::from_fn(c, d, |i, j| bank.svms[i].1.w[j]));
    art.push_tensor(
        "svm.b",
        Mat::from_fn(1, c, |_, j| bank.svms[j].1.b),
    );
    Ok(art)
}

/// Reconstruct a detector bank from an artifact. Pure deserialization —
/// no `fit` call anywhere on this path (the `serve --model` guarantee).
pub fn decode_bank(art: &ModelArtifact) -> Result<DetectorBank> {
    let projection = decode_projection(art)?;
    let c = art.meta_usize("classes")?;
    let w = art.tensor("svm.w")?;
    let b = art.tensor("svm.b")?;
    ensure!(
        w.rows() == c && b.shape() == (1, c),
        "SVM bank mismatch: classes={c}, svm.w {}x{}, svm.b {}x{}",
        w.rows(),
        w.cols(),
        b.rows(),
        b.cols()
    );
    ensure!(
        w.cols() == projection.dim(),
        "SVM bank dimensionality {} does not match projection dim {}",
        w.cols(),
        projection.dim()
    );
    let svms = (0..c)
        .map(|i| {
            let name = art
                .meta_str(&format!("class.{i}.name"))
                .map(|s| s.to_string())
                .unwrap_or_else(|_| format!("class{i}"));
            Ok((name, LinearSvm { w: w.row(i).to_vec(), b: b[(0, i)] }))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DetectorBank { projection, svms })
}

/// The input dimensionality a decoded bank's scoring service must accept.
pub fn input_dim(art: &ModelArtifact) -> Result<usize> {
    art.meta_usize(INPUT_DIM_KEY)
        .context("artifact has no input_dim — not a bank artifact?")
}

// ---------------------------------------------------------------------------
// Resume state <-> artifact (continual learning)
// ---------------------------------------------------------------------------

/// Meta key tagging which resume flavour an artifact carries.
pub const RESUME_KIND_KEY: &str = "resume.kind";

/// Exact-path resume state: everything `da::incremental` needs to grow a
/// published AKDA model by bordered Cholesky rows (the training rows
/// themselves live in the `kernel.x_train` section).
#[derive(Debug, Clone)]
pub struct ExactResume {
    /// Lower Cholesky factor of K + εI over the training rows.
    pub chol_l: Mat,
    /// Training labels, same row order as `kernel.x_train`.
    pub labels: Vec<usize>,
    pub eps: f64,
    pub n_classes: usize,
}

/// Approximate-path resume state: the tiled accumulator aggregates
/// (`da::akda_stream`) plus a labeled reservoir of the training history
/// for landmark refresh and SVM retraining.
#[derive(Debug, Clone)]
pub struct ApproxResume {
    /// Pre-ridge m×m Gram accumulator G = ΦᵀΦ.
    pub gram: Mat,
    /// m×C class sums S = ΦᵀR.
    pub class_sums: Mat,
    /// Per-class row counts.
    pub counts: Vec<usize>,
    /// Labeled reservoir rows (uniform sample of the training history).
    pub reservoir: Mat,
    pub reservoir_labels: Vec<usize>,
    /// Total rows ever absorbed by the reservoir (Algorithm R counter).
    pub seen: usize,
    pub eps: f64,
}

/// Optional continual-learning state carried next to a servable bank.
#[derive(Debug, Clone)]
pub enum ResumeState {
    Exact(ExactResume),
    Approx(ApproxResume),
}

impl ResumeState {
    pub fn kind(&self) -> &'static str {
        match self {
            ResumeState::Exact(_) => "exact",
            ResumeState::Approx(_) => "approx",
        }
    }
}

fn encode_usize_row(art: &mut ModelArtifact, name: &str, v: &[usize]) {
    art.push_tensor(name, Mat::from_fn(1, v.len(), |_, j| v[j] as f64));
}

fn decode_usize_row(art: &ModelArtifact, name: &str) -> Result<Vec<usize>> {
    let t = art.tensor(name)?;
    ensure!(t.rows() == 1, "{name} must be a 1-row tensor, got {}x{}", t.rows(), t.cols());
    let mut out = Vec::with_capacity(t.cols());
    for &v in t.data() {
        ensure!(
            v >= 0.0 && v.fract() == 0.0 && v < (1u64 << 53) as f64,
            "{name} holds a non-integer entry {v}"
        );
        out.push(v as usize);
    }
    Ok(out)
}

fn push_scalar(art: &mut ModelArtifact, name: &str, v: f64) {
    art.push_tensor(name, Mat::from_vec(1, 1, vec![v]));
}

fn scalar(art: &ModelArtifact, name: &str) -> Result<f64> {
    let t = art.tensor(name)?;
    ensure!(t.shape() == (1, 1), "{name} must be 1x1");
    Ok(t[(0, 0)])
}

/// Attach resume sections to a bank artifact (see the module docs table).
pub fn encode_resume(art: &mut ModelArtifact, resume: &ResumeState) -> Result<()> {
    art.set_meta(RESUME_KIND_KEY, resume.kind());
    match resume {
        ResumeState::Exact(r) => {
            ensure!(
                r.chol_l.rows() == r.chol_l.cols() && r.chol_l.rows() == r.labels.len(),
                "exact resume mismatch: factor {}x{} vs {} labels",
                r.chol_l.rows(),
                r.chol_l.cols(),
                r.labels.len()
            );
            art.set_meta("resume.n_classes", r.n_classes.to_string());
            art.push_tensor("resume.chol_l", r.chol_l.clone());
            encode_usize_row(art, "resume.labels", &r.labels);
            push_scalar(art, "resume.eps", r.eps);
        }
        ResumeState::Approx(r) => {
            ensure!(
                r.gram.rows() == r.gram.cols() && r.gram.rows() == r.class_sums.rows(),
                "approx resume mismatch: gram {}x{} vs class sums {}x{}",
                r.gram.rows(),
                r.gram.cols(),
                r.class_sums.rows(),
                r.class_sums.cols()
            );
            ensure!(
                r.counts.len() == r.class_sums.cols(),
                "approx resume mismatch: {} counts vs {} class-sum columns",
                r.counts.len(),
                r.class_sums.cols()
            );
            ensure!(
                r.reservoir.rows() == r.reservoir_labels.len() && r.seen >= r.reservoir.rows(),
                "approx resume mismatch: reservoir {} rows, {} labels, seen {}",
                r.reservoir.rows(),
                r.reservoir_labels.len(),
                r.seen
            );
            art.set_meta("resume.seen", r.seen.to_string());
            art.push_tensor("resume.gram", r.gram.clone());
            art.push_tensor("resume.class_sums", r.class_sums.clone());
            encode_usize_row(art, "resume.counts", &r.counts);
            art.push_tensor("resume.reservoir", r.reservoir.clone());
            encode_usize_row(art, "resume.reservoir_labels", &r.reservoir_labels);
            push_scalar(art, "resume.eps", r.eps);
        }
    }
    Ok(())
}

/// Decode the resume sections, `None` when the artifact never stored any
/// (older artifacts, or training paths with no resumable state).
pub fn decode_resume(art: &ModelArtifact) -> Result<Option<ResumeState>> {
    let kind = match art.meta.get(RESUME_KIND_KEY) {
        Some(k) => k.as_str(),
        None => return Ok(None),
    };
    Ok(Some(match kind {
        "exact" => {
            let chol_l = art.tensor("resume.chol_l")?.clone();
            let labels = decode_usize_row(art, "resume.labels")?;
            ensure!(
                chol_l.rows() == chol_l.cols() && chol_l.rows() == labels.len(),
                "exact resume mismatch: factor {}x{} vs {} labels",
                chol_l.rows(),
                chol_l.cols(),
                labels.len()
            );
            ResumeState::Exact(ExactResume {
                chol_l,
                labels,
                eps: scalar(art, "resume.eps")?,
                n_classes: art.meta_usize("resume.n_classes")?,
            })
        }
        "approx" => {
            let gram = art.tensor("resume.gram")?.clone();
            let class_sums = art.tensor("resume.class_sums")?.clone();
            let counts = decode_usize_row(art, "resume.counts")?;
            let reservoir = art.tensor("resume.reservoir")?.clone();
            let reservoir_labels = decode_usize_row(art, "resume.reservoir_labels")?;
            let seen = art.meta_usize("resume.seen")?;
            ensure!(
                gram.rows() == gram.cols() && gram.rows() == class_sums.rows(),
                "approx resume mismatch: gram {}x{} vs class sums {}x{}",
                gram.rows(),
                gram.cols(),
                class_sums.rows(),
                class_sums.cols()
            );
            ensure!(
                counts.len() == class_sums.cols(),
                "approx resume mismatch: {} counts vs {} class-sum columns",
                counts.len(),
                class_sums.cols()
            );
            ensure!(
                reservoir.rows() == reservoir_labels.len() && seen >= reservoir.rows(),
                "approx resume mismatch: reservoir {} rows, {} labels, seen {}",
                reservoir.rows(),
                reservoir_labels.len(),
                seen
            );
            ResumeState::Approx(ApproxResume {
                gram,
                class_sums,
                counts,
                reservoir,
                reservoir_labels,
                seen,
                eps: scalar(art, "resume.eps")?,
            })
        }
        other => bail!("unknown resume kind {other:?} in artifact"),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::da::DrMethod;

    fn roundtrip(proj: &dyn Projection, x: &Mat) {
        let mut art = ModelArtifact::new();
        encode_projection(&mut art, proj).unwrap();
        let art = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        let loaded = decode_projection(&art).unwrap();
        assert_eq!(loaded.dim(), proj.dim());
        let (a, b) = (proj.project(x), loaded.project(x));
        assert_eq!(a, b, "projection must round-trip bit-for-bit");
    }

    fn toy() -> (Mat, Vec<usize>) {
        let mut rng = crate::util::rng::Rng::new(9);
        let x = Mat::from_fn(26, 5, |r, _| (r % 2) as f64 * 3.0 + rng.normal());
        let labels = (0..26).map(|i| i % 2).collect();
        (x, labels)
    }

    #[test]
    fn kernel_projection_roundtrips_bitwise() {
        let (x, labels) = toy();
        let proj = crate::da::akda::Akda::new(Kernel::Rbf { rho: 0.37 })
            .fit(&x, &labels, 2)
            .unwrap();
        roundtrip(proj.as_ref(), &x);
    }

    #[test]
    fn centered_kernel_projection_keeps_its_centering() {
        let (x, labels) = toy();
        let proj = crate::da::gda::Gda { kernel: Kernel::Rbf { rho: 0.3 }, eps: 1e-3 }
            .fit(&x, &labels, 2)
            .unwrap();
        let mut art = ModelArtifact::new();
        encode_projection(&mut art, proj.as_ref()).unwrap();
        assert!(art.has_tensor("kernel.center"));
        roundtrip(proj.as_ref(), &x);
    }

    #[test]
    fn linear_and_identity_projections_roundtrip() {
        let (x, labels) = toy();
        let proj = crate::da::pca::Pca::new().fit(&x, &labels, 2).unwrap();
        roundtrip(proj.as_ref(), &x);
        let ident = IdentityProjection::new(5);
        roundtrip(&ident, &x);
    }

    #[test]
    fn poly_and_linear_kernels_roundtrip_through_params() {
        let (x, labels) = toy();
        for kernel in [Kernel::Linear, Kernel::Poly { degree: 3, c: 1.25 }] {
            let proj = crate::da::akda::Akda::new(kernel).fit(&x, &labels, 2).unwrap();
            roundtrip(proj.as_ref(), &x);
        }
    }

    #[test]
    fn approx_and_blocked_projections_roundtrip() {
        use crate::da::akda_approx::AkdaApprox;
        let (x, labels) = toy();
        for cfg in [
            AkdaApprox::nystrom(Kernel::Rbf { rho: 0.4 }, 8),
            AkdaApprox::rff(Kernel::Rbf { rho: 0.4 }, 32),
        ] {
            let proj = cfg.fit(&x, &labels, 2).unwrap();
            roundtrip(proj.as_ref(), &x);
            // the same state served through the tiled projection
            let ap = proj.as_any().downcast_ref::<ApproxProjection>().unwrap();
            let blocked = BlockedProjection {
                map: ap.map.clone(),
                w: ap.w.clone(),
                block_rows: 7,
            };
            roundtrip(&blocked, &x);
        }
    }

    #[test]
    fn resume_state_roundtrips_both_kinds() {
        let exact = ResumeState::Exact(ExactResume {
            chol_l: Mat::from_fn(4, 4, |r, c| if c <= r { (r + c + 1) as f64 } else { 0.0 }),
            labels: vec![0, 1, 0, 2],
            eps: 1e-3,
            n_classes: 3,
        });
        let approx = ResumeState::Approx(ApproxResume {
            gram: Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f64),
            class_sums: Mat::from_fn(3, 2, |r, c| (r + c) as f64 * 0.5),
            counts: vec![7, 9],
            reservoir: Mat::from_fn(5, 2, |r, c| (r * 2 + c) as f64),
            reservoir_labels: vec![0, 1, 1, 0, 1],
            seen: 16,
            eps: 2e-3,
        });
        for state in [exact, approx] {
            let mut art = ModelArtifact::new();
            encode_resume(&mut art, &state).unwrap();
            let art = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
            let back = decode_resume(&art).unwrap().expect("resume stored");
            assert_eq!(back.kind(), state.kind());
            match (state, back) {
                (ResumeState::Exact(a), ResumeState::Exact(b)) => {
                    assert_eq!(a.chol_l, b.chol_l);
                    assert_eq!(a.labels, b.labels);
                    assert_eq!(a.eps, b.eps);
                    assert_eq!(a.n_classes, b.n_classes);
                }
                (ResumeState::Approx(a), ResumeState::Approx(b)) => {
                    assert_eq!(a.gram, b.gram);
                    assert_eq!(a.class_sums, b.class_sums);
                    assert_eq!(a.counts, b.counts);
                    assert_eq!(a.reservoir, b.reservoir);
                    assert_eq!(a.reservoir_labels, b.reservoir_labels);
                    assert_eq!((a.seen, a.eps), (b.seen, b.eps));
                }
                _ => panic!("kind changed across the round trip"),
            }
        }
    }

    #[test]
    fn artifacts_without_resume_state_decode_to_none() {
        let (x, labels) = toy();
        let proj = crate::da::akda::Akda::new(Kernel::Rbf { rho: 0.3 })
            .fit(&x, &labels, 2)
            .unwrap();
        let mut art = ModelArtifact::new();
        encode_projection(&mut art, proj.as_ref()).unwrap();
        assert!(decode_resume(&art).unwrap().is_none());
    }

    #[test]
    fn decode_rejects_cross_wired_sections() {
        // a kernel artifact with psi rows != support points must not load
        let mut art = ModelArtifact::new();
        art.set_meta(PROJECTION_KEY, "kernel");
        art.set_meta(INPUT_DIM_KEY, "3");
        encode_kernel(&mut art, "kernel", Kernel::Rbf { rho: 0.5 });
        art.push_tensor("kernel.x_train", Mat::zeros(4, 3));
        art.push_tensor("kernel.psi", Mat::zeros(5, 1));
        assert!(decode_projection(&art).is_err());
    }
}
