//! Binary kernel SVM via SMO (simplified working-set selection) — the
//! paper's KSVM baseline column (LIBSVM's role in Sec. 6.3.1).

use crate::kernels::{cross_gram, gram, Kernel};
use crate::linalg::Mat;

#[derive(Debug, Clone)]
pub struct KernelSvm {
    pub support_x: Mat,
    pub support_coef: Vec<f64>, // α_i y_i of the support vectors
    pub b: f64,
    pub kernel: Kernel,
}

#[derive(Debug, Clone, Copy)]
pub struct KernelSvmConfig {
    pub c: f64,
    pub kernel: Kernel,
    pub max_iter: usize,
    pub tol: f64,
}

impl Default for KernelSvmConfig {
    fn default() -> Self {
        KernelSvmConfig {
            c: 1.0,
            kernel: Kernel::Rbf { rho: 0.5 },
            max_iter: 10_000,
            tol: 1e-3,
        }
    }
}

impl KernelSvm {
    /// SMO with maximal-violating-pair working-set selection.
    pub fn train(x: &Mat, y: &[f64], cfg: KernelSvmConfig) -> KernelSvm {
        let n = x.rows();
        assert_eq!(y.len(), n);
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let k = gram(x, cfg.kernel);
        let mut alpha = vec![0.0_f64; n];
        // gradient of the dual objective: g_i = Σ_j α_j y_i y_j K_ij − 1
        let mut grad = vec![-1.0_f64; n];

        for _it in 0..cfg.max_iter {
            // maximal violating pair (i from I_up, j from I_low)
            let mut i_sel = usize::MAX;
            let mut g_max = f64::NEG_INFINITY;
            let mut j_sel = usize::MAX;
            let mut g_min = f64::INFINITY;
            for t in 0..n {
                let up = (y[t] > 0.0 && alpha[t] < cfg.c) || (y[t] < 0.0 && alpha[t] > 0.0);
                let low = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < cfg.c);
                let v = -y[t] * grad[t];
                if up && v > g_max {
                    g_max = v;
                    i_sel = t;
                }
                if low && v < g_min {
                    g_min = v;
                    j_sel = t;
                }
            }
            if i_sel == usize::MAX || j_sel == usize::MAX || g_max - g_min < cfg.tol {
                break;
            }
            let (i, j) = (i_sel, j_sel);
            let eta = (k[(i, i)] + k[(j, j)] - 2.0 * k[(i, j)]).max(1e-12);
            let delta = (g_max - g_min) / eta;
            // clip to the box
            let (old_ai, old_aj) = (alpha[i], alpha[j]);
            let mut d = delta;
            if y[i] > 0.0 {
                d = d.min(cfg.c - alpha[i]);
            } else {
                d = d.min(alpha[i]);
            }
            if y[j] > 0.0 {
                d = d.min(alpha[j]);
            } else {
                d = d.min(cfg.c - alpha[j]);
            }
            alpha[i] += y[i] * d;
            alpha[j] -= y[j] * d;
            let (di, dj) = ((alpha[i] - old_ai) * y[i], (alpha[j] - old_aj) * y[j]);
            for t in 0..n {
                grad[t] += y[t] * (di * k[(i, t)] + dj * k[(j, t)]);
            }
        }

        // bias from free support vectors (fallback: margin midpoint)
        let mut b_sum = 0.0;
        let mut b_cnt = 0usize;
        for t in 0..n {
            if alpha[t] > 1e-9 && alpha[t] < cfg.c - 1e-9 {
                // y_t (f(x_t)) = 1 ⇒ b = y_t − Σ α_j y_j K_jt
                let f: f64 = (0..n).map(|j2| alpha[j2] * y[j2] * k[(j2, t)]).sum();
                b_sum += y[t] - f;
                b_cnt += 1;
            }
        }
        let b = if b_cnt > 0 {
            b_sum / b_cnt as f64
        } else {
            let mut lo = f64::NEG_INFINITY;
            let mut hi = f64::INFINITY;
            for t in 0..n {
                let f: f64 = (0..n).map(|j2| alpha[j2] * y[j2] * k[(j2, t)]).sum();
                if y[t] > 0.0 {
                    hi = hi.min(y[t] - f);
                } else {
                    lo = lo.max(y[t] - f);
                }
            }
            if lo.is_finite() && hi.is_finite() { 0.5 * (lo + hi) } else { 0.0 }
        };

        // keep only the support vectors
        let sv_idx: Vec<usize> = (0..n).filter(|&t| alpha[t] > 1e-9).collect();
        let support_x = x.select_rows(&sv_idx);
        let support_coef = sv_idx.iter().map(|&t| alpha[t] * y[t]).collect();
        KernelSvm { support_x, support_coef, b, kernel: cfg.kernel }
    }

    pub fn decision_batch(&self, x: &Mat) -> Vec<f64> {
        let kc = cross_gram(x, &self.support_x, self.kernel);
        (0..x.rows())
            .map(|i| {
                crate::linalg::dot(kc.row(i), &self.support_coef) + self.b
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::concentric_shells;
    use crate::util::rng::Rng;

    #[test]
    fn solves_nonlinear_shells() {
        let (x, labels) = concentric_shells(40, 3, 1);
        let y: Vec<f64> = labels.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let svm = KernelSvm::train(&x, &y, KernelSvmConfig::default());
        let scores = svm.decision_batch(&x);
        let errors = (0..80).filter(|&i| scores[i].signum() != y[i]).count();
        assert!(errors <= 2, "errors={errors}");
    }

    #[test]
    fn linear_kernel_matches_linear_svm_behavior() {
        let mut rng = Rng::new(2);
        let mut x = Mat::zeros(60, 2);
        let mut y = Vec::new();
        for i in 0..60 {
            let cls = if i < 30 { 1.0 } else { -1.0 };
            x[(i, 0)] = cls * 2.0 + 0.4 * rng.normal();
            x[(i, 1)] = rng.normal();
            y.push(cls);
        }
        let svm = KernelSvm::train(
            &x,
            &y,
            KernelSvmConfig { kernel: Kernel::Linear, ..Default::default() },
        );
        let scores = svm.decision_batch(&x);
        let errors = (0..60).filter(|&i| scores[i].signum() != y[i]).count();
        assert_eq!(errors, 0);
    }

    #[test]
    fn support_vectors_are_subset() {
        let (x, labels) = concentric_shells(30, 3, 5);
        let y: Vec<f64> = labels.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let svm = KernelSvm::train(&x, &y, KernelSvmConfig::default());
        assert!(svm.support_x.rows() <= 60);
        assert!(svm.support_x.rows() > 0);
        assert_eq!(svm.support_x.rows(), svm.support_coef.len());
    }

    #[test]
    fn dual_constraint_satisfied() {
        // Σ α_i y_i ≈ 0 (KKT) — recover from stored coefficients
        let (x, labels) = concentric_shells(25, 2, 7);
        let y: Vec<f64> = labels.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let svm = KernelSvm::train(&x, &y, KernelSvmConfig::default());
        let s: f64 = svm.support_coef.iter().sum();
        assert!(s.abs() < 1e-6, "Σ α y = {s}");
    }
}
