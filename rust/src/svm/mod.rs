//! SVM substrate: the paper evaluates every DR method as DR + binary
//! linear SVM, with raw LSVM and KSVM as extra baseline columns
//! (Sec. 6.3). Implemented from scratch (no LIBSVM/LIBLINEAR offline).

pub mod kernel;
pub mod linear;

pub use kernel::{KernelSvm, KernelSvmConfig};
pub use linear::{LinearSvm, LinearSvmConfig};
