//! Binary linear SVM via dual coordinate descent (LIBLINEAR-style,
//! L2-regularized L1-loss). Every DR method in the paper's evaluation is
//! combined with exactly this classifier (Sec. 6.3: "one LSVM is trained
//! for each class in the discriminant subspace").

use crate::linalg::{dot, Mat};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LinearSvm {
    pub w: Vec<f64>,
    pub b: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct LinearSvmConfig {
    /// Penalty C (the paper's ς, CV-searched in {0.1, 1, 10, 100}).
    pub c: f64,
    pub max_iter: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Default for LinearSvmConfig {
    fn default() -> Self {
        LinearSvmConfig { c: 1.0, max_iter: 1000, tol: 1e-4, seed: 1 }
    }
}

impl LinearSvm {
    /// Train on rows of `x` with ±1 labels in `y` (dual coordinate descent
    /// on the L1-loss dual with box constraint 0 ≤ α ≤ C). A constant bias
    /// feature is appended internally.
    pub fn train(x: &Mat, y: &[f64], cfg: LinearSvmConfig) -> LinearSvm {
        let (n, d) = x.shape();
        assert_eq!(y.len(), n);
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let mut alpha = vec![0.0; n];
        let mut w = vec![0.0; d + 1]; // last component = bias (x augmented with 1)
        // Q_ii = x_i·x_i + 1 (bias feature)
        let qd: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i)) + 1.0).collect();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(cfg.seed);

        for _it in 0..cfg.max_iter {
            rng.shuffle(&mut order);
            let mut max_pg: f64 = 0.0;
            for &i in &order {
                let xi = x.row(i);
                // G = y_i (w·x_i + b) − 1
                let g = y[i] * (dot(&w[..d], xi) + w[d]) - 1.0;
                // projected gradient for the box constraint
                let pg = if alpha[i] <= 0.0 {
                    g.min(0.0)
                } else if alpha[i] >= cfg.c {
                    g.max(0.0)
                } else {
                    g
                };
                max_pg = max_pg.max(pg.abs());
                if pg.abs() > 1e-12 {
                    let old = alpha[i];
                    alpha[i] = (alpha[i] - g / qd[i]).clamp(0.0, cfg.c);
                    let delta = (alpha[i] - old) * y[i];
                    for (wj, &xj) in w[..d].iter_mut().zip(xi) {
                        *wj += delta * xj;
                    }
                    w[d] += delta;
                }
            }
            if max_pg < cfg.tol {
                break;
            }
        }
        let b = w[d];
        w.truncate(d);
        LinearSvm { w, b }
    }

    /// Decision value (confidence score, used directly for AP ranking).
    pub fn decision(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }

    pub fn decision_batch(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| self.decision(x.row(i))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn separable(n_per: usize, gap: f64, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let n = 2 * n_per;
        let mut x = Mat::zeros(n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = if i < n_per { 1.0 } else { -1.0 };
            x[(i, 0)] = cls * gap + 0.3 * rng.normal();
            x[(i, 1)] = rng.normal();
            y.push(cls);
        }
        (x, y)
    }

    #[test]
    fn separates_linearly_separable_data() {
        let (x, y) = separable(50, 2.0, 1);
        let svm = LinearSvm::train(&x, &y, LinearSvmConfig::default());
        let errors = (0..100)
            .filter(|&i| svm.decision(x.row(i)).signum() != y[i])
            .count();
        assert_eq!(errors, 0);
    }

    #[test]
    fn margin_direction_is_separating_axis() {
        let (x, y) = separable(80, 3.0, 2);
        let svm = LinearSvm::train(&x, &y, LinearSvmConfig::default());
        assert!(svm.w[0].abs() > 5.0 * svm.w[1].abs(), "w={:?}", svm.w);
    }

    #[test]
    fn small_c_softens_overlapping_data() {
        let (x, y) = separable(60, 0.3, 3); // heavy overlap
        for &c in &[0.1, 1.0, 10.0] {
            let svm = LinearSvm::train(
                &x, &y, LinearSvmConfig { c, ..Default::default() });
            let acc = (0..120)
                .filter(|&i| svm.decision(x.row(i)).signum() == y[i])
                .count() as f64
                / 120.0;
            assert!(acc > 0.6, "c={c} acc={acc}");
        }
    }

    #[test]
    fn biased_data_handled() {
        // both classes offset far from origin — bias must absorb it
        let (mut x, y) = separable(40, 2.0, 4);
        for i in 0..80 {
            x[(i, 1)] += 100.0;
        }
        let svm = LinearSvm::train(&x, &y, LinearSvmConfig::default());
        let errors = (0..80)
            .filter(|&i| svm.decision(x.row(i)).signum() != y[i])
            .count();
        assert!(errors <= 1, "errors={errors}");
    }

    #[test]
    fn deterministic_for_seed() {
        let (x, y) = separable(30, 1.0, 5);
        let a = LinearSvm::train(&x, &y, LinearSvmConfig::default());
        let b = LinearSvm::train(&x, &y, LinearSvmConfig::default());
        assert_eq!(a.w, b.w);
        assert_eq!(a.b, b.b);
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        let x = Mat::zeros(2, 2);
        LinearSvm::train(&x, &[0.0, 1.0], LinearSvmConfig::default());
    }
}
