//! Kernel functions and native Gram-matrix computation (Eq. 9).
//!
//! The accelerated path computes Gram matrices through the Pallas/PJRT
//! artifacts; this native implementation (a) serves the baselines, which
//! must pay the same 2N²F cost the paper charges them, and (b)
//! cross-checks the artifact numerics in the integration tests. The
//! `approx` subsystem sidesteps the N×N Gram entirely with explicit
//! feature maps whose inner products approximate these kernels; both
//! consume the same `Kernel` enum, so a method switches between exact,
//! approximate, and streaming training without touching kernel choice.

use crate::linalg::backend::{self, Backend};
use crate::linalg::mat::{dot, Mat};

/// Mercer kernel choice (Sec. 6.3.1 uses the Gaussian RBF as base kernel;
/// the toy example of Sec. 6.2 uses the linear kernel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    Linear,
    /// k(x, y) = exp(-rho * ||x - y||^2)
    Rbf { rho: f64 },
    /// k(x, y) = (x·y + c)^d
    Poly { degree: i32, c: f64 },
}

impl Kernel {
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        match *self {
            Kernel::Linear => dot(x, y),
            Kernel::Rbf { rho } => {
                let mut d2 = 0.0;
                for (a, b) in x.iter().zip(y) {
                    let d = a - b;
                    d2 += d * d;
                }
                (-rho * d2).exp()
            }
            Kernel::Poly { degree, c } => (dot(x, y) + c).powi(degree),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Poly { .. } => "poly",
        }
    }

    /// RBF bandwidth if applicable (what the PJRT artifacts take as `rho`).
    pub fn rho(&self) -> f64 {
        match *self {
            Kernel::Rbf { rho } => rho,
            _ => 0.0,
        }
    }
}

/// Gram matrix K[i,j] = k(x_i, x_j) of the rows of `x`, tiled over row
/// stripes by the globally selected `linalg::backend` and exploiting
/// symmetry (only the upper triangle is computed).
pub fn gram(x: &Mat, kernel: Kernel) -> Mat {
    gram_with(x, kernel, backend::active(x.rows()))
}

/// [`gram`] on an explicit backend. Each entry is a single closed-form
/// expression (one `dot` plus the kernel arithmetic), so every tile
/// schedule — scalar, blocked, parallel, any pool size — produces
/// identical bits; the sequential mirror step below never reads a
/// partially written stripe because the backend joins all tiles first.
pub fn gram_with(x: &Mat, kernel: Kernel, backend: &dyn Backend) -> Mat {
    let _phase = crate::obs::span("gram");
    let _backend = crate::obs::span(backend.kind().name());
    let n = x.rows();
    let mut k = Mat::zeros(n, n);
    // For RBF, precompute squared norms once: d2 = ni + nj - 2 x_i·x_j.
    let sq: Vec<f64> = match kernel {
        Kernel::Rbf { .. } => (0..n).map(|i| dot(x.row(i), x.row(i))).collect(),
        _ => Vec::new(),
    };
    let sq = &sq;
    backend.for_row_stripes(k.data_mut(), n, &|r0, stripe| {
        for (dr, krow) in stripe.chunks_mut(n).enumerate() {
            let i = r0 + dr;
            let xi = x.row(i);
            for (j, kv) in krow.iter_mut().enumerate().skip(i) {
                *kv = match kernel {
                    Kernel::Rbf { rho } => {
                        let g = dot(xi, x.row(j));
                        let d2 = (sq[i] + sq[j] - 2.0 * g).max(0.0);
                        (-rho * d2).exp()
                    }
                    _ => kernel.eval(xi, x.row(j)),
                };
            }
        }
    });
    // Mirror the computed upper triangle into the lower one for EVERY
    // kernel type — callers (Cholesky, centering, projections) read
    // K[(j, i)] and must never see the unwritten half.
    for i in 0..n {
        for j in (i + 1)..n {
            k[(j, i)] = k[(i, j)];
        }
    }
    k
}

/// Cross kernel K[e,t] = k(test_e, train_t) (Eq. 11, batched over rows)
/// on the globally selected `linalg::backend`. This is the O(N·m) hot
/// loop of `NystromMap::transform` (N test rows against m landmarks).
pub fn cross_gram(x_test: &Mat, x_train: &Mat, kernel: Kernel) -> Mat {
    cross_gram_with(x_test, x_train, kernel, backend::active(x_test.rows()))
}

/// [`cross_gram`] on an explicit backend; one `kernel.eval` per output
/// element, so tile-schedule invariant like [`gram_with`].
pub fn cross_gram_with(
    x_test: &Mat,
    x_train: &Mat,
    kernel: Kernel,
    backend: &dyn Backend,
) -> Mat {
    let (ne, nt) = (x_test.rows(), x_train.rows());
    let mut k = Mat::zeros(ne, nt);
    backend.for_row_stripes(k.data_mut(), nt, &|r0, stripe| {
        for (dr, krow) in stripe.chunks_mut(nt).enumerate() {
            let xe = x_test.row(r0 + dr);
            for (t, kv) in krow.iter_mut().enumerate() {
                *kv = kernel.eval(xe, x_train.row(t));
            }
        }
    });
    k
}

/// Centered kernel matrix K̄ (Eq. 21) — required by GDA/SRKDA/GSDA.
pub fn center_gram(k: &Mat) -> Mat {
    let n = k.rows();
    let inv = 1.0 / n as f64;
    // row means, col means (symmetric input, but keep it general), total
    let row_mean: Vec<f64> = (0..n)
        .map(|i| k.row(i).iter().sum::<f64>() * inv)
        .collect();
    let col_mean: Vec<f64> = (0..n).map(|j| (0..n).map(|i| k[(i, j)]).sum::<f64>() * inv).collect();
    let total: f64 = row_mean.iter().sum::<f64>() * inv;
    Mat::from_fn(n, n, |i, j| k[(i, j)] - row_mean[i] - col_mean[j] + total)
}

/// Center a cross-kernel block against the training kernel's statistics
/// (the testing-phase normalization of Eq. 22, extended to full centering).
pub fn center_cross(k_cross: &Mat, k_train: &Mat) -> Mat {
    let (ne, n) = k_cross.shape();
    let inv = 1.0 / n as f64;
    let train_col_mean: Vec<f64> =
        (0..n).map(|j| (0..n).map(|i| k_train[(i, j)]).sum::<f64>() * inv).collect();
    let total: f64 = train_col_mean.iter().sum::<f64>() * inv;
    let cross_row_mean: Vec<f64> =
        (0..ne).map(|e| k_cross.row(e).iter().sum::<f64>() * inv).collect();
    Mat::from_fn(ne, n, |e, j| {
        k_cross[(e, j)] - cross_row_mean[e] - train_col_mean[j] + total
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn gram_linear_is_xxt() {
        let x = randmat(20, 5, 1);
        let k = gram(&x, Kernel::Linear);
        assert!(k.sub(&x.matmul_nt(&x)).max_abs() < 1e-9);
    }

    #[test]
    fn gram_rbf_properties() {
        let x = randmat(30, 4, 2);
        let k = gram(&x, Kernel::Rbf { rho: 0.5 });
        for i in 0..30 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
            for j in 0..30 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12);
                assert!(k[(i, j)] > 0.0 && k[(i, j)] <= 1.0 + 1e-12);
            }
        }
        // matches scalar evaluation
        assert!((k[(3, 7)] - Kernel::Rbf { rho: 0.5 }.eval(x.row(3), x.row(7))).abs() < 1e-10);
    }

    #[test]
    fn cross_gram_matches_eval() {
        let xe = randmat(7, 3, 3);
        let xt = randmat(11, 3, 4);
        let k = cross_gram(&xe, &xt, Kernel::Rbf { rho: 0.2 });
        for e in 0..7 {
            for t in 0..11 {
                let want = Kernel::Rbf { rho: 0.2 }.eval(xe.row(e), xt.row(t));
                assert!((k[(e, t)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_lower_triangle_is_mirrored_for_all_kernels() {
        // Regression: only the upper triangle is computed in the threaded
        // sweep; the lower triangle must be mirrored (not left zero) for
        // every kernel type, Poly included.
        let x = randmat(17, 4, 8);
        for kernel in [
            Kernel::Linear,
            Kernel::Rbf { rho: 0.7 },
            Kernel::Poly { degree: 3, c: 0.5 },
        ] {
            let k = gram(&x, kernel);
            for i in 0..17 {
                for j in 0..i {
                    assert!(
                        (k[(i, j)] - k[(j, i)]).abs() < 1e-12,
                        "{}: K[({i},{j})] asymmetric",
                        kernel.name()
                    );
                    let want = kernel.eval(x.row(i), x.row(j));
                    assert!(
                        (k[(i, j)] - want).abs() < 1e-9 * (1.0 + want.abs()),
                        "{}: lower triangle entry ({i},{j}) = {} want {}",
                        kernel.name(),
                        k[(i, j)],
                        want
                    );
                }
            }
        }
    }

    #[test]
    fn poly_kernel_eval() {
        let k = Kernel::Poly { degree: 2, c: 1.0 };
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 144.0).abs() < 1e-12);
    }

    #[test]
    fn centered_gram_rows_sum_to_zero() {
        let x = randmat(25, 6, 5);
        let k = gram(&x, Kernel::Rbf { rho: 1.0 });
        let kc = center_gram(&k);
        for i in 0..25 {
            let rs: f64 = kc.row(i).iter().sum();
            assert!(rs.abs() < 1e-9);
        }
        // equals the explicit formula (Eq. 21)
        let n = 25.0;
        let j = Mat::from_fn(25, 25, |_, _| 1.0 / n);
        let want = k
            .sub(&k.matmul(&j))
            .sub(&j.matmul(&k))
            .add(&j.matmul(&k).matmul(&j));
        assert!(kc.sub(&want).max_abs() < 1e-9);
    }

    #[test]
    fn centered_cross_consistent_with_train_centering() {
        // centering the train block through center_cross must equal
        // center_gram on the train kernel
        let x = randmat(18, 4, 7);
        let k = gram(&x, Kernel::Rbf { rho: 0.3 });
        let via_cross = center_cross(&k, &k);
        let via_gram = center_gram(&k);
        assert!(via_cross.sub(&via_gram).max_abs() < 1e-9);
    }
}
