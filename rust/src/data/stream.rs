//! Out-of-core block streaming: feed row-tiles of a labelled dataset to
//! consumers that never need the whole N×F matrix resident.
//!
//! The streaming AKDA path (`da::akda_stream`) only ever touches one tile
//! of B rows at a time — it accumulates the m×m Gram ΦᵀΦ and the m×C
//! class sums ΦᵀR block by block — so a [`BlockSource`] is all it needs
//! from the data layer:
//!
//! * [`MemBlockSource`] — chunked adapter over an in-memory `Mat` (the
//!   coordinator's `Split`s), used to bound peak memory of the Φ pipeline
//!   and to test streaming ≡ in-memory equivalence;
//! * [`CsvBlockSource`] — reads the `data::csv` `label,f1,f2,...` format
//!   tile by tile without ever loading the whole file, the genuine
//!   N ≫ RAM path.
//!
//! Sources are rewindable ([`BlockSource::reset`]) because a streaming fit
//! may traverse the data more than once: a reservoir-sampling pass to pick
//! Nyström landmarks ([`reservoir_sample`]), then the accumulation pass.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::csv::parse_labeled_line;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Default tile height B for the streaming paths: large enough that the
/// per-block transform amortizes, small enough that a B×m tile of f64
/// features stays well under typical cache/RAM budgets.
pub const DEFAULT_BLOCK_ROWS: usize = 1024;

/// One tile of a labelled dataset: `x.rows() == labels.len()`.
#[derive(Debug, Clone)]
pub struct LabeledBlock {
    pub x: Mat,
    pub labels: Vec<usize>,
}

/// A rewindable supplier of row-tiles. Implementors yield the dataset in
/// row order, each block at most the configured tile height; the streaming
/// accumulator's results are independent of where the block boundaries
/// fall (see `linalg::accumulate_tn`).
pub trait BlockSource {
    /// Feature dimensionality F — constant across blocks.
    fn n_features(&self) -> usize;
    /// Rewind to the first row so the stream can be traversed again.
    fn reset(&mut self) -> Result<()>;
    /// Next tile, or `None` once the stream is exhausted.
    fn next_block(&mut self) -> Result<Option<LabeledBlock>>;
}

/// Chunked in-memory adapter: streams an already-resident matrix in tiles
/// of `block_rows`, so downstream consumers exercise the exact same tiled
/// code path as the out-of-core sources.
pub struct MemBlockSource<'a> {
    x: &'a Mat,
    labels: &'a [usize],
    block_rows: usize,
    pos: usize,
}

impl<'a> MemBlockSource<'a> {
    pub fn new(x: &'a Mat, labels: &'a [usize], block_rows: usize) -> Self {
        assert_eq!(x.rows(), labels.len(), "rows/labels length mismatch");
        assert!(block_rows >= 1, "block_rows must be >= 1");
        MemBlockSource { x, labels, block_rows, pos: 0 }
    }
}

impl BlockSource for MemBlockSource<'_> {
    fn n_features(&self) -> usize {
        self.x.cols()
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn next_block(&mut self) -> Result<Option<LabeledBlock>> {
        if self.pos >= self.x.rows() {
            return Ok(None);
        }
        let nr = self.block_rows.min(self.x.rows() - self.pos);
        let block = LabeledBlock {
            x: self.x.submatrix(self.pos, 0, nr, self.x.cols()),
            labels: self.labels[self.pos..self.pos + nr].to_vec(),
        };
        self.pos += nr;
        Ok(Some(block))
    }
}

/// Streaming reader for the `data::csv::load_labeled` format
/// (`label,f1,f2,...` lines, `#` comments and blanks skipped): holds one
/// tile of at most `block_rows` parsed rows plus one line buffer — the
/// file is never resident. `reset` reopens the file.
pub struct CsvBlockSource {
    path: PathBuf,
    block_rows: usize,
    n_features: usize,
    reader: BufReader<File>,
    lineno: usize,
}

impl CsvBlockSource {
    /// Open `path`, peeking the first data line to learn F, then rewind.
    pub fn open(path: &Path, block_rows: usize) -> Result<Self> {
        anyhow::ensure!(block_rows >= 1, "block_rows must be >= 1");
        let mut src = CsvBlockSource {
            path: path.to_path_buf(),
            block_rows,
            n_features: 0,
            reader: open_reader(path)?,
            lineno: 0,
        };
        let first = src
            .next_row()?
            .with_context(|| format!("empty dataset {path:?}"))?;
        src.n_features = first.1.len();
        anyhow::ensure!(src.n_features > 0, "no features on first data line of {path:?}");
        src.reset()?;
        Ok(src)
    }

    /// Next parsed data row (skipping blanks/comments), or `None` at EOF.
    fn next_row(&mut self) -> Result<Option<(usize, Vec<f64>)>> {
        let mut line = String::new();
        loop {
            line.clear();
            self.lineno += 1;
            let n = self
                .reader
                .read_line(&mut line)
                .with_context(|| format!("read {:?} line {}", self.path, self.lineno))?;
            if n == 0 {
                return Ok(None);
            }
            if let Some(row) = parse_labeled_line(&line, self.lineno)? {
                return Ok(Some(row));
            }
        }
    }
}

fn open_reader(path: &Path) -> Result<BufReader<File>> {
    Ok(BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    ))
}

impl BlockSource for CsvBlockSource {
    fn n_features(&self) -> usize {
        self.n_features
    }

    fn reset(&mut self) -> Result<()> {
        self.reader = open_reader(&self.path)?;
        self.lineno = 0;
        Ok(())
    }

    fn next_block(&mut self) -> Result<Option<LabeledBlock>> {
        // cap the pre-allocation hint: an oversized block_rows must not
        // abort on a small file — the Vec grows if the tile really is huge
        let rows_hint = self.block_rows.min(64 * 1024);
        let mut data = Vec::with_capacity(rows_hint * self.n_features);
        let mut labels = Vec::with_capacity(rows_hint);
        while labels.len() < self.block_rows {
            let Some((label, feats)) = self.next_row()? else { break };
            anyhow::ensure!(
                feats.len() == self.n_features,
                "inconsistent feature count on line {} of {:?} (got {}, want {})",
                self.lineno,
                self.path,
                feats.len(),
                self.n_features
            );
            labels.push(label);
            data.extend(feats);
        }
        if labels.is_empty() {
            return Ok(None);
        }
        let x = Mat::from_vec(labels.len(), self.n_features, data);
        Ok(Some(LabeledBlock { x, labels }))
    }
}

/// Uniform reservoir sample (Algorithm R) of up to `cap` rows from a
/// stream — O(cap·F) memory however long the stream is. This is how the
/// streaming Nyström path picks its landmark-fitting subset without
/// materializing X.
pub fn reservoir_sample(source: &mut dyn BlockSource, cap: usize, seed: u64) -> Result<Mat> {
    anyhow::ensure!(cap >= 1, "reservoir cap must be >= 1");
    let f = source.n_features();
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut seen = 0usize;
    let mut rng = Rng::new(seed);
    source.reset()?;
    while let Some(block) = source.next_block()? {
        for r in 0..block.x.rows() {
            seen += 1;
            if rows.len() < cap {
                rows.push(block.x.row(r).to_vec());
            } else {
                let j = rng.below(seen);
                if j < cap {
                    rows[j] = block.x.row(r).to_vec();
                }
            }
        }
    }
    anyhow::ensure!(seen > 0, "cannot sample from an empty source");
    let mut data = Vec::with_capacity(rows.len() * f);
    let n = rows.len();
    for row in rows {
        data.extend(row);
    }
    Ok(Mat::from_vec(n, f, data))
}

/// A resumable labeled reservoir (Algorithm R over `(row, label)` pairs):
/// a uniform sample of every observation ever absorbed, in O(cap·F)
/// memory, that can be persisted and *continued* — absorb more rows later
/// and the reservoir is still a uniform sample of the whole history. The
/// model subsystem stores one per approximate model (`resume.reservoir`
/// sections) so `akda update` can refresh landmarks and re-train the OvR
/// SVM bank from a bounded, drift-tracking subsample instead of the full
/// (unavailable) training history.
#[derive(Debug, Clone)]
pub struct LabeledReservoir {
    cap: usize,
    rows: Vec<Vec<f64>>,
    labels: Vec<usize>,
    /// Total observations ever offered (the Algorithm R denominator).
    seen: usize,
    rng: Rng,
}

impl LabeledReservoir {
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap >= 1, "reservoir cap must be >= 1");
        LabeledReservoir { cap, rows: Vec::new(), labels: Vec::new(), seen: 0, rng: Rng::new(seed) }
    }

    /// Resume a persisted reservoir: the stored rows/labels plus the
    /// running `seen` count. `seed` re-seeds the replacement RNG (the
    /// uniformity guarantee needs `seen`, not the original RNG state).
    ///
    /// The cap can change across a resume without breaking uniformity,
    /// within what the stored sample supports: shrinking takes a uniform
    /// subsample of the stored rows (uniform-of-uniform stays uniform);
    /// growing only applies while the reservoir has never overflowed
    /// (`seen == stored rows`) — once rows have been discarded, the
    /// effective cap is clamped to the stored row count, because admitting
    /// new rows into the freed slots with probability 1 would bias the
    /// "uniform over the whole history" sample toward the newest batch.
    pub fn from_parts(x: &Mat, labels: &[usize], seen: usize, cap: usize, seed: u64) -> Result<Self> {
        anyhow::ensure!(cap >= 1, "reservoir cap must be >= 1");
        anyhow::ensure!(
            x.rows() == labels.len(),
            "reservoir state mismatch: {} rows vs {} labels",
            x.rows(),
            labels.len()
        );
        anyhow::ensure!(
            seen >= x.rows(),
            "reservoir state mismatch: seen {} < stored rows {}",
            seen,
            x.rows()
        );
        let mut rng = Rng::new(seed);
        let mut rows: Vec<Vec<f64>> = (0..x.rows()).map(|r| x.row(r).to_vec()).collect();
        let mut labels = labels.to_vec();
        if cap < rows.len() {
            // partial Fisher-Yates: keep a uniform cap-subset of the rows
            for i in 0..cap {
                let j = i + rng.below(rows.len() - i);
                rows.swap(i, j);
                labels.swap(i, j);
            }
            rows.truncate(cap);
            labels.truncate(cap);
        }
        let cap = if seen > x.rows() { cap.min(rows.len()) } else { cap };
        Ok(LabeledReservoir { cap, rows, labels, seen, rng })
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total observations ever offered to the reservoir.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// Offer one labelled observation (kept with probability cap/seen).
    pub fn offer(&mut self, row: &[f64], label: usize) {
        self.seen += 1;
        if self.rows.len() < self.cap {
            self.rows.push(row.to_vec());
            self.labels.push(label);
        } else {
            let j = self.rng.below(self.seen);
            if j < self.cap {
                self.rows[j] = row.to_vec();
                self.labels[j] = label;
            }
        }
    }

    /// Offer every row of a labelled tile.
    pub fn absorb(&mut self, block: &LabeledBlock) {
        for r in 0..block.x.rows() {
            self.offer(block.x.row(r), block.labels[r]);
        }
    }

    /// Snapshot the current sample as a matrix + label vector.
    pub fn snapshot(&self) -> Result<(Mat, Vec<usize>)> {
        anyhow::ensure!(!self.rows.is_empty(), "reservoir is empty");
        let f = self.rows[0].len();
        let n = self.rows.len();
        let mut data = Vec::with_capacity(n * f);
        for row in &self.rows {
            data.extend_from_slice(row);
        }
        Ok((Mat::from_vec(n, f, data), self.labels.clone()))
    }
}

impl LabeledReservoir {
    /// Deterministic weighted union of two reservoirs: a bounded sample of
    /// the two histories *combined*, built without revisiting either
    /// stream. Each stored row of `self` stands for `seen/len` history
    /// rows, so the merge repeatedly draws the next row from `self` with
    /// probability proportional to its remaining represented mass
    /// (`seen_a·len_b·(len_a−taken_a)` against the mirror-image weight for
    /// `other` — both integers, no floating-point in the draw). The result
    /// is deterministic in `(self, other, cap, seed)`; the shard-merge
    /// path exploits that by folding shards in a canonical order so any
    /// merge tree produces bit-identical output.
    pub fn merge(&self, other: &LabeledReservoir, cap: usize, seed: u64) -> Result<LabeledReservoir> {
        anyhow::ensure!(cap >= 1, "reservoir cap must be >= 1");
        if let (Some(a), Some(b)) = (self.rows.first(), other.rows.first()) {
            anyhow::ensure!(
                a.len() == b.len(),
                "reservoir merge width mismatch: {} vs {} features",
                a.len(),
                b.len()
            );
        }
        let (la, lb) = (self.rows.len(), other.rows.len());
        let take = cap.min(la + lb);
        let mut rng = Rng::new(seed);
        let (mut ia, mut ib) = (0usize, 0usize);
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(take);
        let mut labels: Vec<usize> = Vec::with_capacity(take);
        while rows.len() < take {
            let wa = if ia < la { self.seen * lb.max(1) * (la - ia) } else { 0 };
            let wb = if ib < lb { other.seen * la.max(1) * (lb - ib) } else { 0 };
            let from_a = match (wa, wb) {
                (0, 0) => break,
                (_, 0) => true,
                (0, _) => false,
                _ => rng.below(wa + wb) < wa,
            };
            if from_a {
                rows.push(self.rows[ia].clone());
                labels.push(self.labels[ia]);
                ia += 1;
            } else {
                rows.push(other.rows[ib].clone());
                labels.push(other.labels[ib]);
                ib += 1;
            }
        }
        let seen = self.seen + other.seen;
        // same clamp rule as `from_parts`: once rows have been discarded,
        // the effective cap is the stored row count
        let cap = if seen > rows.len() { cap.min(rows.len().max(1)) } else { cap };
        Ok(LabeledReservoir { cap, rows, labels, seen, rng })
    }
}

/// Restriction of a [`BlockSource`] to one stride class: yields exactly
/// the rows whose global (0-based) row index `g` satisfies
/// `g % count == index`, in the original row order. This is the shard-`i`
/// view of a stream for `akda train --shard i/k` — the `k` stride classes
/// partition the stream, so the union of the `k` shard accumulators over
/// a [`StridedBlockSource`] equals one accumulator over the whole stream.
pub struct StridedBlockSource<S: BlockSource> {
    inner: S,
    index: usize,
    count: usize,
    /// Global row index of the next row the inner source will yield.
    next_row: usize,
}

impl<S: BlockSource> StridedBlockSource<S> {
    pub fn new(inner: S, index: usize, count: usize) -> Result<Self> {
        anyhow::ensure!(count >= 1, "shard count must be >= 1");
        anyhow::ensure!(
            index < count,
            "shard index {index} out of range for {count} shards"
        );
        Ok(StridedBlockSource { inner, index, count, next_row: 0 })
    }

    /// The wrapped source, e.g. to rewind it for a separate full-stream
    /// pass (landmark fitting sees the whole stream; only the
    /// accumulation is sharded).
    pub fn inner_mut(&mut self) -> &mut S {
        &mut self.inner
    }
}

impl<S: BlockSource> BlockSource for StridedBlockSource<S> {
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }

    fn reset(&mut self) -> Result<()> {
        self.next_row = 0;
        self.inner.reset()
    }

    fn next_block(&mut self) -> Result<Option<LabeledBlock>> {
        loop {
            let Some(block) = self.inner.next_block()? else { return Ok(None) };
            let base = self.next_row;
            self.next_row += block.x.rows();
            let keep: Vec<usize> = (0..block.x.rows())
                .filter(|r| (base + r) % self.count == self.index)
                .collect();
            if keep.is_empty() {
                continue; // tile held no shard-`index` rows; try the next
            }
            let x = block.x.select_rows(&keep);
            let labels = keep.iter().map(|&r| block.labels[r]).collect();
            return Ok(Some(LabeledBlock { x, labels }));
        }
    }
}

/// Labeled mirror of [`reservoir_sample`]: one pass over the stream into a
/// fresh [`LabeledReservoir`], returning the sampled rows, their labels,
/// and the total row count seen.
pub fn reservoir_sample_labeled(
    source: &mut dyn BlockSource,
    cap: usize,
    seed: u64,
) -> Result<(Mat, Vec<usize>, usize)> {
    anyhow::ensure!(cap >= 1, "reservoir cap must be >= 1");
    let mut res = LabeledReservoir::new(cap, seed);
    source.reset()?;
    while let Some(block) = source.next_block()? {
        res.absorb(&block);
    }
    anyhow::ensure!(res.seen() > 0, "cannot sample from an empty source");
    let seen = res.seen();
    let (x, labels) = res.snapshot()?;
    Ok((x, labels, seen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csv::{load_labeled, save_labeled};
    use crate::util::rng::Rng as TestRng;

    fn toy(n: usize, f: usize, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = TestRng::new(seed);
        let x = Mat::from_fn(n, f, |_, _| rng.normal());
        let labels: Vec<usize> = (0..n).map(|i| i % 3).collect();
        (x, labels)
    }

    /// Drain a source and splice the tiles back together.
    fn drain(source: &mut dyn BlockSource) -> (Mat, Vec<usize>, Vec<usize>) {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut labels = Vec::new();
        let mut block_sizes = Vec::new();
        source.reset().unwrap();
        while let Some(b) = source.next_block().unwrap() {
            assert_eq!(b.x.rows(), b.labels.len());
            block_sizes.push(b.x.rows());
            for r in 0..b.x.rows() {
                rows.push(b.x.row(r).to_vec());
            }
            labels.extend(b.labels);
        }
        let f = source.n_features();
        let n = rows.len();
        let mut data = Vec::with_capacity(n * f);
        for row in rows {
            data.extend(row);
        }
        (Mat::from_vec(n, f, data), labels, block_sizes)
    }

    #[test]
    fn mem_source_tiles_cover_the_matrix() {
        let (x, labels) = toy(23, 4, 1);
        for block in [1usize, 7, 23, 100] {
            let mut src = MemBlockSource::new(&x, &labels, block);
            let (x2, l2, sizes) = drain(&mut src);
            assert!(x2.sub(&x).max_abs() == 0.0, "block={block}");
            assert_eq!(l2, labels);
            assert!(sizes.iter().all(|&s| s <= block));
            assert_eq!(sizes.iter().sum::<usize>(), 23);
            // rewind works: second traversal yields the same tiles
            let (x3, l3, _) = drain(&mut src);
            assert!(x3.sub(&x).max_abs() == 0.0);
            assert_eq!(l3, labels);
        }
    }

    #[test]
    fn csv_source_round_trips_against_load_labeled() {
        let dir = std::env::temp_dir().join("akda_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt_stream.csv");
        let (x, labels) = toy(31, 5, 2);
        save_labeled(&path, &x, &labels).unwrap();
        let (x_mem, l_mem) = load_labeled(&path).unwrap();
        for block in [1usize, 7, 31, 64] {
            let mut src = CsvBlockSource::open(&path, block).unwrap();
            assert_eq!(src.n_features(), 5);
            let (x_st, l_st, _) = drain(&mut src);
            assert!(x_st.sub(&x_mem).max_abs() == 0.0, "block={block}");
            assert_eq!(l_st, l_mem);
        }
    }

    #[test]
    fn csv_source_skips_comments_and_rejects_ragged_rows() {
        let dir = std::env::temp_dir().join("akda_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comments_stream.csv");
        std::fs::write(&path, "# header\n\n0,1.0,2.0\n1,3.0,4.0\n").unwrap();
        let mut src = CsvBlockSource::open(&path, 8).unwrap();
        let (x, l, _) = drain(&mut src);
        assert_eq!(x.shape(), (2, 2));
        assert_eq!(l, vec![0, 1]);

        let ragged = dir.join("ragged_stream.csv");
        std::fs::write(&ragged, "0,1.0,2.0\n1,3.0\n").unwrap();
        let mut src = CsvBlockSource::open(&ragged, 8).unwrap();
        src.reset().unwrap();
        assert!(src.next_block().is_err());
    }

    #[test]
    fn csv_open_rejects_empty_files() {
        let dir = std::env::temp_dir().join("akda_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty_stream.csv");
        std::fs::write(&path, "# only comments\n\n").unwrap();
        assert!(CsvBlockSource::open(&path, 8).is_err());
    }

    #[test]
    fn reservoir_keeps_everything_when_it_fits() {
        let (x, labels) = toy(12, 3, 3);
        let mut src = MemBlockSource::new(&x, &labels, 5);
        let sample = reservoir_sample(&mut src, 50, 7).unwrap();
        assert!(sample.sub(&x).max_abs() == 0.0, "cap >= N keeps rows in order");
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic() {
        let (x, labels) = toy(40, 3, 4);
        let mut src = MemBlockSource::new(&x, &labels, 9);
        let a = reservoir_sample(&mut src, 10, 11).unwrap();
        let b = reservoir_sample(&mut src, 10, 11).unwrap();
        assert_eq!(a.shape(), (10, 3));
        assert!(a.sub(&b).max_abs() == 0.0, "same seed, same sample");
        // every sampled row is a row of x
        for r in 0..a.rows() {
            let found = (0..x.rows()).any(|i| {
                x.row(i).iter().zip(a.row(r)).all(|(p, q)| p == q)
            });
            assert!(found, "sample row {r} not from the stream");
        }
    }

    #[test]
    fn labeled_reservoir_keeps_rows_with_their_labels() {
        let (x, labels) = toy(30, 3, 5);
        let mut src = MemBlockSource::new(&x, &labels, 7);
        let (sample, slabels, seen) = reservoir_sample_labeled(&mut src, 8, 21).unwrap();
        assert_eq!(seen, 30);
        assert_eq!((sample.rows(), slabels.len()), (8, 8));
        // every sampled (row, label) pair exists in the stream
        for r in 0..sample.rows() {
            let found = (0..x.rows()).any(|i| {
                labels[i] == slabels[r]
                    && x.row(i).iter().zip(sample.row(r)).all(|(p, q)| p == q)
            });
            assert!(found, "sample pair {r} not from the stream");
        }
    }

    #[test]
    fn labeled_reservoir_resumes_from_parts() {
        let (x, labels) = toy(24, 3, 6);
        // one continuous reservoir over all 24 rows
        let mut full = LabeledReservoir::new(6, 9);
        let mut src = MemBlockSource::new(&x, &labels, 4);
        src.reset().unwrap();
        while let Some(b) = src.next_block().unwrap() {
            full.absorb(&b);
        }
        assert_eq!(full.seen(), 24);
        // a persisted-then-resumed reservoir keeps seen and stays bounded
        let (snap_x, snap_l) = full.snapshot().unwrap();
        let mut resumed =
            LabeledReservoir::from_parts(&snap_x, &snap_l, full.seen(), 6, 10).unwrap();
        let (x2, labels2) = toy(12, 3, 7);
        let mut src2 = MemBlockSource::new(&x2, &labels2, 5);
        src2.reset().unwrap();
        while let Some(b) = src2.next_block().unwrap() {
            resumed.absorb(&b);
        }
        assert_eq!(resumed.seen(), 36);
        assert_eq!(resumed.len(), 6);
        // bad persisted state is rejected
        assert!(LabeledReservoir::from_parts(&snap_x, &snap_l[..3], 24, 6, 1).is_err());
        assert!(LabeledReservoir::from_parts(&snap_x, &snap_l, 2, 6, 1).is_err());
    }

    #[test]
    fn strided_sources_partition_the_stream_in_order() {
        let (x, labels) = toy(29, 3, 14);
        for count in [1usize, 2, 3, 7] {
            let mut covered: Vec<usize> = Vec::new();
            for index in 0..count {
                let inner = MemBlockSource::new(&x, &labels, 4);
                let mut src = StridedBlockSource::new(inner, index, count).unwrap();
                let (sx, sl, _) = drain(&mut src);
                // shard `index` holds exactly the rows g ≡ index (mod count)
                let want: Vec<usize> = (0..x.rows()).filter(|g| g % count == index).collect();
                assert_eq!(sl.len(), want.len(), "count={count} index={index}");
                for (r, &g) in want.iter().enumerate() {
                    assert_eq!(sl[r], labels[g]);
                    assert!(sx.row(r).iter().zip(x.row(g)).all(|(p, q)| p == q));
                    covered.push(g);
                }
            }
            // the k stride classes partition the stream exactly
            covered.sort_unstable();
            covered.dedup();
            assert_eq!(covered.len(), x.rows(), "count={count}: not a partition");
        }
        // k=1 is the identity view
        let mut ident = StridedBlockSource::new(MemBlockSource::new(&x, &labels, 5), 0, 1).unwrap();
        let (ix, il, _) = drain(&mut ident);
        assert!(ix.sub(&x).max_abs() == 0.0);
        assert_eq!(il, labels);
        // bad shard specs are rejected
        assert!(StridedBlockSource::new(MemBlockSource::new(&x, &labels, 5), 2, 2).is_err());
        assert!(StridedBlockSource::new(MemBlockSource::new(&x, &labels, 5), 0, 0).is_err());
    }

    #[test]
    fn reservoir_merge_is_bounded_deterministic_and_from_the_streams() {
        let (xa, la) = toy(40, 3, 15);
        let (xb, lb) = toy(25, 3, 16);
        let mut ra = LabeledReservoir::new(10, 1);
        let mut sa = MemBlockSource::new(&xa, &la, 7);
        sa.reset().unwrap();
        while let Some(b) = sa.next_block().unwrap() {
            ra.absorb(&b);
        }
        let mut rb = LabeledReservoir::new(10, 2);
        let mut sb = MemBlockSource::new(&xb, &lb, 7);
        sb.reset().unwrap();
        while let Some(b) = sb.next_block().unwrap() {
            rb.absorb(&b);
        }
        let merged = ra.merge(&rb, 12, 5).unwrap();
        assert_eq!(merged.seen(), 65);
        assert_eq!(merged.len(), 12);
        let again = ra.merge(&rb, 12, 5).unwrap();
        let (mx, ml) = merged.snapshot().unwrap();
        let (ax, al2) = again.snapshot().unwrap();
        assert!(mx.sub(&ax).max_abs() == 0.0, "same inputs+seed, same merge");
        assert_eq!(ml, al2);
        // every merged (row, label) pair came from one of the two streams
        for r in 0..mx.rows() {
            let in_a = (0..xa.rows()).any(|i| {
                la[i] == ml[r] && xa.row(i).iter().zip(mx.row(r)).all(|(p, q)| p == q)
            });
            let in_b = (0..xb.rows()).any(|i| {
                lb[i] == ml[r] && xb.row(i).iter().zip(mx.row(r)).all(|(p, q)| p == q)
            });
            assert!(in_a || in_b, "merged row {r} from neither stream");
        }
        // a merge that fits both reservoirs keeps everything
        let all = ra.merge(&rb, 64, 9).unwrap();
        assert_eq!(all.len(), 20);
        // width mismatch is rejected
        let (xw, lw) = (Mat::from_fn(4, 5, |i, j| (i + j) as f64), vec![0, 1, 0, 1]);
        let mut rw = LabeledReservoir::new(4, 3);
        let mut sw = MemBlockSource::new(&xw, &lw, 2);
        sw.reset().unwrap();
        while let Some(b) = sw.next_block().unwrap() {
            rw.absorb(&b);
        }
        assert!(ra.merge(&rw, 8, 1).is_err());
    }

    #[test]
    fn resumed_reservoir_cap_changes_stay_uniform() {
        let (x, labels) = toy(24, 3, 6);
        let mut full = LabeledReservoir::new(8, 9);
        let mut src = MemBlockSource::new(&x, &labels, 4);
        src.reset().unwrap();
        while let Some(b) = src.next_block().unwrap() {
            full.absorb(&b);
        }
        let (snap_x, snap_l) = full.snapshot().unwrap();

        // shrink: a uniform subsample of the stored rows, paired correctly
        let shrunk = LabeledReservoir::from_parts(&snap_x, &snap_l, full.seen(), 3, 11).unwrap();
        assert_eq!(shrunk.len(), 3);
        let (kept_x, kept_l) = shrunk.snapshot().unwrap();
        for r in 0..kept_x.rows() {
            let found = (0..snap_x.rows()).any(|i| {
                snap_l[i] == kept_l[r]
                    && snap_x.row(i).iter().zip(kept_x.row(r)).all(|(p, q)| p == q)
            });
            assert!(found, "shrunk row {r} is not one of the stored (row, label) pairs");
        }

        // growing an overflowed reservoir is clamped: admitting new rows
        // into freed slots with probability 1 would bias the sample
        let mut grown = LabeledReservoir::from_parts(&snap_x, &snap_l, full.seen(), 64, 12).unwrap();
        grown.offer(x.row(0), labels[0]);
        assert_eq!(grown.len(), snap_x.rows(), "overflowed reservoir must not grow");

        // growing a never-overflowed reservoir (seen == stored) is fine
        let mut fresh =
            LabeledReservoir::from_parts(&snap_x, &snap_l, snap_x.rows(), 64, 13).unwrap();
        fresh.offer(x.row(0), labels[0]);
        assert_eq!(fresh.len(), snap_x.rows() + 1);
    }
}
