//! Dataset substrate: synthetic generators (the paper's datasets are
//! unavailable — see DESIGN.md §3), the Table-1 registry, CSV I/O for
//! bringing your own features, and the out-of-core block-streaming layer
//! (`stream`) that feeds N ≫ RAM datasets through the tiled AKDA path
//! one row-tile at a time.

pub mod csv;
pub mod registry;
pub mod stream;
pub mod synthetic;

pub use registry::{by_name, cross_dataset_collection, med_datasets, Condition, DatasetSpec, Split};
