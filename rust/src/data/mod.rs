//! Dataset substrate: synthetic generators (the paper's datasets are
//! unavailable — see DESIGN.md §3), the Table-1 registry, and CSV I/O for
//! bringing your own features.

pub mod csv;
pub mod registry;
pub mod synthetic;

pub use registry::{by_name, cross_dataset_collection, med_datasets, Condition, DatasetSpec, Split};
