//! Minimal CSV I/O for features + labels (bring-your-own-dataset path and
//! the toy example's figure dumps).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::linalg::Mat;

/// Parse one `label,f1,f2,...` line; `Ok(None)` for blanks and `#`
/// comments. Shared with the out-of-core reader (`data::stream`) so both
/// paths accept the exact same format. `lineno` is 1-based (diagnostics).
pub(crate) fn parse_labeled_line(line: &str, lineno: usize) -> Result<Option<(usize, Vec<f64>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split(',');
    let label: usize = parts
        .next()
        .context("missing label")?
        .trim()
        .parse()
        .with_context(|| format!("bad label on line {lineno}"))?;
    let feats: Vec<f64> = parts
        .map(|p| p.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad feature on line {lineno}"))?;
    Ok(Some((label, feats)))
}

/// Load a labelled feature matrix: each line `label,f1,f2,...`.
///
/// Materializes the whole file; for N ≫ RAM datasets use
/// `data::stream::CsvBlockSource`, which reads the same format tile by
/// tile.
pub fn load_labeled(path: &Path) -> Result<(Mat, Vec<usize>)> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let Some((label, feats)) = parse_labeled_line(&line, lineno + 1)? else {
            continue;
        };
        if let Some(first) = rows.first() {
            anyhow::ensure!(
                feats.len() == first.len(),
                "inconsistent feature count on line {}",
                lineno + 1
            );
        }
        labels.push(label);
        rows.push(feats);
    }
    anyhow::ensure!(!rows.is_empty(), "empty dataset {path:?}");
    let (n, d) = (rows.len(), rows[0].len());
    let mut data = Vec::with_capacity(n * d);
    for r in rows {
        data.extend(r);
    }
    Ok((Mat::from_vec(n, d, data), labels))
}

/// Write a labelled feature matrix in the same format.
pub fn save_labeled(path: &Path, x: &Mat, labels: &[usize]) -> Result<()> {
    anyhow::ensure!(x.rows() == labels.len());
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    for i in 0..x.rows() {
        write!(w, "{}", labels[i])?;
        for v in x.row(i) {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write an unlabeled matrix, one row per line (figure data dumps).
pub fn save_matrix(path: &Path, x: &Mat) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    for i in 0..x.rows() {
        let row: Vec<String> = x.row(i).iter().map(|v| v.to_string()).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("akda_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.csv");
        let x = Mat::from_vec(3, 2, vec![1.0, 2.5, -3.0, 0.0, 7.25, 9.0]);
        let labels = vec![0, 1, 1];
        save_labeled(&path, &x, &labels).unwrap();
        let (x2, l2) = load_labeled(&path).unwrap();
        assert_eq!(l2, labels);
        assert!(x2.sub(&x).max_abs() < 1e-12);
    }

    #[test]
    fn rejects_ragged_rows() {
        let dir = std::env::temp_dir().join("akda_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ragged.csv");
        std::fs::write(&path, "0,1.0,2.0\n1,3.0\n").unwrap();
        assert!(load_labeled(&path).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("akda_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comments.csv");
        std::fs::write(&path, "# header\n\n0,1.0\n1,2.0\n").unwrap();
        let (x, l) = load_labeled(&path).unwrap();
        assert_eq!(x.shape(), (2, 1));
        assert_eq!(l, vec![0, 1]);
    }
}
