//! Synthetic dataset generators.
//!
//! Substitution (DESIGN.md §3): the paper evaluates on TRECVID MED video
//! features and the cross-dataset image collection — neither is available
//! here. The paper's *claims* depend on (N, C, F) for timing and on class
//! nonlinearity/multimodality for accuracy ordering, so these generators
//! control exactly those axes: Gaussian-mixture classes with configurable
//! per-class counts, modes per class (multimodality → subclass methods
//! win), separation and noise (overlap → kernel methods win over linear).

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Specification for a Gaussian-mixture multi-class problem.
#[derive(Debug, Clone)]
pub struct GaussianSpec {
    pub n_classes: usize,
    pub n_per_class: Vec<usize>,
    pub dim: usize,
    /// Distance scale between class (and mode) centers.
    pub class_sep: f64,
    /// Within-mode standard deviation.
    pub noise: f64,
    /// Modes per class (>1 makes classes multimodal — the regime KSDA/
    /// AKSDA are built for, Sec. 2).
    pub modes_per_class: usize,
    pub seed: u64,
}

/// Draw the dataset: returns (X rows-observations, labels), observations
/// sorted by class (the paper's convention, Sec. 2).
pub fn gaussian_classes(spec: &GaussianSpec) -> (Mat, Vec<usize>) {
    assert_eq!(spec.n_per_class.len(), spec.n_classes);
    let mut rng = Rng::new(spec.seed);
    let n: usize = spec.n_per_class.iter().sum();
    let mut x = Mat::zeros(n, spec.dim);
    let mut labels = Vec::with_capacity(n);
    // random unit directions for each class/mode center
    let mut centers: Vec<Vec<f64>> = Vec::new();
    for _ in 0..spec.n_classes * spec.modes_per_class {
        let mut c: Vec<f64> = (0..spec.dim).map(|_| rng.normal()).collect();
        let nrm = c.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
        for v in c.iter_mut() {
            *v *= spec.class_sep / nrm;
        }
        centers.push(c);
    }
    let mut row = 0;
    for cls in 0..spec.n_classes {
        for i in 0..spec.n_per_class[cls] {
            let mode = i % spec.modes_per_class;
            let center = &centers[cls * spec.modes_per_class + mode];
            for j in 0..spec.dim {
                x[(row, j)] = center[j] + spec.noise * rng.normal();
            }
            labels.push(cls);
            row += 1;
        }
    }
    (x, labels)
}

/// A nonlinear two-class problem (concentric shells): linearly
/// inseparable in input space, separable with an RBF kernel — the regime
/// where the paper's kernel methods beat the linear ones (Sec. 6.3.2).
pub fn concentric_shells(n_per: usize, dim: usize, seed: u64) -> (Mat, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let n = 2 * n_per;
    let mut x = Mat::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for cls in 0..2 {
        let radius = if cls == 0 { 1.0 } else { 3.0 };
        for i in 0..n_per {
            let row = cls * n_per + i;
            let v: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            let nrm = v.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-12);
            let r = radius + 0.25 * rng.normal();
            for (j, a) in v.iter().enumerate() {
                x[(row, j)] = a / nrm * r;
            }
            labels.push(cls);
        }
    }
    (x, labels)
}

/// XOR-style multimodal binary problem: each class is two far-apart
/// blobs arranged so class means coincide — unimodal DA fails, subclass
/// DA succeeds. Used by the AKSDA-vs-AKDA ablations.
pub fn xor_blobs(n_per_blob: usize, dim: usize, sep: f64, noise: f64, seed: u64)
    -> (Mat, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let n = 4 * n_per_blob;
    let mut x = Mat::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    // class 0 blobs at (+s,+s) and (−s,−s); class 1 at (+s,−s), (−s,+s)
    let corners = [(1.0, 1.0, 0), (-1.0, -1.0, 0), (1.0, -1.0, 1), (-1.0, 1.0, 1)];
    let mut row = 0;
    // keep observations sorted by class: class 0 blobs first
    for &(a, b, cls) in corners.iter().filter(|c| c.2 == 0).chain(
        corners.iter().filter(|c| c.2 == 1)) {
        for _ in 0..n_per_blob {
            x[(row, 0)] = a * sep + noise * rng.normal();
            x[(row, 1)] = b * sep + noise * rng.normal();
            for j in 2..dim {
                x[(row, j)] = noise * rng.normal();
            }
            labels.push(cls);
            row += 1;
        }
    }
    (x, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_shapes_and_sorted_labels() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![10, 20, 5],
            dim: 6,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 1,
        });
        assert_eq!(x.shape(), (35, 6));
        assert_eq!(labels.len(), 35);
        let mut sorted = labels.clone();
        sorted.sort_unstable();
        assert_eq!(labels, sorted, "observations sorted by class");
        assert_eq!(labels.iter().filter(|&&l| l == 1).count(), 20);
    }

    #[test]
    fn gaussian_deterministic() {
        let spec = GaussianSpec {
            n_classes: 2,
            n_per_class: vec![8, 8],
            dim: 4,
            class_sep: 1.0,
            noise: 0.3,
            modes_per_class: 2,
            seed: 9,
        };
        let (a, _) = gaussian_classes(&spec);
        let (b, _) = gaussian_classes(&spec);
        assert_eq!(a, b);
    }

    #[test]
    fn shells_have_expected_radii() {
        let (x, labels) = concentric_shells(50, 5, 2);
        for i in 0..100 {
            let r: f64 = x.row(i).iter().map(|v| v * v).sum::<f64>().sqrt();
            if labels[i] == 0 {
                assert!(r < 2.0, "inner shell radius {r}");
            } else {
                assert!(r > 2.0, "outer shell radius {r}");
            }
        }
    }

    #[test]
    fn xor_class_means_coincide() {
        let (x, labels) = xor_blobs(100, 4, 3.0, 0.2, 3);
        let mean = |cls: usize, j: usize| {
            let idx: Vec<usize> = (0..400).filter(|&i| labels[i] == cls).collect();
            idx.iter().map(|&i| x[(i, j)]).sum::<f64>() / idx.len() as f64
        };
        for j in 0..2 {
            assert!((mean(0, j) - mean(1, j)).abs() < 0.2, "dim {j}");
        }
        // classes sorted
        assert!(labels[..200].iter().all(|&l| l == 0));
        assert!(labels[200..].iter().all(|&l| l == 1));
    }
}
