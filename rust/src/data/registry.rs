//! Dataset registry mirroring Table 1 of the paper (the cross-dataset
//! collection) plus the two TRECVID MED datasets, scaled to laptop sizes
//! (DESIGN.md §3 documents the substitution). Each entry preserves the
//! original's *shape*: number of classes, examples-per-class regime
//! (10Ex / 100Ex), class imbalance, and a nonlinearity/multimodality
//! profile chosen to reflect how the original datasets behave.

use super::synthetic::{gaussian_classes, GaussianSpec};
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Experimental condition (Sec. 6.1.2): positives per class in training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Condition {
    Ex10,
    Ex100,
}

impl Condition {
    pub fn per_class(&self) -> usize {
        match self {
            Condition::Ex10 => 10,
            Condition::Ex100 => 100,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Condition::Ex10 => "10Ex",
            Condition::Ex100 => "100Ex",
        }
    }

    /// Parse the spellings the CLI and the model manifests use.
    ///
    /// ```
    /// use akda::data::Condition;
    /// assert_eq!(Condition::parse("10").unwrap(), Condition::Ex10);
    /// assert_eq!(Condition::parse("100Ex").unwrap(), Condition::Ex100);
    /// assert!(Condition::parse("50").is_none());
    /// ```
    pub fn parse(s: &str) -> Option<Condition> {
        match s {
            "10" | "10Ex" | "ex10" => Some(Condition::Ex10),
            "100" | "100Ex" | "ex100" => Some(Condition::Ex100),
            _ => None,
        }
    }
}

/// One registry entry (≈ one row of Table 1, scaled).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Scaled class count (original in parentheses in `describe`).
    pub n_classes: usize,
    pub orig_classes: usize,
    /// Input dimensionality (original features are DeCAF-4096/IDT-101376;
    /// scaled to keep 2N²F tractable while N ≫ F still holds at 100Ex).
    pub dim: usize,
    /// Test observations per class.
    pub test_per_class: usize,
    /// Multimodality: modes per class (drives subclass-method gains).
    pub modes_per_class: usize,
    /// Class separation / noise — controls problem hardness.
    pub class_sep: f64,
    pub noise: f64,
    pub seed: u64,
}

/// A realized train/test split.
#[derive(Debug, Clone)]
pub struct Split {
    pub x_train: Mat,
    pub y_train: Vec<usize>,
    pub x_test: Mat,
    pub y_test: Vec<usize>,
    pub n_classes: usize,
}

impl DatasetSpec {
    /// Materialize the split for a condition (Sec. 6.1.2 protocol: k
    /// positives per class for training, the rest for testing).
    pub fn split(&self, cond: Condition) -> Split {
        let train_pc = cond.per_class();
        let total_pc = train_pc + self.test_per_class;
        let spec = GaussianSpec {
            n_classes: self.n_classes,
            n_per_class: vec![total_pc; self.n_classes],
            dim: self.dim,
            class_sep: self.class_sep,
            noise: self.noise,
            modes_per_class: self.modes_per_class,
            seed: self.seed,
        };
        let (x, labels) = gaussian_classes(&spec);
        let mut rng = Rng::new(self.seed ^ 0xA5A5);
        let mut train_idx = Vec::new();
        let mut test_idx = Vec::new();
        for cls in 0..self.n_classes {
            let mut idx: Vec<usize> =
                (0..labels.len()).filter(|&i| labels[i] == cls).collect();
            rng.shuffle(&mut idx);
            train_idx.extend_from_slice(&idx[..train_pc]);
            test_idx.extend_from_slice(&idx[train_pc..]);
        }
        train_idx.sort_unstable();
        test_idx.sort_unstable();
        Split {
            x_train: x.select_rows(&train_idx),
            y_train: train_idx.iter().map(|&i| labels[i]).collect(),
            x_test: x.select_rows(&test_idx),
            y_test: test_idx.iter().map(|&i| labels[i]).collect(),
            n_classes: self.n_classes,
        }
    }

    pub fn describe(&self, cond: Condition) -> String {
        format!(
            "{:<11} C={:<3} (orig {:<3}) F={:<4} train={:<5} test={:<6} modes={}",
            self.name,
            self.n_classes,
            self.orig_classes,
            self.dim,
            self.n_classes * cond.per_class(),
            self.n_classes * self.test_per_class,
            self.modes_per_class
        )
    }
}

/// The cross-dataset collection (Table 1), scaled. Class counts are capped
/// at 16 so the full per-class one-vs-rest protocol stays tractable; the
/// per-dataset character (imbalance of difficulty, multimodality) is kept.
pub fn cross_dataset_collection() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { name: "awa", n_classes: 12, orig_classes: 50, dim: 64,
            test_per_class: 60, modes_per_class: 2, class_sep: 2.2, noise: 1.0, seed: 101 },
        DatasetSpec { name: "ayahoo", n_classes: 12, orig_classes: 12, dim: 64,
            test_per_class: 40, modes_per_class: 1, class_sep: 2.8, noise: 0.9, seed: 102 },
        DatasetSpec { name: "bing", n_classes: 16, orig_classes: 257, dim: 64,
            test_per_class: 80, modes_per_class: 3, class_sep: 1.6, noise: 1.2, seed: 103 },
        DatasetSpec { name: "caltech101", n_classes: 14, orig_classes: 101, dim: 64,
            test_per_class: 50, modes_per_class: 1, class_sep: 3.0, noise: 0.8, seed: 104 },
        DatasetSpec { name: "caltech256", n_classes: 16, orig_classes: 257, dim: 64,
            test_per_class: 60, modes_per_class: 2, class_sep: 2.0, noise: 1.0, seed: 105 },
        DatasetSpec { name: "eth80", n_classes: 8, orig_classes: 80, dim: 64,
            test_per_class: 40, modes_per_class: 2, class_sep: 2.6, noise: 0.8, seed: 106 },
        DatasetSpec { name: "imagenet", n_classes: 14, orig_classes: 118, dim: 64,
            test_per_class: 80, modes_per_class: 2, class_sep: 1.9, noise: 1.1, seed: 107 },
        DatasetSpec { name: "mscorid", n_classes: 10, orig_classes: 22, dim: 64,
            test_per_class: 40, modes_per_class: 1, class_sep: 3.2, noise: 0.7, seed: 108 },
        DatasetSpec { name: "office", n_classes: 12, orig_classes: 91, dim: 64,
            test_per_class: 30, modes_per_class: 2, class_sep: 2.3, noise: 1.0, seed: 109 },
        DatasetSpec { name: "pascal07", n_classes: 10, orig_classes: 20, dim: 64,
            test_per_class: 80, modes_per_class: 3, class_sep: 1.5, noise: 1.3, seed: 110 },
        DatasetSpec { name: "rgbd", n_classes: 12, orig_classes: 51, dim: 64,
            test_per_class: 100, modes_per_class: 1, class_sep: 3.5, noise: 0.6, seed: 111 },
    ]
}

/// The two TRECVID MED datasets (Sec. 6.1.1), scaled: med10 is small with
/// few target events; med-hbb is larger with more events. Video IDT
/// features → higher-dimensional, strongly nonlinear profile.
pub fn med_datasets() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { name: "med10", n_classes: 4, orig_classes: 4, dim: 128,
            test_per_class: 110, modes_per_class: 2, class_sep: 1.7, noise: 1.2, seed: 201 },
        DatasetSpec { name: "med-hbb", n_classes: 12, orig_classes: 25, dim: 128,
            test_per_class: 90, modes_per_class: 3, class_sep: 1.6, noise: 1.2, seed: 202 },
    ]
}

pub fn by_name(name: &str) -> Option<DatasetSpec> {
    cross_dataset_collection()
        .into_iter()
        .chain(med_datasets())
        .find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_table1() {
        let reg = cross_dataset_collection();
        assert_eq!(reg.len(), 11, "11 cross-dataset rows in Table 1");
        let names: Vec<&str> = reg.iter().map(|d| d.name).collect();
        for want in ["awa", "ayahoo", "bing", "caltech101", "caltech256",
                     "eth80", "imagenet", "mscorid", "office", "pascal07", "rgbd"] {
            assert!(names.contains(&want), "{want} missing");
        }
        assert_eq!(med_datasets().len(), 2);
    }

    #[test]
    fn split_respects_condition() {
        let d = by_name("eth80").unwrap();
        let s10 = d.split(Condition::Ex10);
        assert_eq!(s10.y_train.len(), 8 * 10);
        assert_eq!(s10.y_test.len(), 8 * 40);
        let s100 = d.split(Condition::Ex100);
        assert_eq!(s100.y_train.len(), 8 * 100);
        // every class has exactly per_class training positives
        for cls in 0..8 {
            assert_eq!(s10.y_train.iter().filter(|&&l| l == cls).count(), 10);
        }
    }

    #[test]
    fn split_deterministic_and_disjoint() {
        let d = by_name("mscorid").unwrap();
        let a = d.split(Condition::Ex10);
        let b = d.split(Condition::Ex10);
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_test, b.y_test);
        // train and test sizes sum to the full set
        assert_eq!(a.y_train.len() + a.y_test.len(), 10 * (10 + 40));
    }

    #[test]
    fn by_name_finds_med() {
        assert!(by_name("med10").is_some());
        assert!(by_name("nope").is_none());
    }
}
