//! Symmetric eigensolvers.
//!
//! `sym_eig` = Householder tridiagonalization + implicit QL with Wilkinson
//! shifts (the "symmetric QR algorithm" the paper costs at 9N^3 flops in
//! Sec. 4.5). `jacobi_eig` is a cyclic Jacobi fallback used for tiny
//! matrices (the C x C core matrix O_b) where its quadratic convergence and
//! excellent orthogonality matter more than flops.

use super::mat::Mat;

/// Eigen decomposition result: `a = vectors * diag(values) * vectorsᵀ`.
/// `vectors` columns are the eigenvectors.
#[derive(Debug, Clone)]
pub struct Eig {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
/// Returns (d, e, q): diagonal, off-diagonal (e[0] unused), and the
/// accumulated orthogonal transform Q with A = Q T Qᵀ.
fn tridiagonalize(a: &Mat) -> (Vec<f64>, Vec<f64>, Mat) {
    let n = a.rows();
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];

    for i in (1..n).rev() {
        let l = i; // length of the row segment 0..i
        let mut h = 0.0;
        if l > 1 {
            let scale: f64 = (0..l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, i - 1)];
            } else {
                for k in 0..l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, i - 1)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, i - 1)] = f - g;
                f = 0.0;
                for j in 0..l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        z[(j, k)] -= f * e[k] + g * z[(i, k)];
                    }
                }
            }
        } else {
            e[i] = z[(i, i - 1)];
        }
        d[i] = h;
    }

    d[0] = 0.0;
    e[0] = 0.0;
    // accumulate transformation
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    z[(k, j)] -= g * z[(k, i)];
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
    (d, e, z)
}

/// Implicit QL with Wilkinson shifts on a symmetric tridiagonal matrix,
/// accumulating the rotations into `z` (columns become eigenvectors).
fn tql_implicit(d: &mut [f64], e: &mut [f64], z: &mut Mat) -> Result<(), String> {
    let n = d.len();
    if n == 0 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small off-diagonal to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(format!("tql: no convergence at index {l}"));
            }
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sgn = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sgn);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate eigenvectors
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

/// Full symmetric eigendecomposition, eigenvalues ascending.
pub fn sym_eig(a: &Mat) -> Result<Eig, String> {
    assert_eq!(a.rows(), a.cols(), "sym_eig needs a square matrix");
    let n = a.rows();
    if n == 0 {
        return Ok(Eig { values: vec![], vectors: Mat::zeros(0, 0) });
    }
    if n == 1 {
        return Ok(Eig { values: vec![a[(0, 0)]], vectors: Mat::eye(1) });
    }
    let (mut d, mut e, mut z) = tridiagonalize(a);
    tql_implicit(&mut d, &mut e, &mut z)?;
    // sort ascending, permuting eigenvector columns
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            vectors[(r, newc)] = z[(r, oldc)];
        }
    }
    Ok(Eig { values, vectors })
}

/// Symmetric eigendecomposition sorted descending (the order the paper's
/// GEP solutions use: λ1 ≥ … ≥ λD).
pub fn sym_eig_desc(a: &Mat) -> Result<Eig, String> {
    let mut e = sym_eig(a)?;
    let n = e.values.len();
    e.values.reverse();
    let mut v = Mat::zeros(n, n);
    for c in 0..n {
        for r in 0..n {
            v[(r, c)] = e.vectors[(r, n - 1 - c)];
        }
    }
    e.vectors = v;
    Ok(e)
}

/// Cyclic Jacobi eigensolver — slow but extremely robust; used for the tiny
/// core matrices (C x C, H x H). Eigenvalues descending.
pub fn jacobi_eig(a: &Mat) -> Eig {
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    for _sweep in 0..100 {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[(p, q)] * m[(p, q)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vectors = Mat::zeros(n, n);
    for (c, &(_, oldc)) in pairs.iter().enumerate() {
        for r in 0..n {
            vectors[(r, c)] = v[(r, oldc)];
        }
    }
    Eig { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randsym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.add(&a.transpose()).scale(0.5)
    }

    fn check_eig(a: &Mat, e: &Eig, tol: f64) {
        let n = a.rows();
        // A v = λ v per pair
        for c in 0..n {
            let v = e.vectors.col(c);
            let av = a.matvec(&v);
            for r in 0..n {
                assert!(
                    (av[r] - e.values[c] * v[r]).abs() < tol,
                    "residual at ({r},{c})"
                );
            }
        }
        // orthonormal vectors
        let vtv = e.vectors.matmul_tn(&e.vectors);
        assert!(vtv.sub(&Mat::eye(n)).max_abs() < tol);
    }

    #[test]
    fn sym_eig_random_matrices() {
        for &n in &[2, 3, 5, 10, 40, 100] {
            let a = randsym(n, n as u64 + 1);
            let e = sym_eig(&a).unwrap();
            check_eig(&a, &e, 1e-8);
            // ascending order
            for i in 1..n {
                assert!(e.values[i] >= e.values[i - 1] - 1e-12);
            }
        }
    }

    #[test]
    fn sym_eig_diagonal() {
        let a = Mat::diag(&[3.0, -1.0, 2.0]);
        let e = sym_eig(&a).unwrap();
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sym_eig_desc_descends() {
        let a = randsym(12, 9);
        let e = sym_eig_desc(&a).unwrap();
        for i in 1..12 {
            assert!(e.values[i] <= e.values[i - 1] + 1e-12);
        }
        check_eig(&a, &e, 1e-8);
    }

    #[test]
    fn jacobi_matches_ql() {
        for &n in &[2, 4, 8, 16] {
            let a = randsym(n, 50 + n as u64);
            let ej = jacobi_eig(&a);
            let mut eq = sym_eig(&a).unwrap();
            eq.values.reverse();
            for i in 0..n {
                assert!((ej.values[i] - eq.values[i]).abs() < 1e-9);
            }
            check_eig(&a, &ej, 1e-9);
        }
    }

    #[test]
    fn idempotent_projector_has_01_spectrum() {
        // the paper's core matrix O_b = I - n n^T/(n^T n) (Eq. 30)
        let counts = [10.0_f64, 25.0, 7.0, 58.0];
        let nd: Vec<f64> = counts.iter().map(|c| c.sqrt()).collect();
        let nn: f64 = counts.iter().sum();
        let n = counts.len();
        let ob = Mat::from_fn(n, n, |i, j| {
            (if i == j { 1.0 } else { 0.0 }) - nd[i] * nd[j] / nn
        });
        let e = sym_eig_desc(&ob).unwrap();
        for i in 0..n - 1 {
            assert!((e.values[i] - 1.0).abs() < 1e-12);
        }
        assert!(e.values[n - 1].abs() < 1e-12);
    }

    #[test]
    fn property_eig_sweep() {
        for seed in 0..12_u64 {
            let mut rng = Rng::new(3_000 + seed);
            let n = 2 + (rng.next_u64() % 30) as usize;
            let a = randsym(n, 77 * seed + 5);
            let e = sym_eig(&a).unwrap();
            check_eig(&a, &e, 1e-7);
            // trace preserved
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum: f64 = e.values.iter().sum();
            assert!((tr - sum).abs() < 1e-8 * (1.0 + tr.abs()));
        }
    }
}
