//! L10: the backend seam — one scheduling layer under every dense hot
//! path (Gram build, blocked Cholesky, tiled ΦᵀΦ accumulation, matmuls).
//!
//! The three backends execute the *same* floating-point program and
//! differ only in how its row-tiles are scheduled:
//!
//! * [`Scalar`] — one tile, run on the calling thread (the reference
//!   semantics: single-threaded, no tiling);
//! * [`Blocked`] — fixed-height cache tiles, still the calling thread
//!   (right-looking panel Cholesky with tile-level syrk/gemm updates
//!   walks these tiles in ascending order);
//! * [`Parallel`] — the *same* fixed tiles fanned across a
//!   [`WorkPool`], one job per tile.
//!
//! **Determinism contract.** Every routed operation assigns each output
//! element to exactly one tile and fixes the per-element reduction order
//! (ascending k for dot products, ascending sample row for `A^T B`
//! accumulation) independently of tile geometry, worker count, or job
//! completion order. Consequently all three backends — and every
//! WorkPool size — produce bit-for-bit identical results; the only
//! thing a backend may change is wall-clock time. `rust/tests/
//! backend_equiv.rs` locks the contract down over a size grid, and the
//! `auto` policy below is therefore a pure performance choice, never a
//! numerics choice. This is also what lets the PJRT/Pallas engine
//! become "just another backend" later: anything behind this trait that
//! honors the tile/reduction contract is observationally identical.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};

use crate::coordinator::WorkPool;

/// Which backend a caller (CLI `--backend`, `AKDA_BACKEND` env, or the
/// auto policy) asked for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Scalar,
    Blocked,
    Parallel,
    /// Pick per matrix size: Scalar for tiny, Blocked for mid,
    /// Parallel for large (thresholds below). Safe because backends are
    /// bit-for-bit equivalent — only speed is at stake.
    Auto,
}

impl BackendKind {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(BackendKind::Scalar),
            "blocked" => Some(BackendKind::Blocked),
            "parallel" => Some(BackendKind::Parallel),
            "auto" => Some(BackendKind::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Blocked => "blocked",
            BackendKind::Parallel => "parallel",
            BackendKind::Auto => "auto",
        }
    }

    /// Stable numeric id for the MANIFEST `health.backend` key (the
    /// flight recorder stores f64 values only).
    pub fn id(self) -> u8 {
        match self {
            BackendKind::Scalar => 0,
            BackendKind::Blocked => 1,
            BackendKind::Parallel => 2,
            BackendKind::Auto => 3,
        }
    }

    fn from_id(id: u8) -> Option<Self> {
        match id {
            0 => Some(BackendKind::Scalar),
            1 => Some(BackendKind::Blocked),
            2 => Some(BackendKind::Parallel),
            3 => Some(BackendKind::Auto),
            _ => None,
        }
    }
}

/// The scheduling seam. `data` is a contiguous row-major buffer of
/// `data.len() / row_len` rows; the backend partitions it into
/// contiguous row-stripes and invokes `job(first_row, stripe)` exactly
/// once per stripe, covering every row. Stripes are disjoint `&mut`
/// slices, so jobs may run concurrently; `job` must not make any
/// per-element arithmetic depend on the stripe boundaries (that is the
/// determinism contract the equivalence harness enforces).
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Stripe height (in rows) this backend tiles an `rows`-row
    /// operation into. Geometry depends only on `rows`, never on worker
    /// count, so run-to-run schedules are reproducible.
    fn stripe_rows(&self, rows: usize) -> usize;

    /// Run `job` over the row-stripes of `data` (see trait docs).
    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    );
}

/// Walk stripes in ascending order on the calling thread.
fn serial_stripes(
    data: &mut [f64],
    row_len: usize,
    stripe: usize,
    job: &(dyn Fn(usize, &mut [f64]) + Sync),
) {
    if data.is_empty() || row_len == 0 {
        return;
    }
    for (ti, chunk) in data.chunks_mut(stripe.max(1) * row_len).enumerate() {
        job(ti * stripe.max(1), chunk);
    }
}

/// Reference backend: the whole operation is one tile on the calling
/// thread — exactly the single-threaded loop nest spelled out in the
/// routed functions' documentation.
pub struct Scalar;

impl Backend for Scalar {
    fn kind(&self) -> BackendKind {
        BackendKind::Scalar
    }

    fn stripe_rows(&self, rows: usize) -> usize {
        rows.max(1)
    }

    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    ) {
        serial_stripes(data, row_len, self.stripe_rows(data.len()), job);
    }
}

/// Cache-blocked backend: fixed-height tiles walked in ascending order
/// on the calling thread, keeping each tile's output rows (and the
/// panel rows they read) hot in cache across the inner k-loop.
pub struct Blocked {
    pub tile: usize,
}

/// Tile height shared by `Blocked` and `Parallel`: small enough that a
/// tile's output rows fit in L2 alongside the operands, large enough to
/// amortize scheduling. Fixed (never derived from the worker count) so
/// the tile geometry — and with it the schedule shape — is reproducible.
pub const DEFAULT_TILE: usize = 32;

impl Backend for Blocked {
    fn kind(&self) -> BackendKind {
        BackendKind::Blocked
    }

    fn stripe_rows(&self, _rows: usize) -> usize {
        self.tile.max(1)
    }

    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    ) {
        serial_stripes(data, row_len, self.tile, job);
    }
}

/// Parallel backend: the same fixed tiles as [`Blocked`], fanned across
/// a [`WorkPool`] (one job per tile) and joined before returning. Tile
/// geometry is a function of the matrix size alone, and no routed
/// operation reduces across tiles, so results are byte-identical for
/// every pool size — the concurrency hammer in `backend_equiv.rs`
/// shrinks and grows the pool across 50 runs to prove it.
pub struct Parallel {
    pool: Arc<WorkPool>,
    tile: usize,
}

impl Parallel {
    /// Public so tests can pin a pool of their own (the hammer test
    /// cycles pool sizes); production paths use [`Parallel::global`].
    pub fn new(pool: Arc<WorkPool>) -> Self {
        Parallel { pool, tile: DEFAULT_TILE }
    }

    /// The process-wide linalg pool, created on first use with one
    /// worker per available core. Dedicated to leaf tile jobs (which
    /// never re-enter the backend seam), so it cannot deadlock against
    /// the protocol/fleet pools that may be calling into it.
    pub fn global() -> &'static Parallel {
        static GLOBAL: OnceLock<Parallel> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            Parallel::new(Arc::new(WorkPool::new(crate::util::threads::available())))
        })
    }
}

impl Backend for Parallel {
    fn kind(&self) -> BackendKind {
        BackendKind::Parallel
    }

    fn stripe_rows(&self, _rows: usize) -> usize {
        self.tile.max(1)
    }

    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    ) {
        if data.is_empty() || row_len == 0 {
            return;
        }
        let stripe = self.tile.max(1);
        let rows = data.len() / row_len;
        if rows <= stripe {
            // single tile: skip the pool round-trip
            job(0, data);
            return;
        }
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(stripe * row_len)
            .enumerate()
            .map(|(ti, chunk)| {
                let f: Box<dyn FnOnce() + Send + '_> =
                    Box::new(move || job(ti * stripe, chunk));
                f
            })
            .collect();
        self.pool.run_scoped(jobs);
    }
}

// --- global selection -----------------------------------------------------

/// Auto policy: matrices with at least this many rows go parallel.
pub const PARALLEL_MIN_ROWS: usize = 192;
/// Auto policy: at least this many rows gets cache tiling.
pub const BLOCKED_MIN_ROWS: usize = 48;

const UNSET: u8 = u8::MAX;
static GLOBAL_KIND: AtomicU8 = AtomicU8::new(UNSET);

fn env_default() -> BackendKind {
    static ENV: OnceLock<BackendKind> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("AKDA_BACKEND")
            .ok()
            .as_deref()
            .and_then(BackendKind::from_name)
            .unwrap_or(BackendKind::Auto)
    })
}

/// Install the process-wide backend choice (CLI `--backend`). Until
/// this is called, the `AKDA_BACKEND` env var (read once) or `auto`
/// applies.
pub fn set_global(kind: BackendKind) {
    GLOBAL_KIND.store(kind.id(), Ordering::SeqCst);
}

/// The process-wide backend choice currently in force.
pub fn global_kind() -> BackendKind {
    match GLOBAL_KIND.load(Ordering::SeqCst) {
        UNSET => env_default(),
        id => BackendKind::from_id(id).unwrap_or(BackendKind::Auto),
    }
}

/// Resolve a kind to a concrete backend for an `rows`-row operation.
pub fn resolve(kind: BackendKind, rows: usize) -> &'static dyn Backend {
    static SCALAR: Scalar = Scalar;
    static BLOCKED: Blocked = Blocked { tile: DEFAULT_TILE };
    match kind {
        BackendKind::Scalar => &SCALAR,
        BackendKind::Blocked => &BLOCKED,
        BackendKind::Parallel => Parallel::global(),
        BackendKind::Auto => {
            if rows >= PARALLEL_MIN_ROWS {
                Parallel::global()
            } else if rows >= BLOCKED_MIN_ROWS {
                &BLOCKED
            } else {
                &SCALAR
            }
        }
    }
}

/// The backend the routed entry points (`gram`, `cholesky`,
/// `accumulate_tn`, `matmul*`) use: the global kind, resolved against
/// the operation's row count.
pub fn active(rows: usize) -> &'static dyn Backend {
    resolve(global_kind(), rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for kind in [
            BackendKind::Scalar,
            BackendKind::Blocked,
            BackendKind::Parallel,
            BackendKind::Auto,
        ] {
            assert_eq!(BackendKind::from_name(kind.name()), Some(kind));
            assert_eq!(BackendKind::from_id(kind.id()), Some(kind));
        }
        assert_eq!(BackendKind::from_name("gpu"), None);
    }

    #[test]
    fn auto_policy_scales_with_rows() {
        assert_eq!(resolve(BackendKind::Auto, 8).kind(), BackendKind::Scalar);
        assert_eq!(resolve(BackendKind::Auto, 64).kind(), BackendKind::Blocked);
        assert_eq!(
            resolve(BackendKind::Auto, 4096).kind(),
            BackendKind::Parallel
        );
        // explicit kinds ignore the size
        assert_eq!(resolve(BackendKind::Scalar, 4096).kind(), BackendKind::Scalar);
        assert_eq!(resolve(BackendKind::Parallel, 1).kind(), BackendKind::Parallel);
    }

    #[test]
    fn stripes_cover_every_row_exactly_once() {
        let rows = 37usize;
        let row_len = 5usize;
        for backend in [
            &Scalar as &dyn Backend,
            &Blocked { tile: 4 },
            Parallel::global(),
        ] {
            let mut data = vec![0.0_f64; rows * row_len];
            backend.for_row_stripes(&mut data, row_len, &|r0, stripe| {
                for (dr, row) in stripe.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + dr) as f64 + 1.0;
                    }
                }
            });
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(
                        data[r * row_len + c],
                        r as f64 + 1.0,
                        "{:?} row {r} col {c}",
                        backend.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn empty_input_is_a_noop() {
        let mut data: Vec<f64> = Vec::new();
        for backend in [
            &Scalar as &dyn Backend,
            &Blocked { tile: 8 },
            Parallel::global(),
        ] {
            backend.for_row_stripes(&mut data, 4, &|_, _| panic!("no stripes expected"));
        }
    }

    #[test]
    fn pinned_parallel_pool_is_usable() {
        let par = Parallel::new(Arc::new(WorkPool::new(3)));
        let mut data = vec![1.0_f64; 100 * 2];
        par.for_row_stripes(&mut data, 2, &|_, stripe| {
            for v in stripe.iter_mut() {
                *v *= 2.0;
            }
        });
        assert!(data.iter().all(|&v| v == 2.0));
    }
}
