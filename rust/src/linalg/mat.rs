//! Dense row-major matrix type used by every native (non-PJRT) code path.
//!
//! The coordinator environment is fully offline (no BLAS/LAPACK crates), so
//! this module is the linear-algebra substrate the paper's baselines (KDA,
//! SRKDA, GDA, KSDA, ...) and the native AKDA engine are built on. All
//! heavy routines are blocked for cache locality and parallelized with
//! `std::thread::scope`.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    pub fn diag(v: &[f64]) -> Self {
        let mut m = Mat::zeros(v.len(), v.len());
        for (i, &x) in v.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self[(r, c)] = v[r];
        }
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t[(c, r)] = self[(r, c)];
                    }
                }
            }
        }
        t
    }

    /// Contiguous sub-matrix copy.
    pub fn submatrix(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Mat {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols);
        let mut out = Mat::zeros(nr, nc);
        for r in 0..nr {
            out.row_mut(r).copy_from_slice(&self.row(r0 + r)[c0..c0 + nc]);
        }
        out
    }

    pub fn set_submatrix(&mut self, r0: usize, c0: usize, m: &Mat) {
        assert!(r0 + m.rows <= self.rows && c0 + m.cols <= self.cols);
        for r in 0..m.rows {
            let cols = self.cols;
            let dst = &mut self.data[(r0 + r) * cols + c0..(r0 + r) * cols + c0 + m.cols];
            dst.copy_from_slice(m.row(r));
        }
    }

    /// Select a subset of rows (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += s * I (ridge regularization).
    pub fn add_ridge(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += s;
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// A * B with cache-blocked inner loops, threaded over row stripes.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul inner dim mismatch");
        let mut out = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut out);
        out
    }

    /// A * B^T — avoids materializing the transpose for gram-like
    /// products. Each output element is one `dot`, so results are
    /// identical under every `linalg::backend` tile schedule.
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt inner dim mismatch");
        let (m, n) = (self.rows, b.rows);
        let mut out = Mat::zeros(m, n);
        let backend = super::backend::active(m);
        let a_ref = &*self;
        backend.for_row_stripes(&mut out.data, n, &|r0, stripe| {
            for (dr, orow) in stripe.chunks_mut(n).enumerate() {
                let arow = a_ref.row(r0 + dr);
                for (c, o) in orow.iter_mut().enumerate() {
                    *o = dot(arow, b.row(c));
                }
            }
        });
        out
    }

    /// A^T * B without materializing A^T.
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, b.cols);
        accumulate_tn(&mut out, self, b);
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps the FP pipes busy and gives
    // deterministic results independent of thread count.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// acc += A^T * B without materializing A^T, as a sequence of row-by-row
/// rank-1 updates (acc += a_rᵀ b_r for r = 0, 1, …).
///
/// Because each element of acc receives its updates in strictly
/// ascending sample-row order, accumulating the row-blocks of a
/// partitioned A (and B) in order performs the exact same
/// floating-point operations as one `matmul_tn` over the full matrices —
/// no reassociation, so tiled out-of-core accumulation (`data::stream` /
/// `da::akda_stream`) is bit-for-bit identical to the in-memory product
/// for every block size. Uses the globally selected `linalg::backend`.
pub fn accumulate_tn(acc: &mut Mat, a: &Mat, b: &Mat) {
    accumulate_tn_with(acc, a, b, super::backend::active(a.cols));
}

/// [`accumulate_tn`] on an explicit backend. The backend tiles the
/// *output* rows of acc (columns of A); every tile replays the full
/// ascending r-loop restricted to its own acc rows, so the per-element
/// update chain — and hence the bits — is the same for every tile
/// geometry. That per-element fixed-order reduction is what keeps the
/// Parallel backend deterministic run-to-run.
pub fn accumulate_tn_with(
    acc: &mut Mat,
    a: &Mat,
    b: &Mat,
    backend: &dyn super::backend::Backend,
) {
    assert_eq!(a.rows, b.rows, "accumulate_tn inner dim mismatch");
    assert_eq!(acc.shape(), (a.cols, b.cols), "accumulate_tn acc shape mismatch");
    let bc = b.cols;
    backend.for_row_stripes(&mut acc.data, bc, &|i0, stripe| {
        for r in 0..a.rows {
            let arow = a.row(r);
            let brow = b.row(r);
            for (di, orow) in stripe.chunks_mut(bc).enumerate() {
                let av = arow[i0 + di];
                if av != 0.0 {
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
}

/// out = A * B on the globally selected `linalg::backend`; the inner
/// kernel iterates the k-dimension outermost over B rows so B is
/// streamed row-major.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    matmul_into_with(a, b, out, super::backend::active(a.rows));
}

/// [`matmul_into`] on an explicit backend. Each output row is an
/// independent k-ascending accumulation, so every tile schedule yields
/// identical bits.
pub fn matmul_into_with(
    a: &Mat,
    b: &Mat,
    out: &mut Mat,
    backend: &dyn super::backend::Backend,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(out.shape(), (m, n));
    backend.for_row_stripes(&mut out.data, n, &|r0, stripe| {
        for (dr, orow) in stripe.chunks_mut(n).enumerate() {
            let arow = a.row(r0 + dr);
            orow.fill(0.0);
            for kk in 0..k {
                let av = arow[kk];
                if av != 0.0 {
                    let brow = b.row(kk);
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        for &(m, k, n) in &[(3, 4, 5), (17, 9, 23), (64, 64, 64), (1, 7, 1)] {
            let a = randmat(m, k, (m * k) as u64);
            let b = randmat(k, n, (k * n + 1) as u64);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.sub(&want).max_abs() < 1e-10, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_nt_and_tn_match() {
        let a = randmat(13, 7, 1);
        let b = randmat(19, 7, 2);
        let got = a.matmul_nt(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.sub(&want).max_abs() < 1e-12);

        let c = randmat(13, 5, 3);
        let got = a.matmul_tn(&c);
        let want = a.transpose().matmul(&c);
        assert!(got.sub(&want).max_abs() < 1e-12);
    }

    #[test]
    fn accumulate_tn_is_block_size_invariant() {
        // the contract the out-of-core tiling rests on: summing row-blocks
        // in order is bit-for-bit the full product, for every block size
        let a = randmat(23, 6, 21);
        let b = randmat(23, 4, 22);
        let full = a.matmul_tn(&b);
        for block in [1usize, 7, 23] {
            let mut acc = Mat::zeros(6, 4);
            let mut r0 = 0;
            while r0 < 23 {
                let nr = block.min(23 - r0);
                accumulate_tn(&mut acc, &a.submatrix(r0, 0, nr, 6), &b.submatrix(r0, 0, nr, 4));
                r0 += nr;
            }
            assert_eq!(acc, full, "block={block} must be bit-for-bit");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = randmat(37, 12, 5);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn submatrix_and_set() {
        let a = randmat(10, 8, 9);
        let s = a.submatrix(2, 3, 4, 5);
        assert_eq!(s.shape(), (4, 5));
        assert_eq!(s[(0, 0)], a[(2, 3)]);
        let mut b = Mat::zeros(10, 8);
        b.set_submatrix(2, 3, &s);
        assert_eq!(b[(5, 7)], a[(5, 7)]);
        assert_eq!(b[(0, 0)], 0.0);
    }

    #[test]
    fn select_rows_gathers() {
        let a = randmat(6, 3, 11);
        let s = a.select_rows(&[4, 0, 4]);
        assert_eq!(s.row(0), a.row(4));
        assert_eq!(s.row(1), a.row(0));
        assert_eq!(s.row(2), a.row(4));
    }

    #[test]
    fn ridge_adds_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.add_ridge(0.5);
        assert_eq!(a[(1, 1)], 0.5);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = randmat(9, 4, 13);
        let v: Vec<f64> = (0..4).map(|i| i as f64).collect();
        let got = a.matvec(&v);
        let want = a.matmul(&Mat::col_vec(&v));
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }
}
