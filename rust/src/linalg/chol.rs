//! Blocked Cholesky factorization and triangular solves — the native
//! mirror of the L1 `chol.py` kernels (same right-looking blocked
//! structure, Sec. 4.5: N^3/3 flops, the SYRK trailing update carries
//! ~all of them).

use super::backend::Backend;
use super::mat::{dot, Mat};

pub const DEFAULT_BLOCK: usize = 64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CholError {
    /// Leading minor `k` is not positive definite.
    NotPositiveDefinite(usize),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotPositiveDefinite(k) => {
                write!(f, "matrix is not positive definite (pivot {k})")
            }
        }
    }
}

impl std::error::Error for CholError {}

/// Unblocked lower Cholesky (in place on a copy), for panels.
fn chol_unblocked(a: &Mat) -> Result<Mat, CholError> {
    let n = a.rows();
    let mut l = a.clone();
    for j in 0..n {
        let mut d = l[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError::NotPositiveDefinite(j));
        }
        let d = d.sqrt();
        l[(j, j)] = d;
        for i in (j + 1)..n {
            let mut s = l[(i, j)];
            let (ri, rj) = (i * n, j * n);
            // s -= dot(L[i, :j], L[j, :j])
            s -= dot(&l.data()[ri..ri + j], &l.data()[rj..rj + j]);
            l[(i, j)] = s / d;
        }
    }
    // zero strict upper triangle
    for i in 0..n {
        for j in (i + 1)..n {
            l[(i, j)] = 0.0;
        }
    }
    Ok(l)
}

/// Blocked right-looking Cholesky: returns lower-triangular `L`, `A = L Lᵀ`.
/// Runs on the globally selected `linalg::backend`.
pub fn cholesky(a: &Mat, block: usize) -> Result<Mat, CholError> {
    cholesky_with(a, block, super::backend::active(a.rows()))
}

/// [`cholesky`] on an explicit backend. The floating-point program —
/// and hence the factor, bit for bit — is fixed by `block` alone: the
/// backend only schedules the panel-solve and trailing-SYRK tiles
/// (every output element is a fixed-order chain regardless of tile
/// geometry), so scalar/blocked/parallel agree exactly for a given
/// `block`, and different `block` values differ by rounding only.
pub fn cholesky_with(a: &Mat, block: usize, backend: &dyn Backend) -> Result<Mat, CholError> {
    assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
    let _phase = crate::obs::span("chol");
    let _backend = crate::obs::span(backend.kind().name());
    let n = a.rows();
    let b = block.max(8).min(n.max(1));
    let mut work = a.clone();
    let mut l = Mat::zeros(n, n);
    let mut s = 0;
    while s < n {
        let bs = b.min(n - s);
        let e = s + bs;
        let akk = work.submatrix(s, s, bs, bs);
        let lkk = chol_unblocked(&akk).map_err(|CholError::NotPositiveDefinite(k)| {
            CholError::NotPositiveDefinite(s + k)
        })?;
        l.set_submatrix(s, s, &lkk);
        if e < n {
            let m = n - e;
            // Panel: solve L_panel L_kkᵀ = A[e.., s..e]
            let apanel = work.submatrix(e, s, m, bs);
            let panel = solve_tri_right_lt(&apanel, &lkk, backend);
            l.set_submatrix(e, s, &panel);
            // Trailing SYRK: A[e.., e..] -= panel panelᵀ (tiled)
            syrk_update(&mut work, e, &panel, backend);
        }
        s = e;
    }
    Ok(l)
}

/// Solve X L^T = A for X, with L lower-triangular (bs x bs), A (m x bs).
/// Rows of X are independent; the backend tiles them. Within a row the
/// j/k loops run in the classic ascending order, so the per-element
/// operation chain — and the bits — match the sequential solve.
fn solve_tri_right_lt(a: &Mat, l: &Mat, backend: &dyn Backend) -> Mat {
    let (_m, bs) = a.shape();
    let mut x = a.clone();
    backend.for_row_stripes(x.data_mut(), bs, &|_r0, stripe| {
        for xrow in stripe.chunks_mut(bs) {
            for j in 0..bs {
                let d = l[(j, j)];
                let mut s = xrow[j];
                for k in 0..j {
                    s -= xrow[k] * l[(j, k)];
                }
                xrow[j] = s / d;
            }
        }
    });
    x
}

/// work[e.., e..] -= panel panelᵀ, tiled over row stripes by the
/// backend, using only the lower triangle (the factorization never
/// reads the upper one). One `dot` + one subtraction per element, so
/// every tile schedule produces identical bits.
fn syrk_update(work: &mut Mat, e: usize, panel: &Mat, backend: &dyn Backend) {
    let n = work.cols();
    // split the trailing rows of `work` into disjoint mutable stripes
    let tail = &mut work.data_mut()[e * n..];
    backend.for_row_stripes(tail, n, &|r0, stripe| {
        for (dr, wrow) in stripe.chunks_mut(n).enumerate() {
            let gi = r0 + dr; // row index within the trailing block
            let prow = panel.row(gi);
            // only columns e..=e+gi (lower triangle incl. diagonal)
            for c in 0..=gi {
                wrow[e + c] -= dot(prow, panel.row(c));
            }
        }
    });
}

/// Forward substitution: solve L Y = B (L lower triangular, B n x d).
pub fn solve_lower(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let d = b.cols();
    let mut y = b.clone();
    for i in 0..n {
        let li = l.row(i);
        // y[i,:] -= sum_k<i L[i,k] y[k,:]
        for k in 0..i {
            let c = li[k];
            if c != 0.0 {
                let (head, tail) = y.data_mut().split_at_mut(i * d);
                let yk = &head[k * d..k * d + d];
                let yi = &mut tail[..d];
                for (a, b) in yi.iter_mut().zip(yk) {
                    *a -= c * b;
                }
            }
        }
        let inv = 1.0 / li[i];
        for v in y.row_mut(i) {
            *v *= inv;
        }
    }
    y
}

/// Backward substitution: solve Lᵀ X = B given lower-triangular L.
pub fn solve_upper_from_lower(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let d = b.cols();
    let mut x = b.clone();
    for ii in (0..n).rev() {
        // x[ii,:] = (b[ii,:] - sum_{k>ii} L[k,ii] x[k,:]) / L[ii,ii]
        for k in (ii + 1)..n {
            let c = l[(k, ii)];
            if c != 0.0 {
                let (head, tail) = x.data_mut().split_at_mut(k * d);
                let xi = &mut head[ii * d..ii * d + d];
                let xk = &tail[..d];
                for (a, b) in xi.iter_mut().zip(xk) {
                    *a -= c * b;
                }
            }
        }
        let inv = 1.0 / l[(ii, ii)];
        for v in x.row_mut(ii) {
            *v *= inv;
        }
    }
    x
}

/// Solve the SPD system A X = B via Cholesky (Eq. 44 / Eq. 51 route).
pub fn spd_solve(a: &Mat, b: &Mat, block: usize) -> Result<Mat, CholError> {
    let l = cholesky(a, block)?;
    let y = solve_lower(&l, b);
    Ok(solve_upper_from_lower(&l, &y))
}

/// Log-determinant of an SPD matrix from its Cholesky factor.
pub fn spd_logdet(a: &Mat, block: usize) -> Result<f64, CholError> {
    let l = cholesky(a, block)?;
    Ok((0..a.rows()).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut m = a.matmul_nt(&a).scale(1.0 / n as f64);
        m.add_ridge(1.0);
        m
    }

    #[test]
    fn cholesky_reconstructs() {
        for &(n, b) in &[(5, 8), (32, 8), (64, 16), (100, 32), (129, 64)] {
            let a = spd(n, n as u64);
            let l = cholesky(&a, b).unwrap();
            let diff = l.matmul_nt(&l).sub(&a).max_abs();
            assert!(diff < 1e-9, "n={n} b={b} diff={diff}");
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        let a = spd(48, 3);
        let lb = cholesky(&a, 16).unwrap();
        let lu = chol_unblocked(&a).unwrap();
        assert!(lb.sub(&lu).max_abs() < 1e-10);
    }

    #[test]
    fn backends_agree_bitwise_for_fixed_block() {
        // the determinism contract at the unit level: for a given
        // `block` the factor's bits are backend-invariant (the full
        // grid lives in tests/backend_equiv.rs)
        use crate::linalg::backend::{resolve, BackendKind};
        let a = spd(100, 21);
        for block in [8usize, 16, 64] {
            let ls = cholesky_with(&a, block, resolve(BackendKind::Scalar, 100)).unwrap();
            let lb = cholesky_with(&a, block, resolve(BackendKind::Blocked, 100)).unwrap();
            let lp = cholesky_with(&a, block, resolve(BackendKind::Parallel, 100)).unwrap();
            assert_eq!(ls, lb, "blocked differs from scalar at block={block}");
            assert_eq!(ls, lp, "parallel differs from scalar at block={block}");
        }
    }

    #[test]
    fn non_spd_is_rejected_with_pivot_index() {
        let mut a = Mat::eye(8);
        a[(5, 5)] = -1.0;
        match cholesky(&a, 4) {
            Err(CholError::NotPositiveDefinite(k)) => assert_eq!(k, 5),
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn triangular_solves_invert() {
        let a = spd(40, 7);
        let l = cholesky(&a, 16).unwrap();
        let mut rng = Rng::new(9);
        let b = Mat::from_fn(40, 3, |_, _| rng.normal());
        let y = solve_lower(&l, &b);
        assert!(l.matmul(&y).sub(&b).max_abs() < 1e-9);
        let x = solve_upper_from_lower(&l, &b);
        assert!(l.transpose().matmul(&x).sub(&b).max_abs() < 1e-9);
    }

    #[test]
    fn spd_solve_solves() {
        let a = spd(64, 11);
        let mut rng = Rng::new(12);
        let b = Mat::from_fn(64, 5, |_, _| rng.normal());
        let x = spd_solve(&a, &b, 16).unwrap();
        assert!(a.matmul(&x).sub(&b).max_abs() < 1e-8);
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let want = (4.0 * 3.0 - 1.0_f64).ln();
        assert!((spd_logdet(&a, 8).unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn property_random_spd_sweep() {
        // hand-rolled property test (proptest is unavailable offline):
        // random SPD matrices of random sizes must round-trip L Lᵀ = A
        // and solve to residual ~0.
        for seed in 0..20_u64 {
            let mut rng = Rng::new(1000 + seed);
            let n = 4 + (rng.next_u64() % 96) as usize;
            let a = spd(n, seed * 7 + 1);
            let l = cholesky(&a, 1 + (seed as usize % 64)).unwrap();
            assert!(l.matmul_nt(&l).sub(&a).max_abs() < 1e-8);
            let b = Mat::from_fn(n, 2, |_, _| rng.normal());
            let x = spd_solve(&a, &b, 32).unwrap();
            assert!(a.matmul(&x).sub(&b).max_abs() < 1e-7);
        }
    }
}
