//! Dense linear-algebra substrate (offline, no BLAS/LAPACK).
//!
//! Built from scratch for this reproduction: the paper's methods and all
//! its baselines are pure dense-linear-algebra algorithms, so this module
//! is the foundation everything native sits on. The PJRT artifacts handle
//! the *large* N x N work on the accelerated path; this handles the small
//! core-matrix algebra (O_b is C x C) and the entire baseline zoo.
//!
//! * `backend` — the L10 scheduling seam: `Scalar`/`Blocked`/`Parallel`
//!   backends with bit-for-bit identical results (see its module docs
//!   for the determinism contract) behind every hot path below;
//! * `mat` — the row-major `Mat` type: blocked/threaded products
//!   (`matmul`, `matmul_nt`, `matmul_tn`) and the order-preserving tiled
//!   accumulator `accumulate_tn` that the out-of-core pipeline builds on;
//! * `chol` — blocked Cholesky + triangular solves (the paper's N³/3 hot
//!   spot, and the m×m solve of the approximate/streaming paths);
//! * `eig` — Jacobi and tridiagonal symmetric eigensolvers (the C×C core
//!   eigenproblem, Nyström whitening);
//! * `qr`, `svd` — orthogonalization and rank tools for the baselines.

pub mod backend;
pub mod chol;
pub mod eig;
pub mod mat;
pub mod qr;
pub mod svd;

pub use backend::{Backend, BackendKind};
pub use chol::{cholesky, cholesky_with, solve_lower, solve_upper_from_lower, spd_solve, CholError};
pub use eig::{jacobi_eig, sym_eig, sym_eig_desc, Eig};
pub use mat::{accumulate_tn, accumulate_tn_with, dot, matmul_into, Mat};
pub use qr::{gram_schmidt, qr_thin};
pub use svd::{null_space, rank, svd, Svd};
