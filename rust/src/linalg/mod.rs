//! Dense linear-algebra substrate (offline, no BLAS/LAPACK).
//!
//! Built from scratch for this reproduction: the paper's methods and all
//! its baselines are pure dense-linear-algebra algorithms, so this module
//! is the foundation everything native sits on. The PJRT artifacts handle
//! the *large* N x N work on the accelerated path; this handles the small
//! core-matrix algebra (O_b is C x C) and the entire baseline zoo.

pub mod chol;
pub mod eig;
pub mod mat;
pub mod qr;
pub mod svd;

pub use chol::{cholesky, solve_lower, solve_upper_from_lower, spd_solve, CholError};
pub use eig::{jacobi_eig, sym_eig, sym_eig_desc, Eig};
pub use mat::{dot, matmul_into, Mat};
pub use qr::{gram_schmidt, qr_thin};
pub use svd::{null_space, rank, svd, Svd};
