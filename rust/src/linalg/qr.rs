//! QR factorization (Householder) and modified Gram–Schmidt.
//!
//! Gram–Schmidt is what SRKDA applies to the block matrix C̄ (Sec. 3.1);
//! Householder QR backs KODA's orthogonalization step and general
//! orthonormal-basis needs.

use super::mat::{dot, Mat};

/// Thin Householder QR: A (m x n, m >= n) = Q (m x n) R (n x n).
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin needs m >= n");
    let mut r = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Householder vector for column k below the diagonal
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * dot(&v, &v).sqrt();
        if alpha == 0.0 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = dot(&v, &v);
        if vnorm2 > 0.0 {
            // apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..]
            for j in k..n {
                let mut s = 0.0;
                for i in k..m {
                    s += v[i - k] * r[(i, j)];
                }
                let c = 2.0 * s / vnorm2;
                for i in k..m {
                    r[(i, j)] -= c * v[i - k];
                }
            }
        }
        vs.push(v);
    }
    // build Q by applying the Householder reflectors to the identity
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2 = dot(v, v);
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * q[(i, j)];
            }
            let c = 2.0 * s / vnorm2;
            for i in k..m {
                q[(i, j)] -= c * v[i - k];
            }
        }
    }
    // R upper-triangular part
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }
    (q, rr)
}

/// Modified Gram–Schmidt on the columns of `a`; returns an orthonormal
/// basis of the column space, dropping columns whose residual norm falls
/// below `tol` (rank-revealing, as SRKDA needs on C̄'s eigenvector set).
pub fn gram_schmidt(a: &Mat, tol: f64) -> Mat {
    let (m, n) = a.shape();
    let mut basis: Vec<Vec<f64>> = Vec::new();
    for j in 0..n {
        let mut v = a.col(j);
        for b in &basis {
            let c = dot(&v, b);
            for i in 0..m {
                v[i] -= c * b[i];
            }
        }
        // re-orthogonalize once (classic twice-is-enough)
        for b in &basis {
            let c = dot(&v, b);
            for i in 0..m {
                v[i] -= c * b[i];
            }
        }
        let nrm = dot(&v, &v).sqrt();
        if nrm > tol {
            for x in v.iter_mut() {
                *x /= nrm;
            }
            basis.push(v);
        }
    }
    let mut q = Mat::zeros(m, basis.len());
    for (c, b) in basis.iter().enumerate() {
        for r in 0..m {
            q[(r, c)] = b[r];
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        for &(m, n) in &[(5, 3), (20, 20), (50, 10)] {
            let a = randmat(m, n, (m + n) as u64);
            let (q, r) = qr_thin(&a);
            assert!(q.matmul(&r).sub(&a).max_abs() < 1e-10, "{m}x{n}");
            let qtq = q.matmul_tn(&q);
            assert!(qtq.sub(&Mat::eye(n)).max_abs() < 1e-10);
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn gram_schmidt_orthonormalizes() {
        let a = randmat(30, 8, 3);
        let q = gram_schmidt(&a, 1e-10);
        assert_eq!(q.cols(), 8);
        assert!(q.matmul_tn(&q).sub(&Mat::eye(8)).max_abs() < 1e-10);
    }

    #[test]
    fn gram_schmidt_drops_dependent_columns() {
        let mut a = randmat(20, 3, 4);
        let c0 = a.col(0);
        let c1 = a.col(1);
        let dep: Vec<f64> = c0.iter().zip(&c1).map(|(x, y)| 2.0 * x - y).collect();
        a.set_col(2, &dep);
        let q = gram_schmidt(&a, 1e-8);
        assert_eq!(q.cols(), 2);
    }

    #[test]
    fn gram_schmidt_spans_same_space() {
        let a = randmat(15, 4, 8);
        let q = gram_schmidt(&a, 1e-10);
        // projection of a onto span(q) equals a
        let proj = q.matmul(&q.matmul_tn(&a));
        assert!(proj.sub(&a).max_abs() < 1e-9);
    }
}
