//! Thin SVD via one-sided Jacobi rotations.
//!
//! Backs the SVD-based baselines of Sec. 3.2 (KUDA/KODA/KNDA use cascades
//! of SVDs) and rank decisions. One-sided Jacobi orthogonalizes the columns
//! of A in place; singular values are the resulting column norms. Slow
//! (O(n^2 m) per sweep) but very accurate — exactly what the baseline
//! methods need, and their cost is the point of the comparison anyway.

use super::mat::{dot, Mat};

#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, m x r (columns).
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors, n x r (columns).
    pub v: Mat,
}

/// Thin SVD of `a` (m x n). Singular values below `tol * s_max` are
/// truncated (rank-revealing).
pub fn svd(a: &Mat, tol: f64) -> Svd {
    let (m, n) = a.shape();
    let mut u = a.clone(); // columns get orthogonalized
    let mut v = Mat::eye(n);

    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0_f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let up = u.col(p);
                let uq = u.col(q);
                let apq = dot(&up, &uq);
                let app = dot(&up, &up);
                let aqq = dot(&uq, &uq);
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= 1e-15 * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) entry of AᵀA
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for i in 0..m {
                    let uip = u[(i, p)];
                    let uiq = u[(i, q)];
                    u[(i, p)] = c * uip - s * uiq;
                    u[(i, q)] = s * uip + c * uiq;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // singular values = column norms; sort descending, truncate at tol
    let mut pairs: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let cj = u.col(j);
            (dot(&cj, &cj).sqrt(), j)
        })
        .collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let smax = pairs.first().map(|p| p.0).unwrap_or(0.0);
    let rank = pairs.iter().take_while(|p| p.0 > tol * smax && p.0 > 0.0).count();

    let mut uu = Mat::zeros(m, rank);
    let mut vv = Mat::zeros(n, rank);
    let mut s = Vec::with_capacity(rank);
    for (c, &(sv, j)) in pairs.iter().take(rank).enumerate() {
        s.push(sv);
        for i in 0..m {
            uu[(i, c)] = u[(i, j)] / sv;
        }
        for i in 0..n {
            vv[(i, c)] = v[(i, j)];
        }
    }
    Svd { u: uu, s, v: vv }
}

/// Numerical rank via SVD.
pub fn rank(a: &Mat, tol: f64) -> usize {
    svd(a, tol).s.len()
}

/// Orthonormal basis of the null space of `a` (n x (n - rank)).
pub fn null_space(a: &Mat, tol: f64) -> Mat {
    let n = a.cols();
    let dec = svd(a, tol);
    let r = dec.s.len();
    // the right singular vectors NOT in the row space span the null space;
    // recover them by orthogonalizing the complement of V's columns.
    let mut proj = Mat::eye(n);
    for c in 0..r {
        let v = dec.v.col(c);
        for i in 0..n {
            for j in 0..n {
                proj[(i, j)] -= v[i] * v[j];
            }
        }
    }
    super::qr::gram_schmidt(&proj, 1e-8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn svd_reconstructs() {
        for &(m, n) in &[(8, 5), (20, 20), (30, 7)] {
            let a = randmat(m, n, (m * n) as u64);
            let d = svd(&a, 1e-12);
            // U S Vᵀ = A
            let us = Mat::from_fn(m, d.s.len(), |i, j| d.u[(i, j)] * d.s[j]);
            let rec = us.matmul_nt(&d.v);
            assert!(rec.sub(&a).max_abs() < 1e-9, "{m}x{n}");
            // orthonormality
            let r = d.s.len();
            assert!(d.u.matmul_tn(&d.u).sub(&Mat::eye(r)).max_abs() < 1e-9);
            assert!(d.v.matmul_tn(&d.v).sub(&Mat::eye(r)).max_abs() < 1e-9);
        }
    }

    #[test]
    fn rank_detects_deficiency() {
        let b = randmat(10, 3, 2);
        let low = b.matmul_nt(&b); // 10x10 rank 3
        assert_eq!(rank(&low, 1e-9), 3);
        assert_eq!(rank(&Mat::eye(6), 1e-9), 6);
    }

    #[test]
    fn singular_values_descend() {
        let a = randmat(12, 9, 5);
        let d = svd(&a, 1e-12);
        for i in 1..d.s.len() {
            assert!(d.s[i] <= d.s[i - 1] + 1e-12);
        }
    }

    #[test]
    fn null_space_is_annihilated() {
        let b = randmat(4, 6, 9); // 4x6: null space dim 2
        let ns = null_space(&b, 1e-10);
        assert_eq!(ns.cols(), 2);
        let prod = b.matmul(&ns);
        assert!(prod.max_abs() < 1e-8);
    }
}
