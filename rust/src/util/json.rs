//! Minimal JSON parser and writer (no serde offline) — just enough for
//! the artifact manifest emitted by `python/compile/aot.py` (objects,
//! arrays, strings, numbers, bools, null; UTF-8 escapes for ASCII
//! content) and for the compact documents the `obs` layer emits
//! (metrics snapshots, `BENCH_*.json`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing key {key:?}"))
    }
}

/// Compact (no-whitespace) JSON writer. Object keys keep `BTreeMap`
/// order, so output is deterministic. `f64` uses Rust's shortest
/// round-trip formatting; non-finite numbers render as `null` (JSON has
/// no NaN/Inf).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) if !n.is_finite() => f.write_str("null"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

pub fn parse(s: &str) -> Result<Json> {
    let bytes = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos:?}");
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let txt = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(txt.parse::<f64>().with_context(|| format!("bad number {txt:?}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("truncated escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).context("bad \\u escape")?);
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            c => {
                // raw UTF-8 passthrough
                let ch_len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len])?);
                *pos += ch_len;
            }
        }
    }
    bail!("unterminated string");
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => bail!("expected , or ] at byte {pos:?}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected : at byte {pos:?}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => bail!("expected , or }} at byte {pos:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"d_max": 32, "entries": [
            {"name": "fit_rbf_n256_l64", "file": "fit.hlo.txt",
             "inputs": [{"name": "x", "shape": [256, 64]}],
             "outputs": [{"name": "psi", "shape": [256, 32]}]}]}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.req("d_max").unwrap().as_usize(), Some(32));
        let entries = j.req("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        let shape = entries[0].req("inputs").unwrap().as_arr().unwrap()[0]
            .req("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn writer_round_trips() {
        let doc = parse(r#"{"a":[1,2.5,null,true],"b":{"c":"x\"y\n"},"d":-0.125}"#).unwrap();
        let text = doc.to_string();
        assert_eq!(parse(&text).unwrap(), doc);
        assert_eq!(text, r#"{"a":[1,2.5,null,true],"b":{"c":"x\"y\n"},"d":-0.125}"#);
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn nested_structures() {
        let j = parse(r#"[[1,2],[3,[4]]]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0], Json::Num(4.0));
    }
}
