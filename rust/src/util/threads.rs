//! Thread-count heuristics for the scoped-thread parallel loops.
//!
//! No rayon offline; `std::thread::scope` stripes are used everywhere. This
//! module centralizes the "how many threads is worth it" decision so the
//! perf pass can tune one place.

use std::sync::OnceLock;

static AVAILABLE: OnceLock<usize> = OnceLock::new();

/// Number of worker threads available (cached).
pub fn available() -> usize {
    *AVAILABLE.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Suggested thread count for a loop with `work_items` independent rows.
/// Spawning threads for tiny loops costs more than it saves.
pub fn suggested(work_items: usize) -> usize {
    if work_items < 64 {
        1
    } else {
        available().min(work_items / 16).max(1)
    }
}

/// Run `f(i)` for i in 0..n on up to `suggested(n)` threads, collecting
/// results in order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let nthreads = suggested(n).min(n.max(1));
    if nthreads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(nthreads);
    let stripes: Vec<&mut [Option<T>]> = out.chunks_mut(chunk).collect();
    std::thread::scope(|s| {
        for (ti, stripe) in stripes.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || {
                for (d, slot) in stripe.iter_mut().enumerate() {
                    *slot = Some(f(ti * chunk + d));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(1000, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_map_small_and_empty() {
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn suggested_bounds() {
        assert_eq!(suggested(1), 1);
        assert!(suggested(10_000) >= 1);
        assert!(suggested(10_000) <= available());
    }
}
