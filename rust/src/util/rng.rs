//! Small deterministic RNG (splitmix64 + xoshiro256**) with normal sampling.
//!
//! The environment is offline (no `rand` crate), and every experiment in the
//! paper reproduction must be seed-reproducible anyway, so a tiny
//! explicit-state generator is the right tool.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive a decorrelated seed for a named sub-stream of `base`. Two
/// distinct `stream` tags produce statistically independent seeds even
/// when `base` is small and structured (the splitmix64 finalizer breaks
/// the low-entropy pattern an `xor`-style derivation like `seed ^ 0x9E37`
/// would preserve). Deterministic: same `(base, stream)` ⇒ same seed.
pub fn derive_seed(base: u64, stream: u64) -> u64 {
    let mut sm = base ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut sm)
}

/// Seed for shard `index` of a `count`-way sharded train. Shards never
/// share an RNG stream (each `(index, count)` pair maps to its own
/// derived seed), while the degenerate single-shard train keeps `base`
/// untouched — so `k = 1` sharded training is bit-for-bit the unsharded
/// train.
pub fn shard_seed(base: u64, index: usize, count: usize) -> u64 {
    if count <= 1 {
        base
    } else {
        derive_seed(base, ((count as u64) << 32) | index as u64)
    }
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-job RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn derived_seeds_are_distinct_per_stream_and_shard() {
        // distinct streams of one base never collide on a small sample
        let seeds: Vec<u64> = (0..64).map(|s| derive_seed(29, s)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 64);
        // k=1 leaves the base seed untouched (bit-for-bit unsharded train)
        assert_eq!(shard_seed(29, 0, 1), 29);
        // shards of one train, and the same index across different k,
        // all draw from different streams
        let mut all = vec![29u64];
        for k in [2usize, 3, 7] {
            for i in 0..k {
                all.push(shard_seed(29, i, k));
            }
        }
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "shard seeds must never collide");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(3);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut w = v.clone();
        w.sort_unstable();
        assert_eq!(w, (0..100).collect::<Vec<_>>());
    }
}
