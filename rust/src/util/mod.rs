//! Small shared utilities: deterministic RNG, thread heuristics, timing.

pub mod json;
pub mod rng;
pub mod threads;
pub mod timer;
