//! Wall-clock timing helpers used by the evaluation harness (the paper's
//! ϑ (training time) and φ (testing time) measurements, Sec. 6.3.1).

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Accumulating stopwatch for split train/test phases.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    pub train_s: f64,
    pub test_s: f64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn train<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let (out, s) = timed(f);
        self.train_s += s;
        out
    }

    pub fn test<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let (out, s) = timed(f);
        self.test_s += s;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_measures_something() {
        let (v, s) = timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            42
        });
        assert_eq!(v, 42);
        assert!(s >= 0.009);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut w = Stopwatch::new();
        w.train(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        w.train(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        w.test(|| ());
        assert!(w.train_s >= 0.009);
        assert!(w.test_s < 0.01);
    }
}
