//! Wall-clock timing helpers used by the evaluation harness (the paper's
//! ϑ (training time) and φ (testing time) measurements, Sec. 6.3.1).
//!
//! `Stopwatch` is the single source of truth for both surfaces: each
//! `train`/`test` closure runs inside an [`crate::obs::span`], whose
//! one elapsed measurement feeds the accumulated `train_s`/`test_s`
//! fields (the ϑ/φ tables) *and* the `akda_phase_seconds` histogram
//! (`train`, `test`, and any nested `train/gram`-style sub-phases) —
//! no double timing.

use std::time::Instant;

use crate::obs;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Accumulating stopwatch for split train/test phases.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    pub train_s: f64,
    pub test_s: f64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn train<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let span = obs::span("train");
        let out = f();
        self.train_s += span.finish();
        out
    }

    pub fn test<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let span = obs::span("test");
        let out = f();
        self.test_s += span.finish();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_measures_something() {
        let (v, s) = timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            42
        });
        assert_eq!(v, 42);
        assert!(s >= 0.009);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut w = Stopwatch::new();
        w.train(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        w.train(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        w.test(|| ());
        assert!(w.train_s >= 0.009);
        assert!(w.test_s < 0.01);
    }

    #[test]
    fn stopwatch_feeds_phase_histogram() {
        let h = obs::histogram_with("akda_phase_seconds", &[("path", "train")]);
        let before = h.count();
        let mut w = Stopwatch::new();
        w.train(|| ());
        assert_eq!(h.count(), before + 1);
    }
}
