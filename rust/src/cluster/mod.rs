pub mod kmeans;
