//! k-means clustering (k-means++ init, Lloyd iterations) — the subclass
//! partitioning procedure AKSDA/GSDA use (Sec. 5.4, the O(N) term), plus a
//! nearest-neighbor chain partitioning used by the KSDA baseline [3].

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Result of clustering the rows of a matrix.
#[derive(Debug, Clone)]
pub struct Clustering {
    pub assignments: Vec<usize>,
    pub centroids: Mat,
    pub inertia: f64,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ seeding followed by Lloyd iterations.
pub fn kmeans(x: &Mat, k: usize, max_iter: usize, seed: u64) -> Clustering {
    let (n, d) = x.shape();
    assert!(k >= 1 && n >= 1);
    let k = k.min(n);
    let mut rng = Rng::new(seed);

    // k-means++ init
    let mut centroids = Mat::zeros(k, d);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sq_dist(x.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.uniform() * total;
            let mut idx = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    idx = i;
                    break;
                }
                target -= w;
            }
            idx
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            d2[i] = d2[i].min(sq_dist(x.row(i), centroids.row(c)));
        }
    }

    lloyd(x, centroids, max_iter)
}

/// Warm-started k-means: Lloyd iterations from caller-supplied centroids
/// instead of a fresh k-means++ seeding. This is the incremental
/// landmark-refresh primitive (`model::update`): as data drifts, the
/// current Nyström landmarks are the starting centroids, so a handful of
/// iterations tracks the drift instead of re-clustering from scratch.
/// Deterministic — no randomness is consumed.
pub fn kmeans_warm(x: &Mat, init: &Mat, max_iter: usize) -> Clustering {
    assert_eq!(x.cols(), init.cols(), "warm start dimensionality mismatch");
    assert!(init.rows() >= 1 && x.rows() >= 1);
    lloyd(x, init.clone(), max_iter)
}

/// Lloyd iterations from the given starting centroids (shared by
/// [`kmeans`] and [`kmeans_warm`]).
fn lloyd(x: &Mat, mut centroids: Mat, max_iter: usize) -> Clustering {
    let (n, d) = x.shape();
    let k = centroids.rows();
    let mut assignments = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    for _ in 0..max_iter {
        // assignment step (threaded)
        let new_assign: Vec<usize> = crate::util::threads::parallel_map(n, |i| {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for c in 0..k {
                let dd = sq_dist(x.row(i), centroids.row(c));
                if dd < bd {
                    bd = dd;
                    best = c;
                }
            }
            best
        });
        // update step
        let mut sums = Mat::zeros(k, d);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = new_assign[i];
            counts[c] += 1;
            let row = x.row(i);
            for (s, v) in sums.row_mut(c).iter_mut().zip(row) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed an empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sq_dist(x.row(a), centroids.row(new_assign[a]))
                            .partial_cmp(&sq_dist(x.row(b), centroids.row(new_assign[b])))
                            .unwrap()
                    })
                    .unwrap();
                sums.row_mut(c).copy_from_slice(x.row(far));
                counts[c] = 1;
            }
            let inv = 1.0 / counts[c] as f64;
            for v in sums.row_mut(c) {
                *v *= inv;
            }
        }
        centroids = sums;
        let new_inertia: f64 = (0..n)
            .map(|i| sq_dist(x.row(i), centroids.row(new_assign[i])))
            .sum();
        let converged = new_assign == assignments || (inertia - new_inertia).abs() < 1e-12;
        assignments = new_assign;
        inertia = new_inertia;
        if converged {
            break;
        }
    }
    Clustering { assignments, centroids, inertia }
}

/// Nearest-neighbor chain partitioning (the KSDA baseline's subclass
/// division [3]): order observations by a greedy NN chain, then cut the
/// chain into `k` contiguous segments of equal size.
pub fn nn_partition(x: &Mat, k: usize) -> Vec<usize> {
    let n = x.rows();
    let k = k.min(n).max(1);
    // greedy chain from observation 0
    let mut order = Vec::with_capacity(n);
    let mut used = vec![false; n];
    let mut cur = 0usize;
    used[0] = true;
    order.push(0);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut bd = f64::INFINITY;
        for j in 0..n {
            if !used[j] {
                let d = sq_dist(x.row(cur), x.row(j));
                if d < bd {
                    bd = d;
                    best = j;
                }
            }
        }
        used[best] = true;
        order.push(best);
        cur = best;
    }
    let mut out = vec![0usize; n];
    for (pos, &i) in order.iter().enumerate() {
        out[i] = (pos * k / n).min(k - 1);
    }
    out
}

/// Partition every class into `h_per_class` subclasses with k-means,
/// producing the flat subclass labelling AKSDA consumes.
pub fn partition_classes(
    x: &Mat,
    labels: &[usize],
    n_classes: usize,
    h_per_class: usize,
    seed: u64,
) -> crate::da::core::SubclassPartition {
    let mut sub_labels = vec![0usize; labels.len()];
    let mut class_of = Vec::new();
    let mut next = 0usize;
    for cls in 0..n_classes {
        let idx: Vec<usize> = (0..labels.len()).filter(|&i| labels[i] == cls).collect();
        let h = h_per_class.min(idx.len()).max(1);
        let sub_x = x.select_rows(&idx);
        let cl = kmeans(&sub_x, h, 50, seed ^ (cls as u64).wrapping_mul(0x9E37));
        // drop empty subclasses by remapping to dense ids
        let mut remap = vec![usize::MAX; h];
        let mut used = 0usize;
        for &a in &cl.assignments {
            if remap[a] == usize::MAX {
                remap[a] = used;
                used += 1;
            }
        }
        for (pos, &i) in idx.iter().enumerate() {
            sub_labels[i] = next + remap[cl.assignments[pos]];
        }
        for _ in 0..used {
            class_of.push(cls);
        }
        next += used;
    }
    crate::da::core::SubclassPartition { sub_labels, class_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blobs(n_per: usize, centers: &[[f64; 2]], seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let n = n_per * centers.len();
        let mut x = Mat::zeros(n, 2);
        for (c, ctr) in centers.iter().enumerate() {
            for i in 0..n_per {
                let r = c * n_per + i;
                x[(r, 0)] = ctr[0] + 0.1 * rng.normal();
                x[(r, 1)] = ctr[1] + 0.1 * rng.normal();
            }
        }
        x
    }

    #[test]
    fn kmeans_separates_blobs() {
        let x = blobs(30, &[[0.0, 0.0], [5.0, 5.0], [-5.0, 5.0]], 1);
        let cl = kmeans(&x, 3, 100, 7);
        for b in 0..3 {
            let first = cl.assignments[b * 30];
            for i in 0..30 {
                assert_eq!(cl.assignments[b * 30 + i], first, "blob {b}");
            }
        }
        assert!(cl.inertia < 30.0 * 3.0 * 0.1);
    }

    #[test]
    fn kmeans_k1_centroid_is_mean() {
        let x = blobs(20, &[[1.0, 2.0]], 3);
        let cl = kmeans(&x, 1, 10, 1);
        let mean0: f64 = (0..20).map(|i| x[(i, 0)]).sum::<f64>() / 20.0;
        assert!((cl.centroids[(0, 0)] - mean0).abs() < 1e-9);
    }

    #[test]
    fn kmeans_k_ge_n_is_exact() {
        let x = blobs(2, &[[0.0, 0.0], [9.0, 9.0]], 5);
        let cl = kmeans(&x, 10, 10, 2);
        assert!(cl.inertia < 0.5);
    }

    #[test]
    fn kmeans_deterministic_for_seed() {
        let x = blobs(25, &[[0.0, 0.0], [4.0, 4.0]], 8);
        let a = kmeans(&x, 2, 50, 42);
        let b = kmeans(&x, 2, 50, 42);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn kmeans_warm_tracks_drifted_blobs() {
        // fit on the original blobs, then warm-start on shifted data: the
        // centroids must follow the drift without a fresh seeding
        let x0 = blobs(25, &[[0.0, 0.0], [6.0, 0.0]], 12);
        let cl0 = kmeans(&x0, 2, 50, 3);
        let x1 = blobs(25, &[[1.0, 1.0], [7.0, 1.0]], 13);
        let warm = kmeans_warm(&x1, &cl0.centroids, 25);
        assert_eq!(warm.centroids.rows(), 2);
        // each drifted blob center is within noise of a warm centroid
        for target in [[1.0, 1.0], [7.0, 1.0]] {
            let best = (0..2)
                .map(|c| sq_dist(warm.centroids.row(c), &target))
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.05, "centroid missed drifted blob: {best}");
        }
        // deterministic: no randomness consumed
        let again = kmeans_warm(&x1, &cl0.centroids, 25);
        assert_eq!(warm.assignments, again.assignments);
        assert!(warm.centroids.sub(&again.centroids).max_abs() == 0.0);
    }

    #[test]
    fn nn_partition_counts_balanced() {
        let x = blobs(20, &[[0.0, 0.0], [5.0, 0.0]], 9);
        let p = nn_partition(&x, 4);
        let mut counts = vec![0; 4];
        for &a in &p {
            counts[a] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 40);
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn partition_classes_respects_class_boundaries() {
        let x = blobs(30, &[[0.0, 0.0], [0.0, 3.0], [8.0, 0.0], [8.0, 3.0]], 11);
        // two classes, each made of two true blobs
        let labels: Vec<usize> = vec![0; 60].into_iter().chain(vec![1; 60]).collect();
        let part = partition_classes(&x, &labels, 2, 2, 1);
        assert_eq!(part.n_subclasses(), 4);
        // subclasses never straddle classes
        for (i, &s) in part.sub_labels.iter().enumerate() {
            assert_eq!(part.class_of[s], labels[i]);
        }
        // each class's two blobs land in different subclasses
        assert_ne!(part.sub_labels[0..30].to_vec(), part.sub_labels[30..60].to_vec());
    }
}
