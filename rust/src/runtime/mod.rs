//! PJRT runtime: loads the AOT HLO-text artifacts (`make artifacts`) and
//! executes them on the PJRT CPU client — the L3 side of the three-layer
//! architecture. Python never runs here; the binary is self-contained
//! once `artifacts/` exists.

pub mod engine;
pub mod manifest;
pub mod server;
pub mod xla;

pub use engine::{AkdaPjrt, AksdaPjrt, PjrtEngine, PjrtProjection};
pub use manifest::Manifest;
pub use server::{Arg, PjrtHandle};
