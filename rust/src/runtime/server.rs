//! PJRT execution server.
//!
//! The `xla` crate's PJRT handles wrap raw pointers (not `Send`/`Sync`),
//! so a dedicated server thread owns the client and the compiled-
//! executable cache; the rest of the system talks to it through a cloneable
//! `PjrtHandle` over mpsc channels. XLA's CPU backend is internally
//! multi-threaded, so serializing submissions costs little — and it gives
//! the coordinator a single queue to meter (vLLM-router-style).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::manifest::Manifest;
// The offline stand-in for the `xla` crate (see `runtime::xla`); the
// code below is written against the real bindings' API surface.
use super::xla;

/// One tensor argument: f32 data + dims.
#[derive(Debug, Clone)]
pub struct Arg {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Arg {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Self {
        debug_assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>(),
            "arg data/dims mismatch"
        );
        Arg { data, dims }
    }
}

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Arg>,
        reply: Sender<Result<Vec<f32>>>,
    },
    /// Drop cached executables (used by tests to exercise reload).
    FlushCache,
    Shutdown,
}

/// Cloneable, Send handle to the PJRT server thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Request>,
    manifest: Arc<Manifest>,
}

impl PjrtHandle {
    /// Start the server over an artifact directory.
    pub fn start(artifact_dir: &std::path::Path) -> Result<PjrtHandle> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let (tx, rx) = channel::<Request>();
        let mf = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-server".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        // fail every request with the init error
                        while let Ok(req) = rx.recv() {
                            if let Request::Execute { reply, .. } = req {
                                let _ = reply.send(Err(anyhow::anyhow!(
                                    "PJRT client init failed: {e}"
                                )));
                            }
                        }
                        return;
                    }
                };
                let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
                let path_of = |name: &str| -> Option<PathBuf> {
                    mf.find(name).map(|e| e.file.clone())
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Shutdown => break,
                        Request::FlushCache => cache.clear(),
                        Request::Execute { artifact, inputs, reply } => {
                            let result = (|| -> Result<Vec<f32>> {
                                if !cache.contains_key(&artifact) {
                                    let path = path_of(&artifact).with_context(|| {
                                        format!("unknown artifact {artifact:?}")
                                    })?;
                                    let proto = xla::HloModuleProto::from_text_file(&path)
                                        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
                                    let comp = xla::XlaComputation::from_proto(&proto);
                                    let exe = client
                                        .compile(&comp)
                                        .map_err(|e| anyhow::anyhow!("compile {artifact}: {e}"))?;
                                    cache.insert(artifact.clone(), exe);
                                }
                                let exe = cache.get(&artifact).unwrap();
                                let literals: Vec<xla::Literal> = inputs
                                    .iter()
                                    .map(|a| {
                                        xla::Literal::vec1(&a.data)
                                            .reshape(&a.dims)
                                            .map_err(|e| anyhow::anyhow!("reshape: {e}"))
                                    })
                                    .collect::<Result<_>>()?;
                                let out = exe
                                    .execute::<xla::Literal>(&literals)
                                    .map_err(|e| anyhow::anyhow!("execute {artifact}: {e}"))?;
                                let lit = out[0][0]
                                    .to_literal_sync()
                                    .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
                                // artifacts are lowered with return_tuple=True
                                let first = lit
                                    .to_tuple1()
                                    .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
                                first
                                    .to_vec::<f32>()
                                    .map_err(|e| anyhow::anyhow!("to_vec: {e}"))
                            })();
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .context("spawn pjrt-server")?;
        Ok(PjrtHandle { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name; returns the first output, flattened.
    pub fn execute(&self, artifact: &str, inputs: Vec<Arg>) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("pjrt server is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("pjrt server dropped reply"))?
    }

    pub fn flush_cache(&self) {
        let _ = self.tx.send(Request::FlushCache);
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}
