//! The accelerated AKDA/AKSDA engine: routes the Gram + Cholesky hot spots
//! (>99% of the paper's flops) through the AOT Pallas/XLA artifacts, with
//! exact zero-padding into the shape buckets (DESIGN.md §5).
//!
//! The tiny core-matrix eigenproblem stays native (Alg. 1 step 1-2 — the
//! whole point of AKDA is that it is O(C³) ≪ N³).

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::server::{Arg, PjrtHandle};
use crate::cluster::kmeans::partition_classes;
use crate::da::{core, DrMethod, Projection};
use crate::kernels::Kernel;
use crate::linalg::Mat;

/// Accelerated engine over a running PJRT server.
#[derive(Clone)]
pub struct PjrtEngine {
    handle: PjrtHandle,
}

fn kernel_tag(kernel: Kernel) -> Result<&'static str> {
    match kernel {
        Kernel::Linear => Ok("linear"),
        Kernel::Rbf { .. } => Ok("rbf"),
        Kernel::Poly { .. } => {
            anyhow::bail!("no AOT artifacts for the polynomial kernel; use the native engine")
        }
    }
}

/// Pad a row-major matrix (converted to f32) into a (rows_pad, cols_pad)
/// zero-padded buffer.
fn pad_f32(m: &Mat, rows_pad: usize, cols_pad: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows_pad * cols_pad];
    for r in 0..m.rows() {
        for c in 0..m.cols() {
            out[r * cols_pad + c] = m[(r, c)] as f32;
        }
    }
    out
}

fn mask_f32(n_real: usize, n_pad: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n_pad];
    m[..n_real].fill(1.0);
    m
}

impl PjrtEngine {
    pub fn new(handle: PjrtHandle) -> Self {
        PjrtEngine { handle }
    }

    pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
        Ok(Self::new(PjrtHandle::start(dir)?))
    }

    pub fn handle(&self) -> &PjrtHandle {
        &self.handle
    }

    /// Solve K Ψ = Θ on the accelerated path. Returns Ψ (n × theta.cols()).
    pub fn fit(&self, x: &Mat, theta: &Mat, kernel: Kernel) -> Result<Mat> {
        let (n, l) = x.shape();
        ensure!(theta.rows() == n, "theta rows must match observations");
        let tag = kernel_tag(kernel)?;
        let mf = self.handle.manifest();
        let d_max = mf.d_max;
        ensure!(
            theta.cols() <= d_max,
            "theta has {} cols > bucket D_max {d_max}; re-emit artifacts with a larger D_MAX",
            theta.cols()
        );
        let entry = mf.fit_bucket(tag, n, l)?;
        let (bn, bl) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let inputs = vec![
            Arg::new(pad_f32(x, bn, bl), vec![bn as i64, bl as i64]),
            Arg::new(pad_f32(theta, bn, d_max), vec![bn as i64, d_max as i64]),
            Arg::new(vec![kernel.rho() as f32], vec![1, 1]),
            Arg::new(mask_f32(n, bn), vec![bn as i64, 1]),
        ];
        let name = entry.name.clone();
        let out = self.handle.execute(&name, inputs)?;
        ensure!(out.len() == bn * d_max, "fit output size mismatch");
        // unpad: rows 0..n, cols 0..theta.cols()
        let d = theta.cols();
        Ok(Mat::from_fn(n, d, |r, c| out[r * d_max + c] as f64))
    }

    /// Gram matrix via the standalone gram artifact (used by hybrid
    /// baselines and the cross-check tests).
    pub fn gram(&self, x: &Mat, kernel: Kernel) -> Result<Mat> {
        let (n, l) = x.shape();
        let tag = kernel_tag(kernel)?;
        let entry = self.handle.manifest().gram_bucket(tag, n, l)?;
        let (bn, bl) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let inputs = vec![
            Arg::new(pad_f32(x, bn, bl), vec![bn as i64, bl as i64]),
            Arg::new(vec![kernel.rho() as f32], vec![1, 1]),
            Arg::new(mask_f32(n, bn), vec![bn as i64, 1]),
        ];
        let name = entry.name.clone();
        let out = self.handle.execute(&name, inputs)?;
        ensure!(out.len() == bn * bn, "gram output size mismatch");
        Ok(Mat::from_fn(n, n, |r, c| out[r * bn + c] as f64))
    }

    /// Project test rows: Z = K_cross Ψ, chunked through the fixed-size
    /// test bucket of the project artifact.
    pub fn project(&self, x_train: &Mat, x_test: &Mat, psi: &Mat, kernel: Kernel)
        -> Result<Mat> {
        let (n_tr, l) = x_train.shape();
        let d = psi.cols();
        let tag = kernel_tag(kernel)?;
        let mf = self.handle.manifest();
        let d_max = mf.d_max;
        let entry = mf.project_bucket(tag, n_tr, l)?;
        let (bn, bl) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let chunk = entry.inputs[1].shape[0];
        let name = entry.name.clone();

        let x_train_pad = Arg::new(pad_f32(x_train, bn, bl), vec![bn as i64, bl as i64]);
        let psi_pad = Arg::new(pad_f32(psi, bn, d_max), vec![bn as i64, d_max as i64]);
        let rho = Arg::new(vec![kernel.rho() as f32], vec![1, 1]);
        let mask = Arg::new(mask_f32(n_tr, bn), vec![bn as i64, 1]);

        let ne = x_test.rows();
        let mut z = Mat::zeros(ne, d);
        let mut start = 0;
        while start < ne {
            let take = chunk.min(ne - start);
            let xe = x_test.submatrix(start, 0, take, x_test.cols());
            let inputs = vec![
                x_train_pad.clone(),
                Arg::new(pad_f32(&xe, chunk, bl), vec![chunk as i64, bl as i64]),
                psi_pad.clone(),
                rho.clone(),
                mask.clone(),
            ];
            let out = self.handle.execute(&name, inputs)?;
            ensure!(out.len() == chunk * d_max, "project output size mismatch");
            for r in 0..take {
                for c in 0..d {
                    z[(start + r, c)] = out[r * d_max + c] as f64;
                }
            }
            start += take;
        }
        Ok(z)
    }
}

// ---------------------------------------------------------------------------
// DrMethod adapters: accelerated AKDA / AKSDA.
// ---------------------------------------------------------------------------

/// AKDA with the hot path on PJRT (artifacts bake eps = 1e-3).
pub struct AkdaPjrt {
    pub kernel: Kernel,
    pub engine: Arc<PjrtEngine>,
}

pub struct PjrtProjection {
    engine: Arc<PjrtEngine>,
    x_train: Mat,
    psi: Mat,
    kernel: Kernel,
}

impl PjrtProjection {
    /// Kernel-expansion state for the model-artifact subsystem: a saved
    /// PJRT projection is served natively as a `da::KernelProjection`
    /// (same support points and Ψ; the f32 engine is a training-time
    /// accelerator, not part of the persisted model).
    pub fn expansion_state(&self) -> (&Mat, &Mat, Kernel) {
        (&self.x_train, &self.psi, self.kernel)
    }
}

impl Projection for PjrtProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        self.engine
            .project(&self.x_train, x_test, &self.psi, self.kernel)
            .expect("pjrt project")
    }
    fn dim(&self) -> usize {
        self.psi.cols()
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl DrMethod for AkdaPjrt {
    fn name(&self) -> &'static str {
        "akda-pjrt"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let theta = core::theta_for(labels, n_classes);
        let psi = self.engine.fit(x, &theta, self.kernel).context("akda-pjrt fit")?;
        Ok(Box::new(PjrtProjection {
            engine: self.engine.clone(),
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
        }))
    }
}

/// AKSDA with the hot path on PJRT.
pub struct AksdaPjrt {
    pub kernel: Kernel,
    pub engine: Arc<PjrtEngine>,
    pub h_per_class: usize,
    pub seed: u64,
}

impl DrMethod for AksdaPjrt {
    fn name(&self) -> &'static str {
        "aksda-pjrt"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let part = partition_classes(x, labels, n_classes, self.h_per_class, self.seed);
        let (v, _) = core::v_matrix(&part);
        let psi = self.engine.fit(x, &v, self.kernel).context("aksda-pjrt fit")?;
        Ok(Box::new(PjrtProjection {
            engine: self.engine.clone(),
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
        }))
    }
}
