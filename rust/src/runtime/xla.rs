//! Offline stand-in for the `xla` crate's PJRT CPU bindings.
//!
//! `runtime::server` is written against the real `xla` crate's API
//! surface (client, compiled-executable cache, literals). This build
//! environment carries no XLA/PJRT shared library, so this module
//! mirrors exactly the types and signatures the server consumes and
//! reports "unavailable" at client init. The server's existing
//! degraded-mode path then takes over: every `Execute` request is
//! answered with `PJRT client init failed: ...` instead of a crash, and
//! the PJRT integration tests skip themselves when no engine can start.
//! Restoring the real bindings is a dependency swap — no server change.

use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' `Display`-able error.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!("{what}: XLA/PJRT runtime is not available in this build"))
}

/// PJRT client handle. The stand-in never constructs one: [`PjRtClient::cpu`]
/// reports the runtime as unavailable, which the server converts into its
/// per-request degraded mode.
pub struct PjRtClient;

impl PjRtClient {
    /// Real bindings: initialize the PJRT CPU plugin. Stand-in: always
    /// `Err` — there is no plugin to load.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile an HLO computation for this client.
    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on one replica; returns per-replica, per-output device
    /// buffers (the server reads `out[0][0]`).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module (the `.hlo.txt` artifacts `make artifacts` emits).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. Stand-in: parsing requires the XLA
    /// parser, so this is unavailable (the server only reaches it after
    /// a successful client init, which the stand-in never grants).
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host tensor: flat f32 data plus dims. Fully functional — the server
/// builds its input literals before submitting, and tests exercise the
/// reshape validation.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dims; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(XlaError(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// First element of a tuple literal (artifacts are lowered with
    /// `return_tuple=True`). The stand-in has no tuple literals to
    /// destructure — only execution results are tuples, and execution is
    /// unavailable.
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: FromLiteral>(&self) -> Result<Vec<T>, XlaError> {
        Ok(T::from_f32(&self.data))
    }

    /// Dims as declared (used by the stand-in's tests).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element types a [`Literal`] can be copied out as.
pub trait FromLiteral: Sized {
    fn from_f32(data: &[f32]) -> Vec<Self>;
}

impl FromLiteral for f32 {
    fn from_f32(data: &[f32]) -> Vec<f32> {
        data.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_init_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stand-in must not init");
        assert!(format!("{err}").contains("not available"), "{err}");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let ok = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(ok.dims(), &[2, 3]);
        assert_eq!(ok.to_vec::<f32>().unwrap().len(), 6);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn hlo_parse_is_unavailable_offline() {
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
    }
}
