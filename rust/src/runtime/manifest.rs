//! Artifact manifest: the machine-readable index `python/compile/aot.py`
//! writes next to the HLO text files. The runtime resolves shape buckets
//! against it instead of hard-coding the python-side bucket lists.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub d_max: usize,
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|t| {
            let name = t.req("name")?.as_str().context("name")?.to_string();
            let shape = t
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = parse(&text)?;
        let d_max = j.req("d_max")?.as_usize().context("d_max")?;
        let entries = j
            .req("entries")?
            .as_arr()
            .context("entries")?
            .iter()
            .map(|e| {
                Ok(ArtifactEntry {
                    name: e.req("name")?.as_str().context("name")?.to_string(),
                    file: dir.join(e.req("file")?.as_str().context("file")?),
                    inputs: tensor_specs(e.req("inputs")?)?,
                    outputs: tensor_specs(e.req("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { d_max, entries, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Smallest fit bucket (n, l) covering a live problem, for a kernel.
    /// Returns the entry name.
    pub fn fit_bucket(&self, kernel: &str, n: usize, l: usize) -> Result<&ArtifactEntry> {
        self.pick(&format!("fit_{kernel}_"), n, l)
    }

    pub fn gram_bucket(&self, kernel: &str, n: usize, l: usize) -> Result<&ArtifactEntry> {
        self.pick(&format!("gram_{kernel}_"), n, l)
    }

    /// Project buckets are keyed by (n_train, l); the fixed n_test chunk
    /// size is read from the entry's x_test input spec.
    pub fn project_bucket(&self, kernel: &str, n_train: usize, l: usize)
        -> Result<&ArtifactEntry> {
        let mut best: Option<&ArtifactEntry> = None;
        for e in &self.entries {
            if !e.name.starts_with(&format!("project_{kernel}_")) {
                continue;
            }
            let (bn, bl) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
            if bn >= n_train && bl >= l {
                let better = match best {
                    None => true,
                    Some(b) => (bn, bl) < (b.inputs[0].shape[0], b.inputs[0].shape[1]),
                };
                if better {
                    best = Some(e);
                }
            }
        }
        best.with_context(|| {
            format!("no project_{kernel} bucket covers n_train={n_train} l={l}")
        })
    }

    fn pick(&self, prefix: &str, n: usize, l: usize) -> Result<&ArtifactEntry> {
        let mut best: Option<&ArtifactEntry> = None;
        for e in &self.entries {
            if !e.name.starts_with(prefix) {
                continue;
            }
            let (bn, bl) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
            if bn >= n && bl >= l {
                let better = match best {
                    None => true,
                    Some(b) => (bn, bl) < (b.inputs[0].shape[0], b.inputs[0].shape[1]),
                };
                if better {
                    best = Some(e);
                }
            }
        }
        best.with_context(|| format!("no {prefix}* bucket covers n={n} l={l}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("make artifacts first");
        assert_eq!(m.d_max, 32);
        assert!(m.entries.len() >= 12);
        assert!(m.find("fit_rbf_n256_l64").is_some());
    }

    #[test]
    fn bucket_selection_picks_smallest_cover() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let e = m.fit_bucket("rbf", 200, 50).unwrap();
        assert_eq!(e.inputs[0].shape, vec![256, 64]);
        let e = m.fit_bucket("rbf", 257, 64).unwrap();
        assert_eq!(e.inputs[0].shape, vec![512, 64]);
        let e = m.fit_bucket("linear", 1000, 100).unwrap();
        assert_eq!(e.inputs[0].shape, vec![1024, 256]);
        assert!(m.fit_bucket("rbf", 1_000_000, 64).is_err());
    }

    #[test]
    fn project_bucket_has_test_chunk() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let e = m.project_bucket("rbf", 300, 64).unwrap();
        assert_eq!(e.inputs[0].shape[0], 512); // train bucket
        assert!(e.inputs[1].shape[0] >= 256); // test chunk
    }
}
