//! The paper's factorization framework (Secs. 4.1–4.3, 5.1–5.3): core
//! matrices, their tiny eigenproblems, and the target matrices Θ / V that
//! the big Cholesky solve consumes.
//!
//! The chain of reductions that makes AKDA cheap:
//!
//! 1. **Central factors.** The kernel scatter matrices factor as
//!    S_b = K C_b K, S_w = K C_w K, S_t = K C_t K, where the N×N central
//!    factors C_b, C_w, C_t (Eq. 29) depend on the *labels only*. They
//!    are idempotent projectors with C_t = C_b + C_w and C_b C_w = 0
//!    (Sec. 4.2) — so the generalized eigenproblem S_b ψ = λ S_t ψ can be
//!    attacked through the label structure instead of the data.
//!
//! 2. **Core matrix.** C_b itself compresses to the C×C *core matrix*
//!    O_b = I − ṅṅᵀ/N (Eq. 30, ṅ = per-class sqrt-counts,
//!    `core_matrix`): C_b = R N^{−1/2} O_b N^{−1/2} Rᵀ with R the N×C
//!    one-hot class indicator. O_b is an idempotent projector of rank
//!    C−1 whose null vector is ṅ (Eq. 32).
//!
//! 3. **NZEP.** The nonzero-eigenpair eigenvectors Ξ of O_b — the C−1
//!    directions with eigenvalue exactly 1 (`core_eigenvectors`, Eq. 39)
//!    — lift to the NZEP of C_b as Θ = R N^{−1/2} Ξ (Eq. 40, `theta`).
//!    Row n of Θ is just row `label(n)` of Ξ scaled by 1/sqrt(N of that
//!    class): O(N·C) work, no N×N matrix is ever formed, and Θ is
//!    class-piecewise-constant (the property the out-of-core streaming
//!    path exploits to rebuild ΦᵀΘ from m×C class sums).
//!
//! 4. **Simultaneous reduction.** Θ satisfies Θᵀ C_b Θ = I,
//!    Θᵀ C_w Θ = 0, Θᵀ C_t Θ = I (Eqs. 41–43) — so Ψ with K Ψ = Θ
//!    simultaneously diagonalizes all three scatter matrices, and the
//!    entire generalized eigenproblem collapses to one SPD linear solve
//!    (Cholesky; see `da::akda`). For C = 2 even the C×C EVD disappears:
//!    θ is analytic (Eqs. 49–50, `theta_binary`).
//!
//! The subclass mirror (AKSDA, Sec. 5) swaps O_b for the H×H subclass
//! core matrix O_bs (`core_matrix_subclass`) whose NZEP (U, Ω) has
//! eigenvalues in (0, 1] rather than exactly 1; V = R_H N_H^{−1/2} U
//! (`v_matrix`, Eq. 66) plays Θ's role with Vᵀ C_bs V = Ω (Eqs. 67–69).
//!
//! Everything here is O(C³) / O(H³) — the whole point of AKDA is that the
//! only eigenproblem left is this small one (Alg. 1 step 1, Alg. 2 step 1).

use crate::linalg::{jacobi_eig, Mat};

/// Per-class observation counts N_i from a label vector.
pub fn class_counts(labels: &[usize], n_classes: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_classes];
    for &l in labels {
        assert!(l < n_classes, "label {l} out of range");
        counts[l] += 1;
    }
    counts
}

/// Core matrix O_b = I_C − ṅ ṅᵀ / (ṅᵀ ṅ) (Eq. 30), ṅ = sqrt(counts).
pub fn core_matrix(counts: &[usize]) -> Mat {
    let c = counts.len();
    let nd: Vec<f64> = counts.iter().map(|&x| (x as f64).sqrt()).collect();
    let nn: f64 = counts.iter().map(|&x| x as f64).sum();
    Mat::from_fn(c, c, |i, j| {
        (if i == j { 1.0 } else { 0.0 }) - nd[i] * nd[j] / nn
    })
}

/// NZEP eigenvector matrix Ξ of O_b (Eq. 39): the C−1 eigenvectors with
/// eigenvalue 1 (O_b is idempotent with rank C−1, Sec. 4.2).
pub fn core_eigenvectors(counts: &[usize]) -> Mat {
    let c = counts.len();
    let ob = core_matrix(counts);
    let eig = jacobi_eig(&ob); // descending; tiny matrix
    let mut xi = Mat::zeros(c, c - 1);
    for k in 0..c - 1 {
        debug_assert!(
            (eig.values[k] - 1.0).abs() < 1e-8,
            "O_b eigenvalue {} should be 1, got {}",
            k,
            eig.values[k]
        );
        for r in 0..c {
            xi[(r, k)] = eig.vectors[(r, k)];
        }
    }
    // flight recorder: the NZEP eigenvalues are exactly 1 in theory
    // (O_b is idempotent with rank C−1); their drift is a direct
    // numerical-health readout of the eigensolve
    if c > 1 {
        let nzep = &eig.values[..c - 1];
        crate::obs::flight::record("nzep_count", (c - 1) as f64);
        crate::obs::flight::record(
            "core_eig_min",
            nzep.iter().copied().fold(f64::INFINITY, f64::min),
        );
        crate::obs::flight::record(
            "core_eig_max",
            nzep.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        );
    }
    xi
}

/// Θ = R_C N_C^{−1/2} Ξ (Eq. 40): the NZEP of C_b, computed WITHOUT forming
/// the N×N matrix — row n of Θ is row Ξ[label(n),:] / sqrt(N_label(n))
/// (the paper notes this is O(C): scale row i of Ξ and replicate N_i times).
pub fn theta(labels: &[usize], n_classes: usize) -> Mat {
    let counts = class_counts(labels, n_classes);
    assert!(
        counts.iter().all(|&c| c > 0),
        "every class needs at least one observation"
    );
    let xi = core_eigenvectors(&counts);
    let inv_sqrt: Vec<f64> = counts.iter().map(|&c| 1.0 / (c as f64).sqrt()).collect();
    Mat::from_fn(labels.len(), n_classes - 1, |n, d| {
        xi[(labels[n], d)] * inv_sqrt[labels[n]]
    })
}

/// Analytic binary-class θ (Eqs. 49–50), '+' sign branch: class-0 entries
/// positive. Labels must be 0/1 with n1 = |class 0|, n2 = |class 1|.
pub fn theta_binary(labels: &[usize]) -> Mat {
    let n1 = labels.iter().filter(|&&l| l == 0).count();
    let n2 = labels.len() - n1;
    assert!(n1 > 0 && n2 > 0, "both classes must be non-empty");
    let n = (n1 + n2) as f64;
    let pos = (n2 as f64 / (n1 as f64 * n)).sqrt();
    let neg = -(n1 as f64 / (n2 as f64 * n)).sqrt();
    Mat::from_fn(labels.len(), 1, |r, _| if labels[r] == 0 { pos } else { neg })
}

/// Θ with the binary fast path: the analytic [`theta_binary`] (Eqs.
/// 49–50) when C = 2, the NZEP route ([`theta`], Eq. 40) otherwise — the
/// single dispatch every AKDA-family trainer (exact, approx, PJRT,
/// incremental) shares, so the fast-path condition can never drift
/// between them.
pub fn theta_for(labels: &[usize], n_classes: usize) -> Mat {
    if n_classes == 2 {
        theta_binary(labels)
    } else {
        theta(labels, n_classes)
    }
}

// ---------------------------------------------------------------------------
// Subclass machinery (AKSDA, Sec. 5).
// ---------------------------------------------------------------------------

/// Subclass structure: a flat subclass id per observation plus the map
/// from subclass id to its parent class.
#[derive(Debug, Clone)]
pub struct SubclassPartition {
    /// subclass id of each observation (0..h)
    pub sub_labels: Vec<usize>,
    /// parent class of each subclass (len h)
    pub class_of: Vec<usize>,
}

impl SubclassPartition {
    pub fn n_subclasses(&self) -> usize {
        self.class_of.len()
    }

    /// The trivial partition: one subclass per class (AKSDA reduces to AKDA).
    pub fn trivial(labels: &[usize], n_classes: usize) -> Self {
        SubclassPartition {
            sub_labels: labels.to_vec(),
            class_of: (0..n_classes).collect(),
        }
    }

    pub fn counts(&self) -> Vec<usize> {
        class_counts(&self.sub_labels, self.n_subclasses())
    }
}

/// Subclass core matrix O_bs (element-wise form, Sec. 5.1):
/// `(O_bs)_aa = (N − N_class(a)) / N`; `(O_bs)_ab = 0` within the same
/// class; `(O_bs)_ab = −sqrt(N_a N_b) / N` across classes.
pub fn core_matrix_subclass(part: &SubclassPartition) -> Mat {
    let counts = part.counts();
    let h = counts.len();
    let n: f64 = counts.iter().map(|&x| x as f64).sum();
    let n_class: Vec<f64> = {
        let n_classes = part.class_of.iter().max().map(|&c| c + 1).unwrap_or(0);
        let mut tot = vec![0.0; n_classes];
        for (s, &cls) in part.class_of.iter().enumerate() {
            tot[cls] += counts[s] as f64;
        }
        tot
    };
    Mat::from_fn(h, h, |a, b| {
        if a == b {
            (n - n_class[part.class_of[a]]) / n
        } else if part.class_of[a] == part.class_of[b] {
            0.0
        } else {
            -((counts[a] as f64) * (counts[b] as f64)).sqrt() / n
        }
    })
}

/// NZEP (U, Ω) of O_bs (Eq. 65) and the target matrix V = R_H N_H^{−1/2} U
/// (Eq. 66). Returns (V, ω) with ω the positive eigenvalues, descending.
pub fn v_matrix(part: &SubclassPartition) -> (Mat, Vec<f64>) {
    let counts = part.counts();
    assert!(counts.iter().all(|&c| c > 0), "empty subclass");
    let h = counts.len();
    let obs = core_matrix_subclass(part);
    let eig = jacobi_eig(&obs);
    let d = eig.values.iter().take_while(|&&v| v > 1e-10).count();
    assert!(d <= h.saturating_sub(1) + 1);
    let inv_sqrt: Vec<f64> = counts.iter().map(|&c| 1.0 / (c as f64).sqrt()).collect();
    let v = Mat::from_fn(part.sub_labels.len(), d, |n, k| {
        let s = part.sub_labels[n];
        eig.vectors[(s, k)] * inv_sqrt[s]
    });
    (v, eig.values[..d].to_vec())
}

// ---------------------------------------------------------------------------
// Central factor matrices (Eq. 29) — O(N²) memory; used by the baselines
// (which must form scatter matrices, that's their cost) and by tests that
// verify the paper's identities. The AKDA fast path never calls these.
// ---------------------------------------------------------------------------

/// C_b = R_C N_C^{−1/2} O_b N_C^{−1/2} R_Cᵀ.
pub fn central_factor_b(labels: &[usize], n_classes: usize) -> Mat {
    let counts = class_counts(labels, n_classes);
    let ob = core_matrix(&counts);
    let n = labels.len();
    let inv_sqrt: Vec<f64> = counts.iter().map(|&c| 1.0 / (c as f64).sqrt()).collect();
    Mat::from_fn(n, n, |i, j| {
        ob[(labels[i], labels[j])] * inv_sqrt[labels[i]] * inv_sqrt[labels[j]]
    })
}

/// C_w = I_N − R_C N_C^{−1} R_Cᵀ.
pub fn central_factor_w(labels: &[usize], n_classes: usize) -> Mat {
    let counts = class_counts(labels, n_classes);
    let n = labels.len();
    Mat::from_fn(n, n, |i, j| {
        let same = if labels[i] == labels[j] {
            1.0 / counts[labels[i]] as f64
        } else {
            0.0
        };
        (if i == j { 1.0 } else { 0.0 }) - same
    })
}

/// C_t = I_N − J_N / N.
pub fn central_factor_t(n: usize) -> Mat {
    let inv = 1.0 / n as f64;
    Mat::from_fn(n, n, |i, j| (if i == j { 1.0 } else { 0.0 }) - inv)
}

/// C_bs (Eq. 57) for the subclass case.
pub fn central_factor_bs(part: &SubclassPartition) -> Mat {
    let counts = part.counts();
    let obs = core_matrix_subclass(part);
    let n = part.sub_labels.len();
    let inv_sqrt: Vec<f64> = counts.iter().map(|&c| 1.0 / (c as f64).sqrt()).collect();
    Mat::from_fn(n, n, |i, j| {
        let (a, b) = (part.sub_labels[i], part.sub_labels[j]);
        obs[(a, b)] * inv_sqrt[a] * inv_sqrt[b]
    })
}

/// C_ws = I_N − R_H N_H^{−1} R_Hᵀ (Eq. 57).
pub fn central_factor_ws(part: &SubclassPartition) -> Mat {
    let counts = part.counts();
    let n = part.sub_labels.len();
    Mat::from_fn(n, n, |i, j| {
        let same = if part.sub_labels[i] == part.sub_labels[j] {
            1.0 / counts[part.sub_labels[i]] as f64
        } else {
            0.0
        };
        (if i == j { 1.0 } else { 0.0 }) - same
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels_3() -> Vec<usize> {
        let mut l = vec![0; 7];
        l.extend(vec![1; 12]);
        l.extend(vec![2; 5]);
        l
    }

    #[test]
    fn core_matrix_is_idempotent_projector() {
        let ob = core_matrix(&[7, 12, 5]);
        assert!(ob.matmul(&ob).sub(&ob).max_abs() < 1e-12, "idempotent");
        // null vector is ṅ (Eq. 32)
        let nd: Vec<f64> = [7.0_f64, 12.0, 5.0].iter().map(|x| x.sqrt()).collect();
        let out = ob.matvec(&nd);
        assert!(out.iter().all(|x| x.abs() < 1e-12));
    }

    #[test]
    fn theta_is_orthonormal_and_in_cb_range() {
        let labels = labels_3();
        let th = theta(&labels, 3);
        assert_eq!(th.shape(), (24, 2));
        // Θᵀ Θ = I (Sec. 4.3)
        assert!(th.matmul_tn(&th).sub(&Mat::eye(2)).max_abs() < 1e-10);
        // Θᵀ C_b Θ = I (Eq. 41)
        let cb = central_factor_b(&labels, 3);
        let red = th.matmul_tn(&cb.matmul(&th));
        assert!(red.sub(&Mat::eye(2)).max_abs() < 1e-10);
        // Θᵀ C_w Θ = 0 (Eq. 42)
        let cw = central_factor_w(&labels, 3);
        let red = th.matmul_tn(&cw.matmul(&th));
        assert!(red.max_abs() < 1e-10);
        // Θᵀ C_t Θ = I (Eq. 43)
        let ct = central_factor_t(24);
        let red = th.matmul_tn(&ct.matmul(&th));
        assert!(red.sub(&Mat::eye(2)).max_abs() < 1e-10);
    }

    #[test]
    fn central_factors_satisfy_paper_identities() {
        let labels = labels_3();
        let cb = central_factor_b(&labels, 3);
        let cw = central_factor_w(&labels, 3);
        let ct = central_factor_t(24);
        // C_t = C_b + C_w ; C_b C_w = 0 (Sec. 4.2)
        assert!(cb.add(&cw).sub(&ct).max_abs() < 1e-12);
        assert!(cb.matmul(&cw).max_abs() < 1e-12);
        // idempotency
        for m in [&cb, &cw, &ct] {
            assert!(m.matmul(m).sub(m).max_abs() < 1e-10);
        }
        // ranks (Eqs. 33-35) via eigenvalue counting
        let rank = |m: &Mat| {
            crate::linalg::sym_eig(m)
                .unwrap()
                .values
                .iter()
                .filter(|v| v.abs() > 1e-8)
                .count()
        };
        assert_eq!(rank(&cb), 2); // C-1
        assert_eq!(rank(&cw), 24 - 3); // N-C
        assert_eq!(rank(&ct), 23); // N-1
    }

    #[test]
    fn theta_binary_matches_evd_route() {
        let labels: Vec<usize> = vec![0; 30].into_iter().chain(vec![1; 70]).collect();
        let ana = theta_binary(&labels);
        let evd = theta(&labels, 2);
        // same up to sign
        let sign = (ana[(0, 0)] * evd[(0, 0)]).signum();
        assert!(ana.sub(&evd.scale(sign)).max_abs() < 1e-10);
        // unit norm (Sec. 4.4)
        let n: f64 = ana.data().iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 1e-12);
    }

    #[test]
    fn theta_binary_paper_toy_values() {
        // Sec. 6.2: N1=100, N2=5000 gives theta entries ±0.09901 / ∓0.00198
        let labels: Vec<usize> = vec![0; 100].into_iter().chain(vec![1; 5000]).collect();
        let th = theta_binary(&labels);
        assert!((th[(0, 0)].abs() - 0.09901).abs() < 1e-5);
        assert!((th[(5099, 0)].abs() - 0.00198).abs() < 1e-5);
    }

    #[test]
    fn subclass_core_matrix_matches_closed_form() {
        // O_bs = I_H − (1/N) Ṅ_H − Ṅ_H ⊛ E (Eq. 60)... verified via its
        // defining properties: SPSD, rank H−1, null vector ṅ_H (Eq. 61-62)
        let part = SubclassPartition {
            sub_labels: [vec![0; 5], vec![1; 9], vec![2; 4], vec![3; 6], vec![4; 7]].concat(),
            class_of: vec![0, 0, 1, 1, 2],
        };
        let obs = core_matrix_subclass(&part);
        let e = jacobi_eig(&obs);
        assert!(e.values.iter().all(|&v| v > -1e-10), "SPSD");
        assert_eq!(e.values.iter().filter(|&&v| v > 1e-10).count(), 4);
        let nd: Vec<f64> = part.counts().iter().map(|&c| (c as f64).sqrt()).collect();
        assert!(obs.matvec(&nd).iter().all(|x| x.abs() < 1e-12));
    }

    #[test]
    fn v_matrix_simultaneous_reduction() {
        // V^T C_bs V = Ω, V^T C_ws V = 0, V^T C_t V = I (Eqs. 67-69)
        let part = SubclassPartition {
            sub_labels: [vec![0; 8], vec![1; 6], vec![2; 10], vec![3; 7]].concat(),
            class_of: vec![0, 0, 1, 1],
        };
        let n = part.sub_labels.len();
        let (v, omega) = v_matrix(&part);
        assert_eq!(v.cols(), 3);
        let cbs = central_factor_bs(&part);
        let cws = central_factor_ws(&part);
        let ct = central_factor_t(n);
        let red_b = v.matmul_tn(&cbs.matmul(&v));
        assert!(red_b.sub(&Mat::diag(&omega)).max_abs() < 1e-10);
        let red_w = v.matmul_tn(&cws.matmul(&v));
        assert!(red_w.max_abs() < 1e-10);
        let red_t = v.matmul_tn(&ct.matmul(&v));
        assert!(red_t.sub(&Mat::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn trivial_partition_reduces_to_class_case() {
        let labels = labels_3();
        let part = SubclassPartition::trivial(&labels, 3);
        let (v, omega) = v_matrix(&part);
        let th = theta(&labels, 3);
        // both span the same 2-D space: projector difference is zero
        let pv = v.matmul_nt(&v);
        let pt = th.matmul_nt(&th);
        assert!(pv.sub(&pt).max_abs() < 1e-8);
        assert_eq!(omega.len(), 2);
    }

    #[test]
    #[should_panic(expected = "both classes must be non-empty")]
    fn theta_binary_rejects_single_class() {
        theta_binary(&[0, 0, 0]);
    }
}
