//! LDA baseline — linear discriminant analysis in the input space.
//!
//! The paper's linear comparator (Sec. 6.3): under the small-sample-size
//! regime Σ_w is severely ill-posed and LDA degrades, which Tables 2–4
//! show; the ridge keeps it runnable.

use anyhow::Result;

use super::{DrMethod, LinearProjection, Projection};
use crate::linalg::{chol, sym_eig_desc, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Lda {
    pub eps: f64,
}

impl Lda {
    pub fn new() -> Self {
        Lda { eps: 1e-3 }
    }
}

impl Default for Lda {
    fn default() -> Self {
        Self::new()
    }
}

impl DrMethod for Lda {
    fn name(&self) -> &'static str {
        "lda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let (n, l) = x.shape();
        // class means + global mean
        let counts = crate::da::core::class_counts(labels, n_classes);
        let mut means = Mat::zeros(n_classes, l);
        let mut mean = vec![0.0; l];
        for i in 0..n {
            for j in 0..l {
                means[(labels[i], j)] += x[(i, j)];
                mean[j] += x[(i, j)];
            }
        }
        for c in 0..n_classes {
            let inv = 1.0 / counts[c] as f64;
            for v in means.row_mut(c) {
                *v *= inv;
            }
        }
        for v in mean.iter_mut() {
            *v /= n as f64;
        }
        // Σ_b = Σ N_i (μ_i − μ)(μ_i − μ)ᵀ ; Σ_w = Σ (x − μ_c)(x − μ_c)ᵀ
        let mut sb = Mat::zeros(l, l);
        for c in 0..n_classes {
            let d: Vec<f64> = (0..l).map(|j| means[(c, j)] - mean[j]).collect();
            let w = counts[c] as f64;
            for a in 0..l {
                for b in 0..l {
                    sb[(a, b)] += w * d[a] * d[b];
                }
            }
        }
        let mut sw = Mat::zeros(l, l);
        for i in 0..n {
            let d: Vec<f64> =
                (0..l).map(|j| x[(i, j)] - means[(labels[i], j)]).collect();
            for a in 0..l {
                for b in 0..l {
                    sw[(a, b)] += d[a] * d[b];
                }
            }
        }
        sw.add_ridge(self.eps * (1.0 + sw.max_abs()));
        // simultaneous reduction via Cholesky + symmetric QR
        let lchol = chol::cholesky(&sw, chol::DEFAULT_BLOCK)
            .map_err(|e| anyhow::anyhow!("LDA Σ_w Cholesky: {e}"))?;
        let y = chol::solve_lower(&lchol, &sb);
        let m = chol::solve_lower(&lchol, &y.transpose());
        let m = m.add(&m.transpose()).scale(0.5);
        let eig = sym_eig_desc(&m).map_err(|e| anyhow::anyhow!("LDA EVD: {e}"))?;
        let d = (n_classes - 1).min(l);
        let mut u = Mat::zeros(l, d);
        for c in 0..d {
            for r in 0..l {
                u[(r, c)] = eig.vectors[(r, c)];
            }
        }
        let w = chol::solve_upper_from_lower(&lchol, &u);
        Ok(Box::new(LinearProjection { w, mean }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    #[test]
    fn lda_separates_linear_problem() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![40, 40],
            dim: 6,
            class_sep: 3.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 1,
        });
        let proj = Lda::new().fit(&x, &labels, 2).unwrap();
        assert_eq!(proj.dim(), 1);
        let z = proj.project(&x);
        let m0 = (0..40).map(|i| z[(i, 0)]).sum::<f64>() / 40.0;
        let m1 = (40..80).map(|i| z[(i, 0)]).sum::<f64>() / 40.0;
        let sd0 = ((0..40).map(|i| (z[(i, 0)] - m0).powi(2)).sum::<f64>() / 40.0).sqrt();
        assert!((m0 - m1).abs() > 4.0 * sd0, "fisher separation");
    }

    #[test]
    fn lda_sss_regime_is_finite() {
        // n < dim: Σ_w singular — ridge must keep the solve alive
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![5, 5],
            dim: 32,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 2,
        });
        let proj = Lda::new().fit(&x, &labels, 2).unwrap();
        assert!(proj.project(&x).is_finite());
    }

    #[test]
    fn lda_multiclass_dim() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 5,
            n_per_class: vec![20; 5],
            dim: 8,
            class_sep: 2.0,
            noise: 0.6,
            modes_per_class: 1,
            seed: 3,
        });
        let proj = Lda::new().fit(&x, &labels, 5).unwrap();
        assert_eq!(proj.dim(), 4);
    }
}
