//! Approximate AKDA: Algorithm 1's core-matrix + Cholesky pipeline run on
//! an explicit m-dimensional kernel-feature space (m ≪ N) instead of the
//! implicit N-dimensional kernel expansion.
//!
//! Steps: (1) target matrix Θ from the C×C core matrix, exactly as exact
//! AKDA (O(C³), binary analytic fast path included); (2) features
//! Φ = φ(X) via a pluggable `approx::FeatureMap` (Nyström landmarks or
//! RFF) — O(N m F); (3) solve (ΦᵀΦ + εI) W = ΦᵀΘ by Cholesky — O(N m²)
//! to form the m×m Gram plus m³/3 for the factorization. Training drops
//! from O(N³) to O(N m²).
//!
//! Why this is the right system: with Ψ the exact solution of
//! (K + εI) Ψ = Θ (Eq. 44) and K = Φ Φᵀ, the feature-space weights
//! W = (ΦᵀΦ + εI)⁻¹ ΦᵀΘ produce the *same* projections φ(x)ᵀW as the
//! kernel expansion k(x,·)ᵀΨ — the push-through identity
//! Φᵀ(ΦΦᵀ + εI)⁻¹ = (ΦᵀΦ + εI)⁻¹Φᵀ. The `nystrom_full_landmarks_*` test
//! verifies the m = N case end-to-end against `Akda`.

use std::sync::Arc;

use anyhow::Result;

use super::core;
use super::{DrMethod, Projection};
use crate::approx::{ApproxKind, FeatureMap, NystromMap, RffMap};
use crate::kernels::Kernel;
use crate::linalg::{chol, Mat};

/// Approximate-AKDA configuration.
#[derive(Debug, Clone, Copy)]
pub struct AkdaApprox {
    pub kernel: Kernel,
    /// Ridge added to ΦᵀΦ (the feature-space mirror of Sec. 4.3's ε).
    pub eps: f64,
    /// Cholesky block size (perf knob; output is block-size invariant).
    pub block: usize,
    /// Which feature approximator to build.
    pub kind: ApproxKind,
    /// Landmark (Nyström) or random-feature (RFF) budget m.
    pub m: usize,
    /// Seed for landmark selection / frequency sampling.
    pub seed: u64,
}

impl AkdaApprox {
    /// Nyström-featured AKDA with an m-landmark budget (landmarks picked
    /// by k-means on the training rows; see `approx::NystromMap`).
    ///
    /// # Examples
    ///
    /// ```
    /// use akda::da::akda_approx::AkdaApprox;
    /// use akda::da::{DrMethod, Projection};
    /// use akda::kernels::Kernel;
    /// use akda::linalg::Mat;
    /// use akda::util::rng::Rng;
    ///
    /// // two noisy clusters, labels 0/1
    /// let mut rng = Rng::new(7);
    /// let x = Mat::from_fn(30, 3, |r, _| (r % 2) as f64 * 4.0 + rng.normal());
    /// let labels: Vec<usize> = (0..30).map(|i| i % 2).collect();
    ///
    /// let akda = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.3 }, 8);
    /// let proj = akda.fit(&x, &labels, 2).unwrap();
    /// assert_eq!(proj.dim(), 1); // C - 1 discriminant directions
    /// assert!(proj.project(&x).is_finite());
    /// ```
    pub fn nystrom(kernel: Kernel, m: usize) -> Self {
        AkdaApprox {
            kernel,
            eps: 1e-3,
            block: chol::DEFAULT_BLOCK,
            kind: ApproxKind::Nystrom,
            m,
            seed: 7,
        }
    }

    /// Random-Fourier-featured AKDA with an m-feature budget (RBF kernel
    /// only; the map is data-independent, see `approx::RffMap`).
    ///
    /// # Examples
    ///
    /// ```
    /// use akda::da::akda_approx::AkdaApprox;
    /// use akda::da::{DrMethod, Projection};
    /// use akda::kernels::Kernel;
    /// use akda::linalg::Mat;
    /// use akda::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(3);
    /// let x = Mat::from_fn(24, 4, |r, _| (r % 2) as f64 * 3.0 + rng.normal());
    /// let labels: Vec<usize> = (0..24).map(|i| i % 2).collect();
    ///
    /// let akda = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 64);
    /// let proj = akda.fit(&x, &labels, 2).unwrap();
    /// assert_eq!(proj.dim(), 1);
    /// ```
    pub fn rff(kernel: Kernel, m: usize) -> Self {
        AkdaApprox { kind: ApproxKind::Rff, ..AkdaApprox::nystrom(kernel, m) }
    }

    /// Build the configured feature map from the training rows.
    pub fn build_map(&self, x: &Mat) -> Result<Box<dyn FeatureMap>> {
        Ok(match self.kind {
            ApproxKind::Nystrom => {
                Box::new(NystromMap::fit(x, self.kernel, self.m, self.seed)?)
            }
            ApproxKind::Rff => {
                Box::new(RffMap::fit(x.cols(), self.kernel, self.m, self.seed)?)
            }
        })
    }

    /// Build the entire label-independent training state once: the
    /// feature map, the training features Φ, and the Cholesky factor of
    /// ΦᵀΦ + εI. One-vs-rest loops (coordinator protocol) share it across
    /// the C binary fits, so each per-class fit costs only the RHS ΦᵀΘ
    /// plus two m×m triangular solves — not k-means + transform + m³/3.
    pub fn prepare(&self, x: &Mat) -> Result<PreparedFeatures> {
        // Φ, ΦᵀΦ and the factorization all run on the globally selected
        // linalg backend; record the choice for the MANIFEST health map
        crate::obs::flight::record(
            "backend",
            crate::linalg::backend::global_kind().id() as f64,
        );
        let map: Arc<dyn FeatureMap> = Arc::from(self.build_map(x)?);
        let phi = map.transform(x);
        let gram = phi.matmul_tn(&phi);
        let mut c = gram.clone();
        c.add_ridge(self.eps);
        let chol_l = chol::cholesky(&c, self.block)
            .map_err(|e| anyhow::anyhow!("approximate AKDA Cholesky failed: {e}"))?;
        Ok(PreparedFeatures { map, phi, gram, chol_l })
    }
}

/// Label-independent training state shared across per-label fits.
pub struct PreparedFeatures {
    pub map: Arc<dyn FeatureMap>,
    /// N×m training features Φ (also the per-class z_train source:
    /// z_train = Φ W).
    pub phi: Mat,
    /// Pre-ridge m×m Gram G = ΦᵀΦ — kept (like `PreparedStream`'s) so the
    /// model subsystem can persist it as resume state without recomputing
    /// the O(N·m²) product.
    gram: Mat,
    /// Lower Cholesky factor of ΦᵀΦ + εI.
    chol_l: Mat,
}

impl PreparedFeatures {
    /// The pre-ridge m×m Gram accumulator G = ΦᵀΦ (resume state).
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// Solve for one labelling reusing the cached factorization: only the
    /// RHS ΦᵀΘ and two m×m triangular solves per call.
    ///
    /// # Examples
    ///
    /// One prepared state, several one-vs-rest fits:
    ///
    /// ```
    /// use akda::da::akda_approx::AkdaApprox;
    /// use akda::kernels::Kernel;
    /// use akda::linalg::Mat;
    /// use akda::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(11);
    /// let x = Mat::from_fn(30, 3, |r, _| (r % 3) as f64 * 3.0 + rng.normal());
    /// let labels: Vec<usize> = (0..30).map(|i| i % 3).collect();
    ///
    /// let akda = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.3 }, 10);
    /// let prep = akda.prepare(&x).unwrap(); // map + Φ + Cholesky, once
    /// for cls in 0..3 {
    ///     let y_bin: Vec<usize> = labels.iter().map(|&l| usize::from(l != cls)).collect();
    ///     let proj = prep.fit(&y_bin, 2).unwrap(); // RHS + triangular solves only
    ///     assert_eq!(proj.w.rows(), prep.map.dim());
    ///     assert_eq!(proj.w.cols(), 1);
    /// }
    /// ```
    pub fn fit(&self, labels: &[usize], n_classes: usize) -> Result<ApproxProjection> {
        let theta = core::theta_for(labels, n_classes);
        let b = self.phi.matmul_tn(&theta);
        let y = chol::solve_lower(&self.chol_l, &b);
        let w = chol::solve_upper_from_lower(&self.chol_l, &y);
        Ok(ApproxProjection { map: self.map.clone(), w })
    }
}

impl DrMethod for AkdaApprox {
    fn name(&self) -> &'static str {
        match self.kind {
            ApproxKind::Nystrom => "akda-nystrom",
            ApproxKind::Rff => "akda-rff",
        }
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        Ok(Box::new(self.prepare(x)?.fit(labels, n_classes)?))
    }
}

/// Fitted approximate projection: z = Wᵀ φ(x). Test-time cost is O(m F)
/// per observation — independent of the training-set size N, unlike
/// `KernelProjection`'s O(N F).
pub struct ApproxProjection {
    /// Shared so OvR loops reuse one map across the C per-class models.
    pub map: Arc<dyn FeatureMap>,
    pub w: Mat,
}

impl Projection for ApproxProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        self.map.transform(x_test).matmul(&self.w)
    }

    fn dim(&self) -> usize {
        self.w.cols()
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::da::akda::Akda;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 8,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    /// Max |a − b| after aligning each column's sign (projections are
    /// defined up to per-direction sign).
    fn sign_aligned_gap(a: &Mat, b: &Mat) -> f64 {
        assert_eq!(a.shape(), b.shape());
        let mut worst = 0.0_f64;
        for c in 0..a.cols() {
            let dot: f64 = (0..a.rows()).map(|r| a[(r, c)] * b[(r, c)]).sum();
            let s = if dot >= 0.0 { 1.0 } else { -1.0 };
            for r in 0..a.rows() {
                worst = worst.max((a[(r, c)] - s * b[(r, c)]).abs());
            }
        }
        worst
    }

    #[test]
    fn nystrom_full_landmarks_matches_exact_akda_binary() {
        // Satellite regression: with landmarks = N the Nyström features
        // reproduce K exactly, so the feature-space solve must give the
        // exact AKDA projections (up to sign).
        let (x, labels) = toy(20, 2, 1);
        let kernel = Kernel::Rbf { rho: 0.4 };
        let exact = Akda { kernel, eps: 1e-3, block: 32 };
        let approx = AkdaApprox::nystrom(kernel, 40);
        let pe = exact.fit(&x, &labels, 2).unwrap();
        let pa = approx.fit(&x, &labels, 2).unwrap();
        let (xt, _) = toy(15, 2, 9);
        let gap = sign_aligned_gap(&pe.project(&xt), &pa.project(&xt));
        assert!(gap < 1e-5, "projection gap {gap}");
    }

    #[test]
    fn nystrom_full_landmarks_matches_exact_akda_multiclass() {
        let (x, labels) = toy(15, 3, 2);
        let kernel = Kernel::Rbf { rho: 0.3 };
        let exact = Akda { kernel, eps: 1e-3, block: 32 };
        let approx = AkdaApprox::nystrom(kernel, 45);
        let pe = exact.fit(&x, &labels, 3).unwrap();
        let pa = approx.fit(&x, &labels, 3).unwrap();
        assert_eq!(pa.dim(), 2);
        let (xt, _) = toy(10, 3, 11);
        let gap = sign_aligned_gap(&pe.project(&xt), &pa.project(&xt));
        assert!(gap < 1e-5, "projection gap {gap}");
    }

    fn separation_gap(z: &Mat, labels: &[usize]) -> f64 {
        let n = z.rows();
        let z0: Vec<f64> = (0..n).filter(|&i| labels[i] == 0).map(|i| z[(i, 0)]).collect();
        let z1: Vec<f64> = (0..n).filter(|&i| labels[i] == 1).map(|i| z[(i, 0)]).collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (m0, m1) = (mean(&z0), mean(&z1));
        let sd = |v: &[f64], m: f64| {
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        (m0 - m1).abs() / (sd(&z0, m0) + sd(&z1, m1)).max(1e-12)
    }

    #[test]
    fn nystrom_with_few_landmarks_still_separates() {
        let (x, labels) = toy(40, 2, 3);
        let approx = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, 16);
        let proj = approx.fit(&x, &labels, 2).unwrap();
        assert!(proj.dim() >= 1);
        let gap = separation_gap(&proj.project(&x), &labels);
        assert!(gap > 2.0, "class separation too weak: {gap}");
    }

    #[test]
    fn rff_separates_classes() {
        let (x, labels) = toy(40, 2, 4);
        let approx = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 256);
        let proj = approx.fit(&x, &labels, 2).unwrap();
        assert_eq!(proj.dim(), 1);
        let gap = separation_gap(&proj.project(&x), &labels);
        assert!(gap > 2.0, "class separation too weak: {gap}");
    }

    #[test]
    fn method_names_reflect_the_approximator() {
        let kernel = Kernel::Rbf { rho: 0.1 };
        assert_eq!(AkdaApprox::nystrom(kernel, 8).name(), "akda-nystrom");
        assert_eq!(AkdaApprox::rff(kernel, 8).name(), "akda-rff");
    }

    #[test]
    fn rff_rejects_linear_kernel_at_fit_time() {
        let (x, labels) = toy(10, 2, 5);
        let approx = AkdaApprox::rff(Kernel::Linear, 32);
        assert!(approx.fit(&x, &labels, 2).is_err());
    }
}
