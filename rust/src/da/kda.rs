//! Conventional KDA baseline [24], [25] — the comparator every speedup in
//! Tables 5–7 is measured against.
//!
//! Deliberately implemented the *expensive* way the paper costs it
//! (Sec. 4.5, (13+1/3)N³ + 2N²F flops): form S_b and S_w as N×N scatter
//! kernel matrices (2N³), Cholesky-factor S_w + εI (N³/3), form
//! L⁻¹ S_b L⁻ᵀ (2N³), and run the full symmetric QR eigensolver (9N³).

use anyhow::Result;

use super::core;
use super::{DrMethod, KernelProjection, Projection};
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol, sym_eig_desc, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Kda {
    pub kernel: Kernel,
    pub eps: f64,
}

impl Kda {
    pub fn new(kernel: Kernel) -> Self {
        Kda { kernel, eps: 1e-3 }
    }

    /// The simultaneous-reduction pipeline shared with KSDA: given the
    /// between-factor C_b* and within-factor C_w*, solve the GEP
    /// (K C_b K) Ψ = λ (K C_w K + εI) Ψ and keep the top `d` eigenvectors.
    pub(crate) fn solve_gep(
        k: &Mat,
        cb: &Mat,
        cw: &Mat,
        eps: f64,
        d: usize,
    ) -> Result<Mat> {
        // S_b = K C_b K, S_w = K C_w K  (the 2N³ the paper charges)
        let sb = k.matmul(&cb.matmul(k));
        let mut sw = k.matmul(&cw.matmul(k));
        sw.add_ridge(eps * (1.0 + sw.max_abs()));
        // Cholesky of S_w (N³/3)
        let l = chol::cholesky(&sw, chol::DEFAULT_BLOCK)
            .map_err(|e| anyhow::anyhow!("KDA S_w Cholesky: {e}"))?;
        // M = L⁻¹ S_b L⁻ᵀ (2N³)
        let y = chol::solve_lower(&l, &sb);
        let m = chol::solve_lower(&l, &y.transpose());
        // enforce symmetry lost to round-off before the QR eigensolver
        let m = m.add(&m.transpose()).scale(0.5);
        // EVD via symmetric QR (9N³)
        let eig = sym_eig_desc(&m).map_err(|e| anyhow::anyhow!("KDA EVD: {e}"))?;
        let mut u = Mat::zeros(m.rows(), d);
        for c in 0..d {
            for r in 0..m.rows() {
                u[(r, c)] = eig.vectors[(r, c)];
            }
        }
        // Ψ = L⁻ᵀ U
        Ok(chol::solve_upper_from_lower(&l, &u))
    }
}

impl DrMethod for Kda {
    fn name(&self) -> &'static str {
        "kda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let k = gram(x, self.kernel);
        let cb = core::central_factor_b(labels, n_classes);
        let cw = core::central_factor_w(labels, n_classes);
        let psi = Self::solve_gep(&k, &cb, &cw, self.eps, n_classes - 1)?;
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: None,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 6,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    #[test]
    fn kda_separates_binary_classes() {
        let (x, labels) = toy(30, 2, 1);
        let proj = Kda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 2).unwrap();
        assert_eq!(proj.dim(), 1);
        let z = proj.project(&x);
        let m0 = (0..30).map(|i| z[(i, 0)]).sum::<f64>() / 30.0;
        let m1 = (30..60).map(|i| z[(i, 0)]).sum::<f64>() / 30.0;
        assert!((m0 - m1).abs() > 1e-3);
    }

    #[test]
    fn kda_and_akda_span_same_subspace_on_training_data() {
        // AKDA ≡ KNDA maximizes between-scatter in null(S_w); with a
        // well-conditioned kernel both methods produce projections that
        // order the two classes identically.
        let (x, labels) = toy(25, 2, 3);
        let kda_z = Kda::new(Kernel::Rbf { rho: 0.5 })
            .fit(&x, &labels, 2).unwrap().project(&x);
        let akda_z = super::super::akda::Akda::new(Kernel::Rbf { rho: 0.5 })
            .fit(&x, &labels, 2).unwrap().project(&x);
        // correlation magnitude between the two 1-D embeddings ≈ 1
        let center = |v: Vec<f64>| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            v.into_iter().map(|x| x - m).collect::<Vec<f64>>()
        };
        let a = center((0..50).map(|i| kda_z[(i, 0)]).collect());
        let b = center((0..50).map(|i| akda_z[(i, 0)]).collect());
        let corr = crate::linalg::dot(&a, &b)
            / (crate::linalg::dot(&a, &a).sqrt() * crate::linalg::dot(&b, &b).sqrt());
        assert!(corr.abs() > 0.95, "corr={corr}");
    }

    #[test]
    fn multiclass_dims() {
        let (x, labels) = toy(15, 4, 5);
        let proj = Kda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 4).unwrap();
        assert_eq!(proj.dim(), 3);
        assert!(proj.project(&x).is_finite());
    }
}
