//! AKDA (Algorithm 1) — the paper's primary contribution, native engine.
//!
//! Steps: (1) core matrix O_b and its NZEP Ξ — O(C³); (2) Θ = R N^{-1/2} Ξ
//! — O(NC); (3) Gram matrix K — 2N²F; (4) solve K Ψ = Θ by Cholesky —
//! N³/3 + 2N²(C−1). No scatter matrix is ever formed; the only
//! eigenproblem is C×C. The binary case (Sec. 4.4) skips even that via the
//! analytic θ (Eq. 50).
//!
//! **Why a linear solve replaces the eigenproblem.** Conventional KDA
//! diagonalizes S_b ψ = λ S_t ψ with S_b = K C_b K, S_t = K C_t K
//! (N×N, O(N³) per iteration plus the scatter construction). The target
//! matrix Θ from `da::core` is the NZEP of the central factor C_b and
//! simultaneously reduces all three central factors (Θᵀ C_b Θ = I,
//! Θᵀ C_w Θ = 0, Θᵀ C_t Θ = I — Eqs. 41–43). Substituting Ψ = K⁻¹Θ
//! (computed here as the solution of K Ψ = Θ, Eq. 44) turns those
//! identities into the scatter-space reductions
//!
//!   Ψᵀ S_b Ψ = I,   Ψᵀ S_w Ψ = 0,   Ψᵀ S_t Ψ = I   (Eqs. 45–47)
//!
//! — exactly the simultaneous diagonalization KDA's eigenproblem seeks,
//! with eigenvalue 1 in every retained direction (the discriminant
//! criterion is saturated; the `simultaneous_reduction_holds` test checks
//! all three identities numerically). When K is ill-conditioned, K + εI
//! regularizes the solve (Sec. 4.3) at O(ε) perturbation of the
//! projections.
//!
//! This is the *native* engine (pure Rust, used by the baselines' timing
//! comparison and as a cross-check); the *accelerated* engine that routes
//! the Gram+Cholesky hot spots through the Pallas/PJRT artifacts lives in
//! `crate::runtime::engine`; the large-N approximations live in
//! `da::akda_approx` (in-memory, O(N m²)) and `da::akda_stream`
//! (out-of-core, peak memory independent of N).

use anyhow::Result;

use super::core;
use super::{DrMethod, KernelProjection, Projection};
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol, Mat};

/// Record the extreme diagonal entries (pivots) of a lower Cholesky
/// factor into the training flight recorder — the conditioning facts
/// of the regularized kernel system (`pivot_min` collapsing toward 0
/// means K + εI is nearly singular despite the ridge). Shared with the
/// continual-update paths, which factorize through other routes.
pub(crate) fn record_pivots(l: &Mat) {
    let n = l.rows().min(l.cols());
    if n == 0 {
        return;
    }
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for i in 0..n {
        let d = l[(i, i)];
        min = min.min(d);
        max = max.max(d);
    }
    crate::obs::flight::record("chol_pivot_min", min);
    crate::obs::flight::record("chol_pivot_max", max);
}

/// AKDA configuration.
#[derive(Debug, Clone, Copy)]
pub struct Akda {
    pub kernel: Kernel,
    /// Ridge added to K when ill-posed (Sec. 4.3).
    pub eps: f64,
    /// Cholesky block size (perf knob; output is block-size invariant).
    pub block: usize,
}

impl Akda {
    pub fn new(kernel: Kernel) -> Self {
        Akda { kernel, eps: 1e-3, block: chol::DEFAULT_BLOCK }
    }

    /// Θ (binary analytic fast path, Sec. 4.4) plus the lower Cholesky
    /// factor of K + εI — the single label/factor builder behind
    /// [`Self::solve_psi`] and [`Self::fit_with_factor`], so the two can
    /// never drift apart in ridge or Θ handling.
    fn theta_and_factor(
        &self,
        x: &Mat,
        labels: &[usize],
        n_classes: usize,
    ) -> Result<(Mat, Mat)> {
        // Step 1-2: Θ (binary analytic fast path, Sec. 4.4)
        let theta = {
            let _phase = crate::obs::span("nzep");
            core::theta_for(labels, n_classes)
        };
        // Step 3: K — on the globally selected linalg backend; record
        // which one so it lands in the MANIFEST health map
        crate::obs::flight::record(
            "backend",
            crate::linalg::backend::global_kind().id() as f64,
        );
        let gram_start = std::time::Instant::now();
        let mut k = gram(x, self.kernel);
        crate::obs::flight::record("phase_gram_s", gram_start.elapsed().as_secs_f64());
        k.add_ridge(self.eps);
        crate::obs::flight::record("eps", self.eps);
        let chol_start = std::time::Instant::now();
        let l = chol::cholesky(&k, self.block)
            .map_err(|e| anyhow::anyhow!("AKDA Cholesky failed: {e}"))?;
        crate::obs::flight::record("phase_chol_s", chol_start.elapsed().as_secs_f64());
        record_pivots(&l);
        Ok((theta, l))
    }

    /// Compute the expansion coefficients Ψ (Eq. 44) plus the target Θ.
    pub fn solve_psi(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<(Mat, Mat)> {
        // Step 4: K Ψ = Θ via Cholesky + two triangular solves
        let (theta, l) = self.theta_and_factor(x, labels, n_classes)?;
        let _phase = crate::obs::span("solve");
        let psi = chol::solve_upper_from_lower(&l, &chol::solve_lower(&l, &theta));
        Ok((psi, theta))
    }

    /// [`DrMethod::fit`] plus the lower Cholesky factor of K + εI it
    /// produced — the continual-learning entry point: `akda train`
    /// persists the factor (`model::codec` resume sections) so `akda
    /// update` can later grow it by bordered rows (`da::incremental`)
    /// instead of refactorizing. Same [`Self::theta_and_factor`] and the
    /// same two triangular solves as [`Self::solve_psi`], so the returned
    /// projection is bit-for-bit what `fit` produces.
    pub fn fit_with_factor(
        &self,
        x: &Mat,
        labels: &[usize],
        n_classes: usize,
    ) -> Result<(KernelProjection, Mat)> {
        let (theta, l) = self.theta_and_factor(x, labels, n_classes)?;
        let psi = {
            let _phase = crate::obs::span("solve");
            chol::solve_upper_from_lower(&l, &chol::solve_lower(&l, &theta))
        };
        let proj = KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: None,
        };
        Ok((proj, l))
    }
}

impl DrMethod for Akda {
    fn name(&self) -> &'static str {
        "akda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let (psi, _) = self.solve_psi(x, labels, n_classes)?;
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: None,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};
    use crate::util::rng::Rng;

    fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 8,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    #[test]
    fn simultaneous_reduction_holds() {
        // Ψᵀ S_b Ψ = I, Ψᵀ S_w Ψ = 0, Ψᵀ S_t Ψ = I (Eqs. 45-47), with
        // S_* = K C_* K built from the central factors.
        let (x, labels) = toy(20, 3, 1);
        let akda = Akda { kernel: Kernel::Rbf { rho: 0.4 }, eps: 0.0, block: 16 };
        let (psi, _) = akda.solve_psi(&x, &labels, 3).unwrap();
        let k = gram(&x, akda.kernel);
        let cb = core::central_factor_b(&labels, 3);
        let cw = core::central_factor_w(&labels, 3);
        let ct = core::central_factor_t(60);
        let sb = k.matmul(&cb).matmul(&k);
        let sw = k.matmul(&cw).matmul(&k);
        let st = k.matmul(&ct).matmul(&k);
        let rb = psi.matmul_tn(&sb.matmul(&psi));
        let rw = psi.matmul_tn(&sw.matmul(&psi));
        let rt = psi.matmul_tn(&st.matmul(&psi));
        assert!(rb.sub(&Mat::eye(2)).max_abs() < 1e-6, "S_b reduction");
        assert!(rw.max_abs() < 1e-6, "S_w nulled");
        assert!(rt.sub(&Mat::eye(2)).max_abs() < 1e-6, "S_t reduction");
    }

    #[test]
    fn binary_projection_separates_classes() {
        let (x, labels) = toy(40, 2, 2);
        let akda = Akda::new(Kernel::Rbf { rho: 0.5 });
        let proj = akda.fit(&x, &labels, 2).unwrap();
        assert_eq!(proj.dim(), 1);
        let z = proj.project(&x);
        // all class-0 projections on one side of all class-1 projections
        let z0: Vec<f64> = (0..80).filter(|&i| labels[i] == 0).map(|i| z[(i, 0)]).collect();
        let z1: Vec<f64> = (0..80).filter(|&i| labels[i] == 1).map(|i| z[(i, 0)]).collect();
        let m0 = z0.iter().sum::<f64>() / z0.len() as f64;
        let m1 = z1.iter().sum::<f64>() / z1.len() as f64;
        let sd = |v: &[f64], m: f64| {
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        let gap = (m0 - m1).abs() / (sd(&z0, m0) + sd(&z1, m1)).max(1e-12);
        assert!(gap > 3.0, "class separation too weak: {gap}");
    }

    #[test]
    fn fit_with_factor_matches_fit_bitwise() {
        let (x, labels) = toy(15, 3, 8);
        let akda = Akda::new(Kernel::Rbf { rho: 0.35 });
        let via_fit = akda.fit(&x, &labels, 3).unwrap();
        let (proj, l) = akda.fit_with_factor(&x, &labels, 3).unwrap();
        let z_a = via_fit.project(&x);
        let z_b = proj.project(&x);
        assert!(z_a.sub(&z_b).max_abs() == 0.0, "same arithmetic, same bits");
        // the factor really factors K + eps I
        let mut k = gram(&x, akda.kernel);
        k.add_ridge(akda.eps);
        assert!(l.matmul_nt(&l).sub(&k).max_abs() < 1e-9);
    }

    #[test]
    fn multiclass_dim_is_c_minus_1() {
        let (x, labels) = toy(15, 4, 3);
        let proj = Akda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 4).unwrap();
        assert_eq!(proj.dim(), 3);
    }

    #[test]
    fn binary_path_matches_multiclass_path() {
        let (x, labels) = toy(25, 2, 4);
        let akda = Akda::new(Kernel::Rbf { rho: 0.7 });
        let (psi_fast, _) = akda.solve_psi(&x, &labels, 2).unwrap();
        // general EVD route
        let theta_gen = core::theta(&labels, 2);
        let mut k = gram(&x, akda.kernel);
        k.add_ridge(akda.eps);
        let psi_gen = chol::spd_solve(&k, &theta_gen, 32).unwrap();
        // equal up to sign
        let sign = (psi_fast[(0, 0)] * psi_gen[(0, 0)]).signum();
        assert!(psi_fast.sub(&psi_gen.scale(sign)).max_abs() < 1e-8);
    }

    #[test]
    fn linear_kernel_works() {
        let (x, labels) = toy(30, 2, 5);
        let akda = Akda { kernel: Kernel::Linear, eps: 1e-1, block: 32 };
        let proj = akda.fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        assert!(z.is_finite());
    }

    #[test]
    fn projection_of_training_data_equals_k_psi() {
        let (x, labels) = toy(20, 2, 6);
        let akda = Akda::new(Kernel::Rbf { rho: 0.2 });
        let (psi, _) = akda.solve_psi(&x, &labels, 2).unwrap();
        let proj = akda.fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        let k = gram(&x, akda.kernel);
        let want = k.matmul(&psi);
        assert!(z.sub(&want).max_abs() < 1e-9);
    }

    #[test]
    fn unbalanced_classes_handled() {
        let mut rng = Rng::new(7);
        let n0 = 5;
        let n1 = 95;
        let mut x = Mat::zeros(n0 + n1, 4);
        for i in 0..n0 {
            for j in 0..4 {
                x[(i, j)] = 3.0 + 0.3 * rng.normal();
            }
        }
        for i in n0..n0 + n1 {
            for j in 0..4 {
                x[(i, j)] = 0.3 * rng.normal();
            }
        }
        let labels: Vec<usize> = vec![0; n0].into_iter().chain(vec![1; n1]).collect();
        let proj = Akda::new(Kernel::Rbf { rho: 0.5 }).fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        let m0 = (0..n0).map(|i| z[(i, 0)]).sum::<f64>() / n0 as f64;
        let m1 = (n0..n0 + n1).map(|i| z[(i, 0)]).sum::<f64>() / n1 as f64;
        assert!((m0 - m1).abs() > 1e-3);
    }
}
