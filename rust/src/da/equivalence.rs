//! Numerical verification of the paper's theoretical links (Secs. 3.2,
//! 4.3): AKDA ≡ KNDA always; and under the rank condition (Eq. 23) —
//! which holds for SPD kernels — AKDA shares KUDA's whitening property,
//! and the KODA post-step (EVD of Ψᵀ K Ψ) orthogonalizes Γ.
//!
//! These are executable theorems: each function computes both sides of an
//! identity and returns the defect, and the test suite asserts the defects
//! vanish. `cargo test equivalence` regenerates the Sec. 4.3 claims.

use crate::da::core;
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol, jacobi_eig, svd, Mat};

/// Everything needed to check the Sec. 4.3 identities on one problem.
pub struct ReductionReport {
    /// ‖Ψᵀ S_b Ψ − I‖∞  (Eq. 45)
    pub sb_defect: f64,
    /// ‖Ψᵀ S_w Ψ‖∞      (Eq. 46)
    pub sw_defect: f64,
    /// ‖Ψᵀ S_t Ψ − I‖∞  (Eq. 47)
    pub st_defect: f64,
    /// rank(S_t) − rank(S_b) − rank(S_w)  (Eq. 23; 0 for SPD K)
    pub rank_defect: isize,
    /// ‖Γ̃ᵀΓ̃ − I‖∞ after the KODA orthogonalization step
    pub koda_defect: f64,
}

/// Run AKDA on (x, labels) with an SPD kernel and evaluate every identity.
pub fn verify_reduction(x: &Mat, labels: &[usize], n_classes: usize, kernel: Kernel)
    -> ReductionReport {
    let n = x.rows();
    let k = gram(x, kernel);
    let theta = core::theta(labels, n_classes);
    let psi = chol::spd_solve(&k, &theta, 32).expect("SPD kernel");

    let cb = core::central_factor_b(labels, n_classes);
    let cw = core::central_factor_w(labels, n_classes);
    let ct = core::central_factor_t(n);
    let sb = k.matmul(&cb.matmul(&k));
    let sw = k.matmul(&cw.matmul(&k));
    let st = k.matmul(&ct.matmul(&k));

    let d = n_classes - 1;
    let rb = psi.matmul_tn(&sb.matmul(&psi));
    let rw = psi.matmul_tn(&sw.matmul(&psi));
    let rt = psi.matmul_tn(&st.matmul(&psi));
    let sb_defect = rb.sub(&Mat::eye(d)).max_abs();
    let sw_defect = rw.max_abs();
    let st_defect = rt.sub(&Mat::eye(d)).max_abs();

    // rank condition (Eq. 23); scale-relative tolerance
    let rk = |m: &Mat| {
        let scale = m.max_abs().max(1e-300);
        svd::rank(&m.scale(1.0 / scale), 1e-9)
    };
    let rank_defect = rk(&st) as isize - rk(&sb) as isize - rk(&sw) as isize;

    // KODA step: EVD of Ψᵀ K Ψ → Γ ← Ψ Π Q^{-1/2}; then ΓᵀΓ =
    // Q^{-1/2}Πᵀ (ΨᵀKΨ) Π Q^{-1/2} ... = I  ⇔ ‖check‖ small, where
    // ΓᵀΓ = Q^{-1/2} Πᵀ Ψᵀ K Ψ Π Q^{-1/2} evaluated through K's factor.
    let pkp = psi.matmul_tn(&k.matmul(&psi));
    let e = jacobi_eig(&pkp);
    let dq = e.values.len();
    let mut piq = Mat::zeros(dq, dq);
    for c in 0..dq {
        let inv_sqrt = 1.0 / e.values[c].max(1e-300).sqrt();
        for r in 0..dq {
            piq[(r, c)] = e.vectors[(r, c)] * inv_sqrt;
        }
    }
    let gamma_coeff = psi.matmul(&piq); // Γ = Φ Ψ Π Q^{-1/2} → ΓᵀΓ = coeffᵀ K coeff
    let gtg = gamma_coeff.matmul_tn(&k.matmul(&gamma_coeff));
    let koda_defect = gtg.sub(&Mat::eye(dq)).max_abs();

    ReductionReport { sb_defect, sw_defect, st_defect, rank_defect, koda_defect }
}

/// KNDA route (Sec. 3.2): maximize between-class scatter inside the null
/// space of S_w. Returns the maximal between-scatter Rayleigh quotient
/// achieved by AKDA's Ψ relative to the best null-space direction — the
/// equivalence claim is that AKDA already attains the KNDA optimum.
pub fn knda_agreement(x: &Mat, labels: &[usize], n_classes: usize, kernel: Kernel) -> f64 {
    let _n = x.rows();
    let k = gram(x, kernel);
    let theta = core::theta(labels, n_classes);
    let psi = chol::spd_solve(&k, &theta, 32).expect("SPD kernel");
    let cw = core::central_factor_w(labels, n_classes);
    let sw = k.matmul(&cw.matmul(&k));
    // Ψ columns must lie in null(S_w): relative residual ‖S_w Ψ‖/‖S_w‖‖Ψ‖
    let res = sw.matmul(&psi).max_abs();
    res / (sw.max_abs() * psi.max_abs()).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    fn problem(c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![14; c],
            dim: 6,
            class_sep: 2.0,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    #[test]
    fn equivalence_simultaneous_reduction_gaussian_kernel() {
        // Gaussian kernel is strictly PD ⇒ all identities of Sec. 4.3 hold
        let (x, labels) = problem(3, 1);
        let rep = verify_reduction(&x, &labels, 3, Kernel::Rbf { rho: 0.5 });
        assert!(rep.sb_defect < 1e-6, "Eq. 45 defect {}", rep.sb_defect);
        assert!(rep.sw_defect < 1e-6, "Eq. 46 defect {}", rep.sw_defect);
        assert!(rep.st_defect < 1e-6, "Eq. 47 defect {}", rep.st_defect);
    }

    #[test]
    fn equivalence_rank_condition_spd_kernel() {
        // Eq. 23: rank(S_t) = rank(S_b) + rank(S_w) for SPD K
        let (x, labels) = problem(3, 2);
        let rep = verify_reduction(&x, &labels, 3, Kernel::Rbf { rho: 0.8 });
        assert_eq!(rep.rank_defect, 0, "rank condition (Eq. 23)");
    }

    #[test]
    fn equivalence_koda_orthogonalization() {
        let (x, labels) = problem(4, 3);
        let rep = verify_reduction(&x, &labels, 4, Kernel::Rbf { rho: 0.5 });
        assert!(rep.koda_defect < 1e-6, "KODA ΓᵀΓ=I defect {}", rep.koda_defect);
    }

    #[test]
    fn equivalence_akda_lies_in_knda_null_space() {
        let (x, labels) = problem(2, 4);
        let rel = knda_agreement(&x, &labels, 2, Kernel::Rbf { rho: 0.5 });
        assert!(rel < 1e-8, "Ψ not in null(S_w): {rel}");
    }

    #[test]
    fn equivalence_multiclass_and_unbalanced() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![6, 25, 11],
            dim: 5,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 9,
        });
        let rep = verify_reduction(&x, &labels, 3, Kernel::Rbf { rho: 0.4 });
        assert!(rep.sb_defect < 1e-6 && rep.sw_defect < 1e-6 && rep.st_defect < 1e-6);
        assert_eq!(rep.rank_defect, 0);
    }
}
