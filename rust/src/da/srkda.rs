//! SRKDA baseline [34] (Sec. 3.1) — spectral-regression KDA, the paper's
//! "previous state of the art" in training speed.
//!
//! The trick: the eigenvectors Θ̄ of the block-diagonal C̄ are known in
//! closed form (class indicators), so after Gram–Schmidt against 𝟙 the
//! transformation solves the linear system K̄ Ψ̄ = Θ̄ — Cholesky, no EVD.
//! Cost N³/3 + 2N²(F + C − 1) + O(N²) + O(N) (Sec. 4.5); the O(N²)
//! centering term is what AKDA shaves off.

use anyhow::Result;

use super::{DrMethod, KernelProjection, Projection};
use crate::kernels::{center_gram, gram, Kernel};
use crate::linalg::{chol, gram_schmidt, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Srkda {
    pub kernel: Kernel,
    pub eps: f64,
}

impl Srkda {
    pub fn new(kernel: Kernel) -> Self {
        Srkda { kernel, eps: 1e-3 }
    }

    /// Closed-form responses: class indicator vectors orthogonalized
    /// against the all-ones vector (Gram–Schmidt on C̄'s eigenvector set),
    /// yielding C−1 target columns.
    pub fn responses(labels: &[usize], n_classes: usize) -> Mat {
        let n = labels.len();
        let mut cols = Mat::zeros(n, n_classes + 1);
        for i in 0..n {
            cols[(i, 0)] = 1.0; // the 𝟙 vector goes first and is dropped
            cols[(i, labels[i] + 1)] = 1.0;
        }
        let q = gram_schmidt(&cols, 1e-10); // n x C (𝟙 + C−1 independents)
        q.submatrix(0, 1, n, q.cols() - 1)
    }
}

impl DrMethod for Srkda {
    fn name(&self) -> &'static str {
        "srkda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let k = gram(x, self.kernel);
        let mut kbar = center_gram(&k);
        kbar.add_ridge(self.eps);
        let theta_bar = Self::responses(labels, n_classes);
        let psi = chol::spd_solve(&kbar, &theta_bar, chol::DEFAULT_BLOCK)
            .map_err(|e| anyhow::anyhow!("SRKDA Cholesky: {e}"))?;
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: Some(k),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    #[test]
    fn responses_orthonormal_and_orthogonal_to_ones() {
        let labels: Vec<usize> = vec![0; 7].into_iter()
            .chain(vec![1; 12]).chain(vec![2; 5]).collect();
        let r = Srkda::responses(&labels, 3);
        assert_eq!(r.shape(), (24, 2));
        let rtr = r.matmul_tn(&r);
        assert!(rtr.sub(&Mat::eye(2)).max_abs() < 1e-10);
        for c in 0..2 {
            let s: f64 = (0..24).map(|i| r[(i, c)]).sum();
            assert!(s.abs() < 1e-10, "col {c} not centered");
        }
        // responses are constant within a class
        for c in 0..2 {
            for i in 1..7 {
                assert!((r[(i, c)] - r[(0, c)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn srkda_separates_classes() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![20; 3],
            dim: 6,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed: 3,
        });
        let proj = Srkda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 3).unwrap();
        assert_eq!(proj.dim(), 2);
        let z = proj.project(&x);
        assert!(z.is_finite());
        // class means in the subspace are distinct
        let mean = |cls: usize, d: usize| {
            let idx: Vec<usize> = (0..60).filter(|&i| labels[i] == cls).collect();
            idx.iter().map(|&i| z[(i, d)]).sum::<f64>() / idx.len() as f64
        };
        let sep01 = (mean(0, 0) - mean(1, 0)).abs() + (mean(0, 1) - mean(1, 1)).abs();
        let sep02 = (mean(0, 0) - mean(2, 0)).abs() + (mean(0, 1) - mean(2, 1)).abs();
        assert!(sep01 > 1e-4 && sep02 > 1e-4);
    }

    #[test]
    fn srkda_and_akda_agree_on_training_ordering_binary() {
        // SRKDA solves the centered problem; AKDA the uncentered one. On a
        // well-separated binary problem, both 1-D embeddings must rank the
        // two classes apart (|corr| high).
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![25, 25],
            dim: 5,
            class_sep: 3.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 6,
        });
        let z_sr = Srkda::new(Kernel::Rbf { rho: 0.4 })
            .fit(&x, &labels, 2).unwrap().project(&x);
        let z_ak = super::super::akda::Akda::new(Kernel::Rbf { rho: 0.4 })
            .fit(&x, &labels, 2).unwrap().project(&x);
        let center = |v: Vec<f64>| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            v.into_iter().map(|x| x - m).collect::<Vec<f64>>()
        };
        let a = center((0..50).map(|i| z_sr[(i, 0)]).collect());
        let b = center((0..50).map(|i| z_ak[(i, 0)]).collect());
        let corr = crate::linalg::dot(&a, &b)
            / (crate::linalg::dot(&a, &a).sqrt() * crate::linalg::dot(&b, &b).sqrt());
        assert!(corr.abs() > 0.9, "corr={corr}");
    }
}
