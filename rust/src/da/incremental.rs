//! Incremental AKDA — the paper's "recursive learning" future-work
//! direction (Sec. 7), made concrete and multiclass.
//!
//! When B new observations arrive, the regularized kernel matrix grows by
//! a bordered block:
//!
//!   K' = [ K    K_nb ]       L' = [ L        0    ]
//!        [ K_nbᵀ K_bb ]            [ L_21    L_22 ]
//!
//! with  L L_21ᵀ = K_nb  (forward substitution, O(N²) per new row) and
//! L_22 the Cholesky factor of the B×B Schur complement
//! K_bb − L_21 L_21ᵀ — so the factor extends in O(N²·B) instead of
//! refactorizing in O((N+B)³/3). The label side is even cheaper: Θ
//! depends only on the per-class counts (Eq. 40) — after an append the
//! C×C core-matrix NZEP is recomputed in O(C³) (or the analytic binary θ
//! of Eq. 50 in O(N)) and one pair of triangular solves through the
//! maintained factor yields the updated Ψ in O(N²·C). A full
//! refactorization is *structurally impossible* on this path: the type
//! never calls `linalg::chol::cholesky` on the grown system ([`Self::batch_psi`]
//! exists only as a from-scratch comparator for equivalence tests and
//! does not touch the maintained state).
//!
//! The numerical ordering of the bordered growth deliberately mirrors the
//! unblocked column sweep inside `linalg::chol::cholesky`, and the
//! appended kernel entries mirror `kernels::gram`'s RBF evaluation
//! (squared-norm expansion), so for systems that fit in one Cholesky
//! panel the incrementally grown factor is bit-for-bit identical to the
//! batch factor — and ≲1e-12 away otherwise. `tests/continual.rs` pins
//! the ≤1e-10 update-equivalence guarantee end to end.
//!
//! The model subsystem persists this state (`model::codec` resume
//! sections: the factor, the labels, ε) so `akda update` can decode a
//! published artifact, grow it with fresh observations, and republish —
//! the train → publish → serve → update → republish loop of
//! `model::update`.

use anyhow::Result;

use super::core;
use super::KernelProjection;
use crate::kernels::Kernel;
use crate::linalg::{chol, dot, Mat};

/// Upper bound on accepted class ids — same rationale as
/// `da::akda_stream::MAX_STREAM_CLASSES`: one corrupt label in an
/// untrusted update CSV must not force an enormous Θ/class-count
/// allocation.
pub const MAX_CLASSES: usize = crate::da::akda_stream::MAX_STREAM_CLASSES;

/// Incrementally-maintained multiclass AKDA model: training rows, labels,
/// and the growing lower-triangular Cholesky factor of K + εI.
pub struct IncrementalAkda {
    kernel: Kernel,
    eps: f64,
    /// Number of classes (grows if an append introduces a new class id).
    n_classes: usize,
    /// Training rows seen so far (N×F).
    x: Mat,
    /// Cached squared row norms (RBF only — mirrors `kernels::gram`'s
    /// squared-norm expansion so appended entries match the batch Gram).
    sq: Vec<f64>,
    labels: Vec<usize>,
    /// Lower-triangular Cholesky factor of K + εI (N×N, growing).
    l: Mat,
    /// Bordered row/column growths performed since construction.
    growths: usize,
}

impl IncrementalAkda {
    /// Empty model. `n_classes` may be 0 — the class count grows as
    /// labelled observations arrive (and [`Self::psi`] requires every class in
    /// `0..C` to be populated before solving).
    pub fn new(kernel: Kernel, eps: f64, n_classes: usize) -> Self {
        IncrementalAkda {
            kernel,
            eps,
            n_classes,
            x: Mat::zeros(0, 0),
            sq: Vec::new(),
            labels: Vec::new(),
            l: Mat::zeros(0, 0),
            growths: 0,
        }
    }

    /// Resume from persisted state: the training rows, their labels, and
    /// the previously grown Cholesky factor of K + εI — what
    /// `model::codec` stores in the `resume.*` artifact sections. No
    /// factorization happens here; the factor is trusted as stored (the
    /// artifact layer checksums it).
    pub fn from_parts(
        kernel: Kernel,
        eps: f64,
        n_classes: usize,
        x: Mat,
        labels: Vec<usize>,
        chol_l: Mat,
    ) -> Result<Self> {
        let n = x.rows();
        anyhow::ensure!(
            labels.len() == n,
            "resume state mismatch: {} rows vs {} labels",
            n,
            labels.len()
        );
        anyhow::ensure!(
            chol_l.shape() == (n, n),
            "resume state mismatch: factor is {}x{} for {} rows",
            chol_l.rows(),
            chol_l.cols(),
            n
        );
        anyhow::ensure!(
            (0..n).all(|i| chol_l[(i, i)] > 0.0),
            "resume factor has a non-positive diagonal — corrupt state"
        );
        let max_label = labels.iter().copied().max().map(|l| l + 1).unwrap_or(0);
        let n_classes = n_classes.max(max_label);
        anyhow::ensure!(n_classes <= MAX_CLASSES, "class count {n_classes} exceeds cap");
        let sq = match kernel {
            Kernel::Rbf { .. } => (0..n).map(|i| dot(x.row(i), x.row(i))).collect(),
            _ => Vec::new(),
        };
        Ok(IncrementalAkda { kernel, eps, n_classes, x, sq, labels, l: chol_l, growths: 0 })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Training rows accumulated so far (N×F).
    pub fn x_train(&self) -> &Mat {
        &self.x
    }

    pub fn labels(&self) -> &[usize] {
        &self.labels
    }

    /// The maintained lower-triangular factor of K + εI.
    pub fn chol_l(&self) -> &Mat {
        &self.l
    }

    /// Bordered row/column growths performed on this instance. The type
    /// has no full-refactorization path, so after `extend`ing B rows this
    /// is exactly B — the "zero full refits" invariant `akda update`
    /// reports.
    pub fn growths(&self) -> usize {
        self.growths
    }

    /// Append one observation (bordered growth of one row/column).
    pub fn push(&mut self, row: &[f64], label: usize) -> Result<()> {
        let x_new = Mat::from_vec(1, row.len(), row.to_vec());
        self.extend(&x_new, &[label])
    }

    /// Append a batch of B observations with one bordered-Cholesky growth:
    /// the factor is grown once to (N+B)×(N+B) and the new rows are
    /// forward-substituted in sequence — O(N²·B) total, no
    /// refactorization of the existing N×N block.
    ///
    /// New class ids extend the class count (the Θ rebuild picks up the
    /// new per-class counts on the next [`Self::psi`] call).
    ///
    /// # Examples
    ///
    /// ```
    /// use akda::da::incremental::IncrementalAkda;
    /// use akda::kernels::Kernel;
    /// use akda::linalg::Mat;
    /// use akda::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(5);
    /// let x = Mat::from_fn(18, 4, |r, _| (r % 3) as f64 * 3.0 + rng.normal());
    /// let labels: Vec<usize> = (0..18).map(|r| r % 3).collect();
    ///
    /// let mut inc = IncrementalAkda::new(Kernel::Rbf { rho: 0.4 }, 1e-3, 3);
    /// inc.extend(&x.submatrix(0, 0, 12, 4), &labels[..12]).unwrap();
    /// inc.extend(&x.submatrix(12, 0, 6, 4), &labels[12..]).unwrap(); // O(N²·B)
    /// assert_eq!((inc.len(), inc.growths()), (18, 18));
    ///
    /// let psi = inc.psi().unwrap(); // K Ψ = Θ through the grown factor
    /// assert_eq!(psi.shape(), (18, 2)); // C − 1 discriminant directions
    /// let batch = inc.batch_psi().unwrap(); // from-scratch comparator
    /// assert!(psi.sub(&batch).max_abs() < 1e-10);
    /// ```
    pub fn extend(&mut self, x_new: &Mat, labels_new: &[usize]) -> Result<()> {
        let b = x_new.rows();
        anyhow::ensure!(
            b == labels_new.len(),
            "extend mismatch: {} rows vs {} labels",
            b,
            labels_new.len()
        );
        if b == 0 {
            return Ok(());
        }
        let n0 = self.x.rows();
        if n0 > 0 {
            anyhow::ensure!(
                x_new.cols() == self.x.cols(),
                "extend mismatch: {} features vs trained {}",
                x_new.cols(),
                self.x.cols()
            );
        }
        for &l in labels_new {
            anyhow::ensure!(
                l < MAX_CLASSES,
                "label {l} exceeds the class cap {MAX_CLASSES} (corrupt row?)"
            );
        }
        let f = x_new.cols();
        let nt = n0 + b;

        // Everything below mutates LOCALS only and commits at the end, so
        // a rejected observation (singular pivot) leaves the model in its
        // pre-extend state, still valid and still growable.

        // concatenated data + squared-norm cache (built once per extend)
        let mut x_all = Mat::zeros(nt, f);
        for r in 0..n0 {
            x_all.row_mut(r).copy_from_slice(self.x.row(r));
        }
        for r in 0..b {
            x_all.row_mut(n0 + r).copy_from_slice(x_new.row(r));
        }
        let mut sq_all = self.sq.clone();
        if matches!(self.kernel, Kernel::Rbf { .. }) {
            sq_all.extend((0..b).map(|r| dot(x_new.row(r), x_new.row(r))));
        }

        // grow the factor once: old L into the top-left block
        let mut l_new = Mat::zeros(nt, nt);
        for r in 0..n0 {
            l_new.row_mut(r)[..n0].copy_from_slice(self.l.row(r));
        }

        // forward-substitute each new row against everything before it —
        // the same column sweep (and the same dot-product operand order)
        // as the unblocked factorization inside `linalg::chol`
        for k in 0..b {
            let n = n0 + k;
            let (mut l21, kappa) = kernel_column(self.kernel, self.eps, &x_all, &sq_all, n);
            for j in 0..n {
                let s = l21[j] - dot(&l21[..j], &l_new.row(j)[..j]);
                l21[j] = s / l_new[(j, j)];
            }
            let mut d = kappa;
            for t in 0..n {
                d -= l21[t] * l21[t];
            }
            anyhow::ensure!(
                d > 0.0 && d.is_finite(),
                "appended observation {k} makes K + eps*I numerically singular \
                 (Schur pivot {d:.3e}) — raise eps or drop duplicates"
            );
            l_new.row_mut(n)[..n].copy_from_slice(&l21);
            l_new[(n, n)] = d.sqrt();
        }

        // commit
        self.l = l_new;
        self.x = x_all;
        self.sq = sq_all;
        self.labels.extend_from_slice(labels_new);
        self.growths += b;
        let max_label = labels_new.iter().copied().max().unwrap_or(0) + 1;
        self.n_classes = self.n_classes.max(max_label);
        Ok(())
    }

    /// Per-class counts of the observations seen so far.
    pub fn class_counts(&self) -> Vec<usize> {
        core::class_counts(&self.labels, self.n_classes)
    }

    /// Current expansion coefficients Ψ: rebuild Θ from the updated class
    /// counts (O(C³) core-matrix NZEP, or the analytic binary θ) and solve
    /// K Ψ = Θ through the maintained factor — O(N²·C), no
    /// refactorization.
    pub fn psi(&self) -> Result<Mat> {
        let n = self.labels.len();
        anyhow::ensure!(n >= 2, "need at least two observations to solve");
        anyhow::ensure!(self.n_classes >= 2, "need at least two classes to solve");
        let counts = self.class_counts();
        anyhow::ensure!(
            counts.iter().all(|&c| c > 0),
            "every class in 0..{} needs at least one observation (counts {:?})",
            self.n_classes,
            counts
        );
        let theta = core::theta_for(&self.labels, self.n_classes);
        let y = chol::solve_lower(&self.l, &theta);
        Ok(chol::solve_upper_from_lower(&self.l, &y))
    }

    /// The current model as a servable kernel expansion — what
    /// `model::update` republishes after a growth.
    pub fn to_projection(&self) -> Result<KernelProjection> {
        Ok(KernelProjection {
            x_train: self.x.clone(),
            psi: self.psi()?,
            kernel: self.kernel,
            center_against: None,
        })
    }

    /// Project test rows with the current model (kernel expansion route —
    /// same arithmetic as the serving-path `KernelProjection`).
    pub fn project(&self, x_test: &Mat) -> Result<Mat> {
        let psi = self.psi()?;
        let kc = crate::kernels::cross_gram(x_test, &self.x, self.kernel);
        Ok(kc.matmul(&psi))
    }

    /// The batch model over the same data — a from-scratch O(N³/3)
    /// refactorization used ONLY as an equivalence-test comparator; the
    /// maintained state is not touched.
    pub fn batch_psi(&self) -> Result<Mat> {
        anyhow::ensure!(self.labels.len() >= 2, "need at least two observations");
        let counts = self.class_counts();
        anyhow::ensure!(counts.iter().all(|&c| c > 0), "empty class");
        let theta = core::theta_for(&self.labels, self.n_classes);
        let mut k = crate::kernels::gram(&self.x, self.kernel);
        k.add_ridge(self.eps);
        chol::spd_solve(&k, &theta, chol::DEFAULT_BLOCK)
            .map_err(|e| anyhow::anyhow!("batch solve: {e}"))
    }
}

/// Kernel column k(x_n, x_j) for j < n plus the regularized diagonal —
/// mirroring `kernels::gram`'s per-kernel arithmetic (the squared-norm
/// expansion for RBF, with `sq` the cached row norms) so appended entries
/// equal the batch Gram's bit for bit.
fn kernel_column(kernel: Kernel, eps: f64, x_all: &Mat, sq: &[f64], n: usize) -> (Vec<f64>, f64) {
    match kernel {
        Kernel::Rbf { rho } => {
            let sq_n = sq[n];
            let col = (0..n)
                .map(|j| {
                    let g = dot(x_all.row(j), x_all.row(n));
                    let d2 = (sq[j] + sq_n - 2.0 * g).max(0.0);
                    (-rho * d2).exp()
                })
                .collect();
            // gram's diagonal is exp(-rho*0) = 1 exactly; add_ridge adds eps
            (col, 1.0 + eps)
        }
        kernel => {
            let row = x_all.row(n);
            let col = (0..n).map(|j| kernel.eval(x_all.row(j), row)).collect();
            (col, kernel.eval(row, row) + eps)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    fn stream(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 6,
            class_sep: 2.0,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    #[test]
    fn incremental_matches_batch_binary() {
        let (x, labels) = stream(25, 2, 1);
        let kernel = Kernel::Rbf { rho: 0.3 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3, 2);
        for i in 0..x.rows() {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        let psi_inc = inc.psi().unwrap();
        let psi_batch = inc.batch_psi().unwrap();
        assert!(psi_inc.sub(&psi_batch).max_abs() < 1e-8,
                "incremental factor must equal batch factor");
    }

    #[test]
    fn incremental_matches_batch_multiclass() {
        let (x, labels) = stream(12, 4, 7);
        let kernel = Kernel::Rbf { rho: 0.4 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3, 4);
        inc.extend(&x, &labels).unwrap();
        let psi_inc = inc.psi().unwrap();
        assert_eq!(psi_inc.shape(), (48, 3));
        let psi_batch = inc.batch_psi().unwrap();
        assert!(psi_inc.sub(&psi_batch).max_abs() < 1e-10,
                "multiclass bordered growth must match the batch factor");
    }

    #[test]
    fn batch_extend_equals_row_by_row_pushes() {
        let (x, labels) = stream(10, 3, 3);
        let kernel = Kernel::Rbf { rho: 0.5 };
        let mut one = IncrementalAkda::new(kernel, 1e-3, 3);
        for i in 0..x.rows() {
            one.push(x.row(i), labels[i]).unwrap();
        }
        let mut all = IncrementalAkda::new(kernel, 1e-3, 3);
        all.extend(&x, &labels).unwrap();
        assert_eq!(one.growths(), all.growths());
        assert!(
            one.chol_l().sub(all.chol_l()).max_abs() == 0.0,
            "batch extend must perform the identical bordered growths"
        );
    }

    #[test]
    fn factor_stays_valid_under_interleaved_appends() {
        let (x, labels) = stream(15, 2, 2);
        let kernel = Kernel::Rbf { rho: 0.5 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3, 2);
        // interleave classes and check psi after each valid prefix
        let order: Vec<usize> = (0..15).flat_map(|i| [i, i + 15]).collect();
        for (step, &i) in order.iter().enumerate() {
            inc.push(x.row(i), labels[i]).unwrap();
            if step >= 1 {
                let psi = inc.psi().unwrap();
                assert!(psi.is_finite(), "step {step}");
            }
        }
        assert_eq!(inc.len(), 30);
    }

    #[test]
    fn rejects_solve_before_every_class_seen() {
        let (x, _) = stream(5, 2, 3);
        let mut inc = IncrementalAkda::new(Kernel::Linear, 1e-2, 2);
        inc.push(x.row(0), 0).unwrap();
        inc.push(x.row(1), 0).unwrap();
        assert!(inc.psi().is_err());
    }

    #[test]
    fn extend_grows_the_class_count() {
        let (x, labels) = stream(8, 3, 9);
        let mut inc = IncrementalAkda::new(Kernel::Rbf { rho: 0.3 }, 1e-3, 2);
        // start with classes {0,1} only
        let idx01: Vec<usize> = (0..x.rows()).filter(|&i| labels[i] < 2).collect();
        for &i in &idx01 {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        assert_eq!(inc.n_classes(), 2);
        assert_eq!(inc.psi().unwrap().cols(), 1);
        // class 2 arrives: C grows, psi gains a direction
        let idx2: Vec<usize> = (0..x.rows()).filter(|&i| labels[i] == 2).collect();
        let x2 = x.select_rows(&idx2);
        inc.extend(&x2, &vec![2; idx2.len()]).unwrap();
        assert_eq!(inc.n_classes(), 3);
        assert_eq!(inc.psi().unwrap().cols(), 2);
        assert!(inc.psi().unwrap().sub(&inc.batch_psi().unwrap()).max_abs() < 1e-9);
    }

    #[test]
    fn duplicate_observation_survives_with_ridge() {
        let (x, labels) = stream(10, 2, 4);
        let mut inc = IncrementalAkda::new(Kernel::Rbf { rho: 0.2 }, 1e-3, 2);
        for i in 0..x.rows() {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        // exact duplicate: K singular without ridge; must still extend
        inc.push(x.row(0), labels[0]).unwrap();
        assert!(inc.psi().unwrap().is_finite());
    }

    #[test]
    fn projection_separates_after_stream() {
        let (x, labels) = stream(30, 2, 5);
        let kernel = Kernel::Rbf { rho: 0.3 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3, 2);
        inc.extend(&x, &labels).unwrap();
        let (xt, yt) = stream(20, 2, 6);
        let z = inc.project(&xt).unwrap();
        let m0 = (0..40).filter(|&i| yt[i] == 0).map(|i| z[(i, 0)]).sum::<f64>() / 20.0;
        let m1 = (0..40).filter(|&i| yt[i] == 1).map(|i| z[(i, 0)]).sum::<f64>() / 20.0;
        assert!((m0 - m1).abs() > 1e-4);
    }

    #[test]
    fn from_parts_resumes_and_keeps_growing() {
        let (x, labels) = stream(10, 3, 8);
        let kernel = Kernel::Rbf { rho: 0.4 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3, 3);
        inc.extend(&x.submatrix(0, 0, 21, x.cols()), &labels[..21]).unwrap();
        // round-trip through parts (what the artifact layer persists)
        let mut resumed = IncrementalAkda::from_parts(
            kernel,
            inc.eps(),
            inc.n_classes(),
            inc.x_train().clone(),
            inc.labels().to_vec(),
            inc.chol_l().clone(),
        )
        .unwrap();
        assert_eq!(resumed.growths(), 0);
        let tail = x.submatrix(21, 0, x.rows() - 21, x.cols());
        resumed.extend(&tail, &labels[21..]).unwrap();
        inc.extend(&tail, &labels[21..]).unwrap();
        assert!(
            resumed.chol_l().sub(inc.chol_l()).max_abs() == 0.0,
            "resume must continue the identical factor"
        );
        assert!(resumed.psi().unwrap().sub(&resumed.batch_psi().unwrap()).max_abs() < 1e-9);
    }

    #[test]
    fn from_parts_rejects_mismatched_state() {
        let (x, labels) = stream(6, 2, 10);
        let kernel = Kernel::Linear;
        let mut inc = IncrementalAkda::new(kernel, 1e-2, 2);
        inc.extend(&x, &labels).unwrap();
        let l = inc.chol_l().clone();
        // wrong label count
        assert!(IncrementalAkda::from_parts(
            kernel, 1e-2, 2, x.clone(), labels[..5].to_vec(), l.clone()
        )
        .is_err());
        // wrong factor shape
        assert!(IncrementalAkda::from_parts(
            kernel, 1e-2, 2, x.clone(), labels.clone(), Mat::zeros(3, 3)
        )
        .is_err());
    }
}
