//! Incremental AKDA — the paper's "recursive learning" future-work
//! direction (Sec. 7), made concrete.
//!
//! When a new observation arrives, the kernel matrix grows by one
//! bordered row/column:
//!
//!   K' = [ K   k ]        L' = [ L        0 ]
//!        [ kᵀ  κ ]             [ l₂₁ᵀ   l₂₂ ]   with  L l₂₁ = k,
//!                                                l₂₂ = sqrt(κ − l₂₁ᵀl₂₁)
//!
//! so the Cholesky factor extends in O(N²) instead of refactorizing in
//! O(N³/3) — and AKDA's Θ update is O(N) (class counts change, the
//! analytic binary θ or the C×C EVD is recomputed, both trivial).
//! A full fit after n appends therefore costs O(nN²) vs O(nN³) naive.

use anyhow::Result;

use super::core;
use crate::kernels::Kernel;
use crate::linalg::{chol, dot, Mat};

/// Incrementally-maintained binary AKDA model.
pub struct IncrementalAkda {
    kernel: Kernel,
    eps: f64,
    /// training rows seen so far
    x: Vec<Vec<f64>>,
    labels: Vec<usize>,
    /// lower-triangular Cholesky factor of K + εI (row-major, growing)
    l: Mat,
}

impl IncrementalAkda {
    pub fn new(kernel: Kernel, eps: f64) -> Self {
        IncrementalAkda { kernel, eps, x: Vec::new(), labels: Vec::new(), l: Mat::zeros(0, 0) }
    }

    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Append one observation, extending the Cholesky factor in O(N²).
    pub fn push(&mut self, row: &[f64], label: usize) -> Result<()> {
        anyhow::ensure!(label < 2, "binary incremental AKDA takes labels 0/1");
        let n = self.x.len();
        // kernel column against existing data + regularized diagonal
        let k_col: Vec<f64> = self.x.iter().map(|xi| self.kernel.eval(xi, row)).collect();
        let kappa = self.kernel.eval(row, row) + self.eps;
        // forward-substitute L l21 = k
        let mut l21 = k_col;
        for i in 0..n {
            let s = l21[i] - dot(&self.l.row(i)[..i], &l21[..i]);
            l21[i] = s / self.l[(i, i)];
        }
        let d2 = kappa - dot(&l21, &l21);
        anyhow::ensure!(
            d2 > 0.0,
            "appended observation makes K + eps*I numerically singular"
        );
        // grow L by one bordered row/column
        let mut grown = Mat::zeros(n + 1, n + 1);
        for r in 0..n {
            grown.row_mut(r)[..n].copy_from_slice(self.l.row(r));
        }
        grown.row_mut(n)[..n].copy_from_slice(&l21);
        grown[(n, n)] = d2.sqrt();
        self.l = grown;
        self.x.push(row.to_vec());
        self.labels.push(label);
        Ok(())
    }

    /// Current expansion coefficients ψ: solve K ψ = θ through the
    /// maintained factor (O(N²) — no refactorization).
    pub fn psi(&self) -> Result<Mat> {
        let n = self.x.len();
        anyhow::ensure!(n >= 2, "need at least one observation per class");
        anyhow::ensure!(
            self.labels.iter().any(|&l| l == 0) && self.labels.iter().any(|&l| l == 1),
            "need both classes before solving"
        );
        let theta = core::theta_binary(&self.labels);
        let y = chol::solve_lower(&self.l, &theta);
        Ok(chol::solve_upper_from_lower(&self.l, &y))
    }

    /// Project test rows with the current model.
    pub fn project(&self, x_test: &Mat) -> Result<Mat> {
        let psi = self.psi()?;
        let n = self.x.len();
        let kc = Mat::from_fn(x_test.rows(), n, |e, t| {
            self.kernel.eval(x_test.row(e), &self.x[t])
        });
        Ok(kc.matmul(&psi))
    }

    /// The batch model over the same data (for equivalence checks).
    pub fn batch_psi(&self) -> Result<Mat> {
        let n = self.x.len();
        let mut xm = Mat::zeros(n, self.x[0].len());
        for (r, row) in self.x.iter().enumerate() {
            xm.row_mut(r).copy_from_slice(row);
        }
        let mut k = crate::kernels::gram(&xm, self.kernel);
        k.add_ridge(self.eps);
        let theta = core::theta_binary(&self.labels);
        chol::spd_solve(&k, &theta, chol::DEFAULT_BLOCK)
            .map_err(|e| anyhow::anyhow!("batch solve: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};

    fn stream(n_per: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![n_per; 2],
            dim: 6,
            class_sep: 2.0,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    #[test]
    fn incremental_matches_batch() {
        let (x, labels) = stream(25, 1);
        let kernel = Kernel::Rbf { rho: 0.3 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3);
        for i in 0..x.rows() {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        let psi_inc = inc.psi().unwrap();
        let psi_batch = inc.batch_psi().unwrap();
        assert!(psi_inc.sub(&psi_batch).max_abs() < 1e-8,
                "incremental factor must equal batch factor");
    }

    #[test]
    fn factor_stays_valid_under_interleaved_appends() {
        let (x, labels) = stream(15, 2);
        let kernel = Kernel::Rbf { rho: 0.5 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3);
        // interleave classes and check psi after each valid prefix
        let order: Vec<usize> = (0..15).flat_map(|i| [i, i + 15]).collect();
        for (step, &i) in order.iter().enumerate() {
            inc.push(x.row(i), labels[i]).unwrap();
            if step >= 1 {
                let psi = inc.psi().unwrap();
                assert!(psi.is_finite(), "step {step}");
            }
        }
        assert_eq!(inc.len(), 30);
    }

    #[test]
    fn rejects_solve_before_both_classes() {
        let (x, _) = stream(5, 3);
        let mut inc = IncrementalAkda::new(Kernel::Linear, 1e-2);
        inc.push(x.row(0), 0).unwrap();
        inc.push(x.row(1), 0).unwrap();
        assert!(inc.psi().is_err());
    }

    #[test]
    fn duplicate_observation_survives_with_ridge() {
        let (x, labels) = stream(10, 4);
        let mut inc = IncrementalAkda::new(Kernel::Rbf { rho: 0.2 }, 1e-3);
        for i in 0..x.rows() {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        // exact duplicate: K singular without ridge; must still extend
        inc.push(x.row(0), labels[0]).unwrap();
        assert!(inc.psi().unwrap().is_finite());
    }

    #[test]
    fn projection_separates_after_stream() {
        let (x, labels) = stream(30, 5);
        let kernel = Kernel::Rbf { rho: 0.3 };
        let mut inc = IncrementalAkda::new(kernel, 1e-3);
        for i in 0..x.rows() {
            inc.push(x.row(i), labels[i]).unwrap();
        }
        let (xt, yt) = stream(20, 6);
        let z = inc.project(&xt).unwrap();
        let m0 = (0..40).filter(|&i| yt[i] == 0).map(|i| z[(i, 0)]).sum::<f64>() / 20.0;
        let m1 = (0..40).filter(|&i| yt[i] == 1).map(|i| z[(i, 0)]).sum::<f64>() / 20.0;
        assert!((m0 - m1).abs() > 1e-4);
    }
}
