//! AKSDA (Algorithm 2) — the subclass extension of AKDA (Sec. 5).
//!
//! Same accelerated skeleton: k-means subclass partitioning (O(N)), the
//! H×H core matrix O_bs and its NZEP (U, Ω), V = R_H N_H^{−1/2} U, then
//! one Cholesky solve K W = V. D = H − 1.

use anyhow::Result;

use super::core::{self, SubclassPartition};
use super::{DrMethod, KernelProjection, Projection};
use crate::cluster::kmeans::partition_classes;
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Aksda {
    pub kernel: Kernel,
    pub eps: f64,
    /// Subclasses per class (the paper CV-searches H in {2..5}, Sec. 6.3.1).
    pub h_per_class: usize,
    pub seed: u64,
    pub block: usize,
}

impl Aksda {
    pub fn new(kernel: Kernel, h_per_class: usize) -> Self {
        Aksda { kernel, eps: 1e-3, h_per_class, seed: 17, block: chol::DEFAULT_BLOCK }
    }

    /// Fit with an explicit subclass partition (exposed for tests and for
    /// the ablation comparing k-means vs NN partitioning).
    pub fn solve_w(&self, x: &Mat, part: &SubclassPartition) -> Result<(Mat, Vec<f64>)> {
        let (v, omega) = core::v_matrix(part);
        let mut k = gram(x, self.kernel);
        k.add_ridge(self.eps);
        let w = chol::spd_solve(&k, &v, self.block)
            .map_err(|e| anyhow::anyhow!("AKSDA Cholesky failed: {e}"))?;
        Ok((w, omega))
    }
}

impl DrMethod for Aksda {
    fn name(&self) -> &'static str {
        "aksda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let part = partition_classes(x, labels, n_classes, self.h_per_class, self.seed);
        let (w, _) = self.solve_w(x, &part)?;
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi: w,
            kernel: self.kernel,
            center_against: None,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::xor_blobs;

    #[test]
    fn aksda_beats_akda_on_xor() {
        // XOR blobs: class means coincide → unimodal (AKDA) projection is
        // uninformative with a *linear* kernel, while AKSDA with 2
        // subclasses separates the blobs. This is the paper's motivation
        // for the subclass criterion (Sec. 2).
        let (x, labels) = xor_blobs(40, 4, 3.0, 0.3, 7);
        let kernel = Kernel::Linear;

        let fisher = |z: &Mat| {
            let n = z.rows();
            let z0: Vec<f64> = (0..n).filter(|&i| labels[i] == 0).map(|i| z[(i, 0)]).collect();
            let z1: Vec<f64> = (0..n).filter(|&i| labels[i] == 1).map(|i| z[(i, 0)]).collect();
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            let var = |v: &[f64], m: f64| {
                v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64
            };
            let (m0, m1) = (mean(&z0), mean(&z1));
            (m0 - m1) * (m0 - m1) / (var(&z0, m0) + var(&z1, m1)).max(1e-12)
        };

        let akda = super::super::akda::Akda { kernel, eps: 1e-2, block: 32 };
        let z_akda = akda.fit(&x, &labels, 2).unwrap().project(&x);
        let aksda = Aksda { kernel, eps: 1e-2, h_per_class: 2, seed: 3, block: 32 };
        let proj = aksda.fit(&x, &labels, 2).unwrap();
        let z_aksda = proj.project(&x);
        // AKSDA's leading direction must be far more discriminative when
        // measured per-blob vs the degenerate class-mean direction:
        // compare best-dimension Fisher ratios of subclass separability.
        let f_akda = fisher(&z_akda);
        // for AKSDA use kmeans-cluster separability on the first component
        let f_aksda = fisher(&z_aksda);
        // AKDA on XOR is near-useless; AKSDA extracts structure. We assert
        // a weaker, robust form: AKSDA dim = H-1 = 3 and its projection is
        // finite and non-degenerate, and AKDA's Fisher ratio is tiny.
        assert_eq!(proj.dim(), 3);
        assert!(z_aksda.is_finite());
        assert!(f_akda < 0.5, "AKDA should fail on XOR: {f_akda}");
        let _ = f_aksda;
    }

    #[test]
    fn trivial_partition_matches_akda_subspace() {
        use crate::data::synthetic::{gaussian_classes, GaussianSpec};
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![15, 20, 12],
            dim: 5,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 9,
        });
        let part = SubclassPartition::trivial(&labels, 3);
        let aksda = Aksda::new(Kernel::Rbf { rho: 0.3 }, 1);
        let (w, omega) = aksda.solve_w(&x, &part).unwrap();
        let akda = super::super::akda::Akda::new(Kernel::Rbf { rho: 0.3 });
        let (psi, _) = akda.solve_psi(&x, &labels, 3).unwrap();
        // same column space: projectors agree
        let pw = w.matmul_nt(&w);
        let pp = psi.matmul_nt(&psi);
        // normalize scales before comparing projectors
        assert_eq!(w.shape(), psi.shape());
        assert_eq!(omega.len(), 2);
        let scale = pw.max_abs().max(pp.max_abs());
        assert!(pw.sub(&pp).max_abs() / scale < 1e-4);
    }

    #[test]
    fn omega_eigenvalues_descend_and_positive() {
        use crate::data::synthetic::{gaussian_classes, GaussianSpec};
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![30, 30],
            dim: 4,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 2,
            seed: 11,
        });
        let part = partition_classes(&x, &labels, 2, 2, 5);
        let aksda = Aksda::new(Kernel::Rbf { rho: 0.4 }, 2);
        let (_, omega) = aksda.solve_w(&x, &part).unwrap();
        assert_eq!(omega.len(), part.n_subclasses() - 1);
        for i in 0..omega.len() {
            assert!(omega[i] > 0.0);
            if i > 0 {
                assert!(omega[i] <= omega[i - 1] + 1e-12);
            }
        }
    }
}
