//! GDA baseline [26] (Sec. 3.1): simultaneous reduction of the centered
//! kernel matrices S̄_b = K̄ C̄ K̄ and S̄_t = K̄ K̄ via the EVD of K̄.
//!
//! Requires data centering at train AND test time (Eqs. 21–22) — exactly
//! the overhead the paper charges against it in the testing-time columns.

use anyhow::Result;

use super::{DrMethod, KernelProjection, Projection};
use crate::da::core::class_counts;
use crate::kernels::{center_gram, gram, Kernel};
use crate::linalg::{sym_eig_desc, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Gda {
    pub kernel: Kernel,
    pub eps: f64,
}

impl Gda {
    pub fn new(kernel: Kernel) -> Self {
        Gda { kernel, eps: 1e-3 }
    }
}

impl DrMethod for Gda {
    fn name(&self) -> &'static str {
        "gda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let n = x.rows();
        let k = gram(x, self.kernel);
        let kbar = center_gram(&k);
        // EVD of K̄ (always singular after centering → regularized rank cut)
        let eig = sym_eig_desc(&kbar).map_err(|e| anyhow::anyhow!("GDA EVD: {e}"))?;
        let tol = self.eps * eig.values.first().copied().unwrap_or(1.0).max(1e-12);
        let r = eig.values.iter().take_while(|&&v| v > tol).count().max(1);
        let mut p = Mat::zeros(n, r);
        for c in 0..r {
            for row in 0..n {
                p[(row, c)] = eig.vectors[(row, c)];
            }
        }
        // block-diagonal class weight matrix C̄ (Sec. 3.1)
        let counts = class_counts(labels, n_classes);
        let cbar = Mat::from_fn(n, n, |i, j| {
            if labels[i] == labels[j] {
                1.0 / counts[labels[i]] as f64
            } else {
                0.0
            }
        });
        // range-space GEP: M = Pᵀ C̄ P, top C−1 eigenvectors
        let m = p.matmul_tn(&cbar.matmul(&p));
        let m = m.add(&m.transpose()).scale(0.5);
        let inner = sym_eig_desc(&m).map_err(|e| anyhow::anyhow!("GDA inner EVD: {e}"))?;
        let d = (n_classes - 1).min(r);
        let mut w = Mat::zeros(r, d);
        for c in 0..d {
            for row in 0..r {
                w[(row, c)] = inner.vectors[(row, c)];
            }
        }
        // Ψ = P Λ⁻¹ W
        let mut plinv = Mat::zeros(n, r);
        for c in 0..r {
            let inv = 1.0 / eig.values[c];
            for row in 0..n {
                plinv[(row, c)] = p[(row, c)] * inv;
            }
        }
        let psi = plinv.matmul(&w);
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: Some(k),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{concentric_shells, gaussian_classes, GaussianSpec};

    #[test]
    fn gda_separates_gaussian_classes() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 2,
            n_per_class: vec![30, 30],
            dim: 5,
            class_sep: 2.5,
            noise: 0.5,
            modes_per_class: 1,
            seed: 2,
        });
        let proj = Gda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        let m0 = (0..30).map(|i| z[(i, 0)]).sum::<f64>() / 30.0;
        let m1 = (30..60).map(|i| z[(i, 0)]).sum::<f64>() / 30.0;
        assert!((m0 - m1).abs() > 1e-4);
    }

    #[test]
    fn gda_solves_nonlinear_shells() {
        let (x, labels) = concentric_shells(40, 4, 3);
        let proj = Gda::new(Kernel::Rbf { rho: 0.5 }).fit(&x, &labels, 2).unwrap();
        let z = proj.project(&x);
        // 1-D projection should separate the shells reasonably: count
        // threshold errors at the midpoint of class means
        let m0 = (0..40).map(|i| z[(i, 0)]).sum::<f64>() / 40.0;
        let m1 = (40..80).map(|i| z[(i, 0)]).sum::<f64>() / 40.0;
        let thr = 0.5 * (m0 + m1);
        let sign = (m0 - m1).signum();
        let errors = (0..80)
            .filter(|&i| {
                let pred0 = sign * (z[(i, 0)] - thr) > 0.0;
                (labels[i] == 0) != pred0
            })
            .count();
        assert!(errors < 8, "shell separation errors: {errors}/80");
    }

    #[test]
    fn gda_multiclass_dim() {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 4,
            n_per_class: vec![15; 4],
            dim: 6,
            class_sep: 2.0,
            noise: 0.6,
            modes_per_class: 1,
            seed: 8,
        });
        let proj = Gda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 4).unwrap();
        assert_eq!(proj.dim(), 3);
    }
}
