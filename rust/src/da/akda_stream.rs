//! Streaming (out-of-core) approximate AKDA: the tiled Φ pipeline.
//!
//! The in-memory approximate path (`da::akda_approx`) materializes the
//! full N×m feature matrix Φ before solving (ΦᵀΦ + εI) W = ΦᵀΘ — peak
//! memory O(N·m), which caps N at what fits in RAM. But the solve only
//! ever consumes two small aggregates of Φ:
//!
//! * the m×m Gram accumulator  G = ΦᵀΦ = Σ_blocks Φ_bᵀ Φ_b, and
//! * the m×C class sums        S = ΦᵀR (column j = Σ over class-j rows
//!   of φ(x)),
//!
//! both of which accumulate tile by tile. Since every Θ of the AKDA
//! family is class-piecewise-constant — row n of Θ is
//! Ξ row `label(n)` scaled by 1/sqrt(N of that class) (Eq. 40), or the
//! analytic binary pair of Eq. 50 — the right-hand side is a C-term
//! recombination ΦᵀΘ = S N^{−1/2} Ξ of the class sums. One pass over the
//! stream therefore yields the label-independent state for *all* C
//! one-vs-rest solves at peak memory O(B·m + m² + m·C) for tile height B,
//! independent of N.
//!
//! Numerics: `linalg::accumulate_tn` performs the identical
//! floating-point operations in the identical order as the in-memory
//! `matmul_tn`, so G — and hence its Cholesky factor — is bit-for-bit
//! independent of the tile size; only the ΦᵀΘ recombination differs from
//! the dense path, by one reassociation (≲1e-12 relative). The
//! `streaming_*` tests pin both properties.
//!
//! Map fitting without X in RAM: RFF is data-independent (needs only F);
//! Nyström fits its landmarks on a bounded [`reservoir_sample`] of the
//! stream.

use std::sync::Arc;

use anyhow::Result;

use super::akda_approx::{AkdaApprox, ApproxProjection};
use super::{core, Projection};
use crate::approx::{ApproxKind, FeatureMap, NystromMap, RffMap};
use crate::data::stream::{reservoir_sample, BlockSource};
use crate::linalg::{accumulate_tn, chol, Mat};

/// Default reservoir budget for streaming Nyström landmark fitting (rows
/// kept resident while sampling; the actual cap is the max of this and
/// 4·m so the k-means always sees a healthy multiple of the landmarks).
pub const DEFAULT_SAMPLE_CAP: usize = 2048;

/// Upper bound on accepted class labels while streaming. The accumulator
/// grows its m-vector class sums to max-label+1, so without a cap one
/// malformed label in an untrusted CSV (e.g. `999999999,...`) would
/// trigger a multi-gigabyte allocation before the end-of-stream
/// every-class-nonempty check could reject it.
pub const MAX_STREAM_CLASSES: usize = 65_536;

/// Why two pieces of sharded training state refused to merge. Every
/// compatibility violation is reported through this enum — the merge
/// paths never panic on foreign state, because shard artifacts cross
/// process (and machine) boundaries and a bad pairing must surface as an
/// actionable error, not a crash.
#[derive(Debug, Clone, PartialEq)]
pub enum MergeError {
    /// Feature dimensionalities m differ (different landmark budgets).
    DimMismatch { left: usize, right: usize },
    /// Declared class counts C differ (different datasets/label spaces).
    ClassMismatch { left: usize, right: usize },
    /// Ridge ε differs bit-for-bit — the merged Gram would be factorized
    /// under a ridge that matches neither shard.
    EpsMismatch { left: f64, right: f64 },
    /// Landmark-basis fingerprints differ: the shards accumulated Φ in
    /// different feature bases, so their Grams are not summable.
    BasisMismatch { left: u64, right: u64 },
    /// Two shards claim the same stride index of one train.
    DuplicateShard { index: usize },
    /// Shards declare different total shard counts k.
    ShardCountMismatch { left: usize, right: usize },
    /// A shard's stride index is outside `0..count`.
    IndexOutOfRange { index: usize, count: usize },
    /// Finalize was asked to produce a model from an incomplete shard set.
    Incomplete { have: usize, want: usize },
    /// No shards at all.
    Empty,
}

impl std::fmt::Display for MergeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MergeError::DimMismatch { left, right } => {
                write!(f, "shard merge: feature dims differ (m {left} vs {right})")
            }
            MergeError::ClassMismatch { left, right } => {
                write!(f, "shard merge: class counts differ (C {left} vs {right})")
            }
            MergeError::EpsMismatch { left, right } => {
                write!(f, "shard merge: ridge eps differs ({left} vs {right})")
            }
            MergeError::BasisMismatch { left, right } => write!(
                f,
                "shard merge: landmark bases differ (fingerprint {left:016x} vs {right:016x}) — \
                 shards must share one feature map"
            ),
            MergeError::DuplicateShard { index } => {
                write!(f, "shard merge: shard {index} supplied twice")
            }
            MergeError::ShardCountMismatch { left, right } => {
                write!(f, "shard merge: shard counts differ (k {left} vs {right})")
            }
            MergeError::IndexOutOfRange { index, count } => {
                write!(f, "shard merge: shard index {index} out of range for {count} shards")
            }
            MergeError::Incomplete { have, want } => {
                write!(f, "shard merge: only {have} of {want} shards present")
            }
            MergeError::Empty => write!(f, "shard merge: no shards supplied"),
        }
    }
}

impl std::error::Error for MergeError {}

/// Accumulation-pass bookkeeping: what flowed through and what stayed
/// resident — the numbers the eval tables report as peak resident tiles.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// Total rows streamed (N).
    pub rows: usize,
    /// Tiles processed.
    pub blocks: usize,
    /// Largest tile height B seen.
    pub peak_block_rows: usize,
    /// Feature dimensionality m of the map.
    pub m: usize,
    /// Distinct classes observed.
    pub n_classes: usize,
    /// Input feature dimensionality F of the stream.
    pub n_features: usize,
    /// Residency of the map-fitting phase (Nyström reservoir sample of
    /// the raw stream; 0 for data-independent maps like RFF or when the
    /// map was fitted elsewhere and only shared in).
    pub map_fit_resident_f64: usize,
}

impl StreamStats {
    /// Peak resident f64 count across the streaming fit: the larger of
    /// the map-fitting phase (reservoir sample) and the accumulation
    /// phase — one raw B×F input tile + its B×m feature tile + the m×m
    /// Gram + the m×C class sums.
    pub fn peak_resident_f64(&self) -> usize {
        let accumulation = self.peak_block_rows * (self.n_features + self.m)
            + self.m * self.m
            + self.m * self.n_classes;
        accumulation.max(self.map_fit_resident_f64)
    }

    /// What the in-memory path keeps resident instead: the full N×F input
    /// plus the full N×m Φ plus the m×m Gram.
    pub fn dense_resident_f64(&self) -> usize {
        self.rows * (self.n_features + self.m) + self.m * self.m
    }
}

/// Tile-by-tile accumulator for G = ΦᵀΦ and the per-class feature sums.
/// Feed it φ-transformed tiles in row order; results are independent of
/// where the tile boundaries fall.
pub struct TiledAccumulator {
    /// m×m Gram accumulator G = ΦᵀΦ.
    g: Mat,
    /// Per-class m-vector sums (grows as new labels appear).
    class_sums: Vec<Vec<f64>>,
    counts: Vec<usize>,
    stats: StreamStats,
    /// Cached global-registry handles (`akda_train_tiles_total`,
    /// `akda_train_rows_total`) so `absorb` never touches the registry
    /// lock on the per-tile path.
    tiles_total: std::sync::Arc<crate::obs::Counter>,
    rows_total: std::sync::Arc<crate::obs::Counter>,
}

impl TiledAccumulator {
    pub fn new(m: usize) -> Self {
        TiledAccumulator {
            g: Mat::zeros(m, m),
            class_sums: Vec::new(),
            counts: Vec::new(),
            stats: StreamStats { m, ..StreamStats::default() },
            tiles_total: crate::obs::counter("akda_train_tiles_total"),
            rows_total: crate::obs::counter("akda_train_rows_total"),
        }
    }

    /// Absorb one φ-tile (rows of Φ) with its labels. Labels are bounded
    /// by [`MAX_STREAM_CLASSES`] so a corrupt row cannot force an
    /// unbounded class-sum allocation.
    pub fn absorb(&mut self, phi: &Mat, labels: &[usize]) -> Result<()> {
        assert_eq!(phi.rows(), labels.len(), "tile rows/labels mismatch");
        assert_eq!(phi.cols(), self.g.rows(), "tile width must be m");
        accumulate_tn(&mut self.g, phi, phi);
        for (r, &l) in labels.iter().enumerate() {
            if l >= self.counts.len() {
                anyhow::ensure!(
                    l < MAX_STREAM_CLASSES,
                    "label {l} exceeds the streaming class cap {MAX_STREAM_CLASSES} \
                     (corrupt row?)"
                );
                self.counts.resize(l + 1, 0);
                self.class_sums.resize(l + 1, vec![0.0; phi.cols()]);
            }
            self.counts[l] += 1;
            for (s, &v) in self.class_sums[l].iter_mut().zip(phi.row(r)) {
                *s += v;
            }
        }
        self.stats.rows += phi.rows();
        self.stats.blocks += 1;
        self.stats.peak_block_rows = self.stats.peak_block_rows.max(phi.rows());
        self.tiles_total.inc();
        self.rows_total.add(phi.rows() as u64);
        Ok(())
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Merge another accumulator into this one. The streaming state is a
    /// pure sum — G, the class sums, and the counts all add elementwise —
    /// so two accumulators fed disjoint row sets combine into exactly the
    /// state one accumulator over the union would have reached (up to
    /// f64 addition order; the shard pipeline folds in a canonical order
    /// to make even the bits reproducible). Both sides must share the
    /// feature dimensionality m; the class axis grows to cover both.
    pub fn merge(&mut self, other: &TiledAccumulator) -> Result<(), MergeError> {
        if self.g.rows() != other.g.rows() {
            return Err(MergeError::DimMismatch {
                left: self.g.rows(),
                right: other.g.rows(),
            });
        }
        self.g.add_assign(&other.g);
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
            self.class_sums.resize(other.counts.len(), vec![0.0; self.g.rows()]);
        }
        for (cls, sums) in other.class_sums.iter().enumerate() {
            self.counts[cls] += other.counts[cls];
            for (s, &v) in self.class_sums[cls].iter_mut().zip(sums) {
                *s += v;
            }
        }
        self.stats.rows += other.stats.rows;
        self.stats.blocks += other.stats.blocks;
        self.stats.peak_block_rows = self.stats.peak_block_rows.max(other.stats.peak_block_rows);
        self.stats.n_features = self.stats.n_features.max(other.stats.n_features);
        self.stats.map_fit_resident_f64 =
            self.stats.map_fit_resident_f64.max(other.stats.map_fit_resident_f64);
        crate::obs::counter("akda_shard_merges_total").inc();
        Ok(())
    }

    /// Tear the accumulator down into its raw aggregates — the per-shard
    /// persistence path. Unlike [`PreparedStream::accumulate`] this does
    /// NOT require every class to be populated (a stride shard may
    /// legitimately miss a rare class; only the *merged* state must cover
    /// them all) and performs no factorization. `n_classes` pads the
    /// class axis out to the dataset's declared C so every shard of one
    /// train carries identically-shaped class sums.
    pub fn into_aggregates(self, n_classes: usize) -> Result<StreamAggregates> {
        let TiledAccumulator { g, class_sums, counts, mut stats, .. } = self;
        anyhow::ensure!(stats.rows > 0, "cannot aggregate an empty stream");
        anyhow::ensure!(
            counts.len() <= n_classes,
            "stream contains label {} but only {} classes were declared",
            counts.len() - 1,
            n_classes
        );
        let m = g.rows();
        let mut padded = counts;
        padded.resize(n_classes, 0);
        let class_sums = Mat::from_fn(m, n_classes, |i, j| {
            if j < class_sums.len() { class_sums[j][i] } else { 0.0 }
        });
        stats.n_classes = n_classes;
        Ok(StreamAggregates { gram: g, class_sums, counts: padded, stats })
    }
}

/// Raw label-independent training state torn out of a
/// [`TiledAccumulator`]: the pre-ridge m×m Gram, the m×C class sums, and
/// the per-class counts. This is the unit that shard artifacts persist
/// and [`PreparedStream::from_aggregates`] resurrects after a merge.
pub struct StreamAggregates {
    /// Pre-ridge m×m Gram accumulator G = ΦᵀΦ.
    pub gram: Mat,
    /// m×C class sums S = ΦᵀR (zero columns for classes the shard missed).
    pub class_sums: Mat,
    /// Per-class row counts, padded to the declared C.
    pub counts: Vec<usize>,
    pub stats: StreamStats,
}

impl AkdaApprox {
    /// Fit the configured feature map without materializing the dataset:
    /// RFF directly from the stream's feature dimensionality, Nyström from
    /// a bounded reservoir sample of the stream.
    pub fn build_map_stream(&self, source: &mut dyn BlockSource) -> Result<Box<dyn FeatureMap>> {
        Ok(match self.kind {
            ApproxKind::Nystrom => {
                let cap = DEFAULT_SAMPLE_CAP.max(4 * self.m);
                let sample = reservoir_sample(source, cap, self.seed)?;
                Box::new(NystromMap::fit(&sample, self.kernel, self.m, self.seed)?)
            }
            ApproxKind::Rff => {
                Box::new(RffMap::fit(source.n_features(), self.kernel, self.m, self.seed)?)
            }
        })
    }

    /// Streaming counterpart of [`AkdaApprox::prepare`]: build the feature
    /// map out of core, then accumulate G and the class sums tile by tile.
    /// Peak memory is O(B·m + m² + m·C) — independent of the stream
    /// length N.
    ///
    /// # Examples
    ///
    /// ```
    /// use akda::da::akda_approx::AkdaApprox;
    /// use akda::data::stream::MemBlockSource;
    /// use akda::kernels::Kernel;
    /// use akda::linalg::Mat;
    /// use akda::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(1);
    /// let x = Mat::from_fn(24, 4, |_, _| rng.normal());
    /// let labels: Vec<usize> = (0..24).map(|i| i % 2).collect();
    /// // stream the 24 rows through the tiled pipeline, 5 rows at a time
    /// let mut source = MemBlockSource::new(&x, &labels, 5);
    /// let prep = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 32)
    ///     .prepare_stream(&mut source)
    ///     .unwrap();
    /// let proj = prep.fit_class(0).unwrap(); // class 0 vs rest
    /// assert_eq!(proj.w.cols(), 1);
    /// assert_eq!(prep.stats.peak_block_rows, 5);
    /// assert_eq!(prep.stats.rows, 24);
    /// ```
    pub fn prepare_stream(&self, source: &mut dyn BlockSource) -> Result<PreparedStream> {
        // the tiled ΦᵀΦ accumulation and the m×m factorization run on
        // the globally selected linalg backend; record it for the
        // MANIFEST health map
        crate::obs::flight::record(
            "backend",
            crate::linalg::backend::global_kind().id() as f64,
        );
        let map: Arc<dyn FeatureMap> = Arc::from(self.build_map_stream(source)?);
        let mut prep = PreparedStream::accumulate(self, map, source)?;
        if self.kind == ApproxKind::Nystrom {
            // charge the landmark-fitting reservoir (a second transient
            // peak) so the reported residency is honest end to end
            let cap = DEFAULT_SAMPLE_CAP.max(4 * self.m);
            prep.stats.map_fit_resident_f64 =
                cap.min(prep.stats.rows) * prep.stats.n_features;
            crate::obs::gauge("akda_train_peak_f64")
                .set_max(prep.stats.peak_resident_f64() as f64);
        }
        Ok(prep)
    }
}

/// Label-independent streaming training state: the feature map, the
/// Cholesky factor of G + εI, and the class sums S — everything needed to
/// solve any one-vs-rest (or the multiclass) problem without revisiting
/// the data. The streaming mirror of
/// `da::akda_approx::PreparedFeatures`, minus the resident N×m Φ.
pub struct PreparedStream {
    pub map: Arc<dyn FeatureMap>,
    /// m×m Gram accumulator G = ΦᵀΦ *before* the ridge — kept so the
    /// model subsystem can persist it and `akda update` can continue the
    /// accumulation over new observations (`model::update`).
    gram: Mat,
    /// Lower Cholesky factor of ΦᵀΦ + εI.
    chol_l: Mat,
    /// m×C class sums S = ΦᵀR.
    class_sums: Mat,
    /// Per-class row counts N_i.
    counts: Vec<usize>,
    pub stats: StreamStats,
}

impl PreparedStream {
    /// Accumulate G and S over `source` with an already-fitted map — the
    /// map-sharing entry point the equivalence tests and the coordinator
    /// use (fit the map once, stream with it).
    pub fn accumulate(
        cfg: &AkdaApprox,
        map: Arc<dyn FeatureMap>,
        source: &mut dyn BlockSource,
    ) -> Result<PreparedStream> {
        let mut acc = TiledAccumulator::new(map.dim());
        acc.stats.n_features = source.n_features();
        source.reset()?;
        while let Some(block) = source.next_block()? {
            let phi = map.transform(&block.x);
            acc.absorb(&phi, &block.labels)?;
        }
        let TiledAccumulator { mut g, class_sums, counts, mut stats, .. } = acc;
        anyhow::ensure!(stats.rows > 0, "cannot train on an empty stream");
        anyhow::ensure!(
            counts.len() >= 2 && counts.iter().all(|&c| c > 0),
            "stream must contain at least two classes, every label in 0..C"
        );
        let gram = g.clone();
        g.add_ridge(cfg.eps);
        let chol_l = chol::cholesky(&g, cfg.block)
            .map_err(|e| anyhow::anyhow!("streaming AKDA Cholesky failed: {e}"))?;
        let (m, c) = (stats.m, counts.len());
        stats.n_classes = c;
        let class_sums = Mat::from_fn(m, c, |i, j| class_sums[j][i]);
        crate::obs::gauge("akda_train_peak_f64").set_max(stats.peak_resident_f64() as f64);
        Ok(PreparedStream { map, gram, chol_l, class_sums, counts, stats })
    }

    /// Resurrect a prepared stream from already-merged aggregates: ridge
    /// + factorize the summed Gram and wire the class sums back up. This
    /// is `akda merge`'s path from k shard artifacts to a servable model
    /// — the exact same ridge/Cholesky code the unsharded
    /// [`PreparedStream::accumulate`] runs, so a single-shard (k = 1)
    /// round trip reproduces the unsharded fit bit for bit.
    pub fn from_aggregates(
        map: Arc<dyn FeatureMap>,
        agg: StreamAggregates,
        eps: f64,
        block: usize,
    ) -> Result<PreparedStream> {
        let StreamAggregates { gram, class_sums, counts, mut stats } = agg;
        let m = map.dim();
        anyhow::ensure!(
            gram.shape() == (m, m),
            "aggregate gram is {}x{} but the map has dimension {m}",
            gram.rows(),
            gram.cols()
        );
        anyhow::ensure!(
            class_sums.shape() == (m, counts.len()),
            "aggregate class sums are {}x{} for m = {m}, C = {}",
            class_sums.rows(),
            class_sums.cols(),
            counts.len()
        );
        anyhow::ensure!(stats.rows > 0, "cannot fit from empty aggregates");
        anyhow::ensure!(
            counts.len() >= 2 && counts.iter().all(|&c| c > 0),
            "merged aggregates must cover at least two classes, every label in 0..C \
             (counts {counts:?})"
        );
        let mut g = gram.clone();
        g.add_ridge(eps);
        let chol_l = chol::cholesky(&g, block)
            .map_err(|e| anyhow::anyhow!("merged-aggregate Cholesky failed: {e}"))?;
        stats.m = m;
        stats.n_classes = counts.len();
        Ok(PreparedStream { map, gram, chol_l, class_sums, counts, stats })
    }

    /// The pre-ridge m×m Gram accumulator G = ΦᵀΦ (resume state).
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// The m×C class sums S = ΦᵀR (resume state).
    pub fn class_sums(&self) -> &Mat {
        &self.class_sums
    }

    /// Per-class row counts (resume state).
    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    pub fn n_classes(&self) -> usize {
        self.counts.len()
    }

    /// Two m×m triangular solves against the cached factor.
    fn solve(&self, b: &Mat) -> Mat {
        let y = chol::solve_lower(&self.chol_l, b);
        chol::solve_upper_from_lower(&self.chol_l, &y)
    }

    /// Block-wise `solve_w` for the one-vs-rest problem `cls` vs rest:
    /// recombine the class sums into ΦᵀΘ with the analytic binary θ
    /// coefficients (Eq. 50, target class plays class 0 / the '+' branch),
    /// then solve (ΦᵀΦ + εI) W = ΦᵀΘ. No data access — O(m·C + m²).
    pub fn solve_w_class(&self, cls: usize) -> Result<Mat> {
        anyhow::ensure!(cls < self.counts.len(), "class {cls} out of range");
        Ok(self.solve(&ovr_rhs(&self.class_sums, &self.counts, cls)))
    }

    /// Block-wise `solve_w` for the full multiclass problem: ΦᵀΘ =
    /// S N_C^{−1/2} Ξ with Ξ the NZEP of the C×C core matrix (Eq. 40),
    /// then one solve for all C−1 discriminant directions.
    pub fn solve_w_multiclass(&self) -> Result<Mat> {
        Ok(self.solve(&multiclass_rhs(&self.class_sums, &self.counts)))
    }

    /// Fitted one-vs-rest projection (`cls` scores positive).
    pub fn fit_class(&self, cls: usize) -> Result<ApproxProjection> {
        Ok(ApproxProjection { map: self.map.clone(), w: self.solve_w_class(cls)? })
    }

    /// Fitted multiclass projection (C−1 discriminant directions).
    pub fn fit_multiclass(&self) -> Result<ApproxProjection> {
        Ok(ApproxProjection { map: self.map.clone(), w: self.solve_w_multiclass()? })
    }
}

/// ΦᵀΘ for the one-vs-rest problem `cls` vs rest, recombined from the
/// m×C class sums: θ entries are sqrt(N₂/(N₁N)) on the target rows and
/// −sqrt(N₁/(N₂N)) on the rest — identical to `core::theta_binary` with
/// the target class relabelled 0. O(m·C), no data access. Shared by
/// [`PreparedStream::solve_w_class`] and the model-update path
/// (`model::update`), which continues a persisted accumulator.
pub fn ovr_rhs(class_sums: &Mat, counts: &[usize], cls: usize) -> Mat {
    assert!(cls < counts.len(), "class {cls} out of range");
    let n_c = counts[cls] as f64;
    let n: f64 = counts.iter().map(|&c| c as f64).sum();
    let n_rest = n - n_c;
    let pos = (n_rest / (n_c * n)).sqrt();
    let neg = -(n_c / (n_rest * n)).sqrt();
    let m = class_sums.rows();
    Mat::from_fn(m, 1, |i, _| {
        let mut rest = 0.0;
        for j in 0..counts.len() {
            if j != cls {
                rest += class_sums[(i, j)];
            }
        }
        pos * class_sums[(i, cls)] + neg * rest
    })
}

/// ΦᵀΘ for the full multiclass problem: S N_C^{−1/2} Ξ with Ξ the NZEP of
/// the C×C core matrix (Eq. 40); the C = 2 case short-circuits to the
/// analytic binary recombination (same sign branch as the dense
/// `PreparedFeatures::fit`, Sec. 4.4).
pub fn multiclass_rhs(class_sums: &Mat, counts: &[usize]) -> Mat {
    let c = counts.len();
    if c == 2 {
        return ovr_rhs(class_sums, counts, 0);
    }
    let xi = core::core_eigenvectors(counts);
    let scaled = Mat::from_fn(c, c - 1, |i, k| xi[(i, k)] / (counts[i] as f64).sqrt());
    class_sums.matmul(&scaled)
}

/// Project rows through z = φ(x) W one tile at a time: peak extra memory
/// is one B×m feature tile instead of the full N×m Φ. Bit-for-bit equal
/// to `map.transform(x).matmul(w)` — both are row-independent.
pub fn project_blocked(map: &dyn FeatureMap, w: &Mat, x: &Mat, block_rows: usize) -> Mat {
    let block_rows = block_rows.max(1);
    let mut z = Mat::zeros(x.rows(), w.cols());
    let mut r0 = 0;
    while r0 < x.rows() {
        let nr = block_rows.min(x.rows() - r0);
        let tile = map.transform(&x.submatrix(r0, 0, nr, x.cols())).matmul(w);
        z.set_submatrix(r0, 0, &tile);
        r0 += nr;
    }
    z
}

/// Fitted streaming projection: same numbers as
/// `da::akda_approx::ApproxProjection`, but projects tile by tile so
/// serving/eval never materializes an N×m feature matrix either.
pub struct BlockedProjection {
    pub map: Arc<dyn FeatureMap>,
    pub w: Mat,
    pub block_rows: usize,
}

impl Projection for BlockedProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        project_blocked(self.map.as_ref(), &self.w, x_test, self.block_rows)
    }

    fn dim(&self) -> usize {
        self.w.cols()
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::{CsvBlockSource, MemBlockSource};
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};
    use crate::kernels::Kernel;

    fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
        gaussian_classes(&GaussianSpec {
            n_classes: c,
            n_per_class: vec![n_per; c],
            dim: 6,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed,
        })
    }

    /// Streaming with a shared map must reproduce the dense solve to
    /// 1e-10, and be bit-for-bit identical across block sizes {1, 7, N}.
    #[test]
    fn streaming_matches_dense_solve_across_block_sizes() {
        let (x, labels) = toy(20, 2, 1);
        let n = x.rows();
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.4 }, 12);
        let prep_dense = cfg.prepare(&x).unwrap();
        let y_bin: Vec<usize> = labels.iter().map(|&l| usize::from(l != 0)).collect();
        let w_dense = prep_dense.fit(&y_bin, 2).unwrap().w;

        let mut ws = Vec::new();
        for block in [1usize, 7, n] {
            let mut src = MemBlockSource::new(&x, &labels, block);
            let ps = PreparedStream::accumulate(&cfg, prep_dense.map.clone(), &mut src).unwrap();
            assert_eq!(ps.stats.rows, n);
            assert!(ps.stats.peak_block_rows <= block);
            let w = ps.solve_w_class(0).unwrap();
            let gap = w.sub(&w_dense).max_abs();
            assert!(gap < 1e-10, "block={block}: dense gap {gap}");
            ws.push(w);
        }
        for w in &ws[1..] {
            assert!(
                w.sub(&ws[0]).max_abs() == 0.0,
                "tiled solve must be bit-for-bit block-size invariant"
            );
        }
    }

    #[test]
    fn streaming_multiclass_matches_dense_solve() {
        let (x, labels) = toy(15, 3, 2);
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.3 }, 14);
        let prep_dense = cfg.prepare(&x).unwrap();
        let w_dense = prep_dense.fit(&labels, 3).unwrap().w;
        let mut src = MemBlockSource::new(&x, &labels, 7);
        let ps = PreparedStream::accumulate(&cfg, prep_dense.map.clone(), &mut src).unwrap();
        assert_eq!(ps.n_classes(), 3);
        let w = ps.solve_w_multiclass().unwrap();
        assert_eq!(w.cols(), 2);
        let gap = w.sub(&w_dense).max_abs();
        assert!(gap < 1e-10, "multiclass dense gap {gap}");
    }

    /// RFF is data-independent, so the fully-streaming path (map fitted
    /// from the stream) must match the dense in-memory fit end to end.
    #[test]
    fn rff_streaming_end_to_end_matches_dense_fit() {
        use crate::da::DrMethod;
        let (x, labels) = toy(25, 2, 3);
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 64);
        let y_bin: Vec<usize> = labels.to_vec();
        let dense = cfg.fit(&x, &y_bin, 2).unwrap();
        let mut src = MemBlockSource::new(&x, &labels, 9);
        let ps = cfg.prepare_stream(&mut src).unwrap();
        let proj = ps.fit_class(0).unwrap();
        let (xt, _) = toy(10, 2, 8);
        let gap = dense.project(&xt).sub(&proj.project(&xt)).max_abs();
        assert!(gap < 1e-10, "end-to-end RFF gap {gap}");
    }

    /// Nyström with reservoir-fitted landmarks (a genuine subsample) still
    /// produces a usable discriminant.
    #[test]
    fn nystrom_reservoir_streaming_separates_classes() {
        let (x, labels) = toy(40, 2, 4);
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, 12);
        let mut src = MemBlockSource::new(&x, &labels, 16);
        let ps = cfg.prepare_stream(&mut src).unwrap();
        let proj = ps.fit_class(0).unwrap();
        let z = proj.project(&x);
        let z0: Vec<f64> =
            (0..z.rows()).filter(|&i| labels[i] == 0).map(|i| z[(i, 0)]).collect();
        let z1: Vec<f64> =
            (0..z.rows()).filter(|&i| labels[i] == 1).map(|i| z[(i, 0)]).collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (m0, m1) = (mean(&z0), mean(&z1));
        let sd = |v: &[f64], m: f64| {
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        let gap = (m0 - m1).abs() / (sd(&z0, m0) + sd(&z1, m1)).max(1e-12);
        assert!(gap > 2.0, "class separation too weak: {gap}");
    }

    #[test]
    fn project_blocked_is_bitwise_equal_to_dense_projection() {
        let (x, labels) = toy(18, 2, 5);
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.6 }, 10);
        let prep = cfg.prepare(&x).unwrap();
        let y_bin: Vec<usize> = labels.to_vec();
        let proj = prep.fit(&y_bin, 2).unwrap();
        let dense_z = proj.map.transform(&x).matmul(&proj.w);
        for block in [1usize, 5, 36] {
            let z = project_blocked(proj.map.as_ref(), &proj.w, &x, block);
            assert!(z.sub(&dense_z).max_abs() == 0.0, "block={block}");
        }
        let blocked = BlockedProjection { map: proj.map.clone(), w: proj.w.clone(), block_rows: 4 };
        assert_eq!(blocked.dim(), proj.w.cols());
        assert!(blocked.project(&x).sub(&dense_z).max_abs() == 0.0);
    }

    /// Training from a CSV stream must equal training from memory — the
    /// CSV writer emits shortest-round-trip floats, so even bit-for-bit.
    #[test]
    fn csv_stream_training_matches_mem_stream_training() {
        let (x, labels) = toy(16, 2, 6);
        let dir = std::env::temp_dir().join("akda_stream_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("train.csv");
        crate::data::csv::save_labeled(&path, &x, &labels).unwrap();

        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.4 }, 32);
        let mut mem = MemBlockSource::new(&x, &labels, 5);
        let w_mem = cfg.prepare_stream(&mut mem).unwrap().solve_w_class(0).unwrap();
        let mut csv = CsvBlockSource::open(&path, 5).unwrap();
        let w_csv = cfg.prepare_stream(&mut csv).unwrap().solve_w_class(0).unwrap();
        assert!(w_csv.sub(&w_mem).max_abs() == 0.0, "CSV stream must match memory");
    }

    #[test]
    fn stats_report_tile_and_dense_residency() {
        let (x, labels) = toy(30, 2, 7);
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 16);
        let mut src = MemBlockSource::new(&x, &labels, 10);
        let ps = cfg.prepare_stream(&mut src).unwrap();
        let (m, f) = (ps.map.dim(), x.cols());
        assert_eq!(ps.stats.m, m);
        assert_eq!(ps.stats.n_features, f);
        assert_eq!(ps.stats.rows, 60);
        assert_eq!(ps.stats.blocks, 6);
        // RFF needs no data to fit, so the accumulation tile is the peak
        assert_eq!(ps.stats.map_fit_resident_f64, 0);
        assert_eq!(
            ps.stats.peak_resident_f64(),
            10 * (f + m) + m * m + 2 * m
        );
        assert_eq!(ps.stats.dense_resident_f64(), 60 * (f + m) + m * m);
        assert!(ps.stats.peak_resident_f64() < ps.stats.dense_resident_f64());
    }

    #[test]
    fn nystrom_stats_charge_the_reservoir_phase() {
        let (x, labels) = toy(30, 2, 8);
        let cfg = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, 8);
        let mut src = MemBlockSource::new(&x, &labels, 10);
        let ps = cfg.prepare_stream(&mut src).unwrap();
        // cap (2048) exceeds N, so the whole 60-row stream was sampled
        assert_eq!(ps.stats.map_fit_resident_f64, 60 * x.cols());
        assert!(ps.stats.peak_resident_f64() >= ps.stats.map_fit_resident_f64);
    }

    /// Stride-sharded accumulators merged back together must equal one
    /// accumulator over the whole stream — and the merge must commute
    /// bitwise (f64 `+` is commutative even though it is not associative).
    #[test]
    fn sharded_accumulators_merge_to_the_single_pass_state() {
        use crate::data::stream::StridedBlockSource;
        let (x, labels) = toy(21, 3, 10);
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 16);
        let mut full_src = MemBlockSource::new(&x, &labels, 4);
        let map: Arc<dyn FeatureMap> = Arc::from(cfg.build_map_stream(&mut full_src).unwrap());

        let absorb_all = |src: &mut dyn BlockSource| -> TiledAccumulator {
            let mut acc = TiledAccumulator::new(map.dim());
            src.reset().unwrap();
            while let Some(block) = src.next_block().unwrap() {
                let phi = map.transform(&block.x);
                acc.absorb(&phi, &block.labels).unwrap();
            }
            acc
        };
        let whole = absorb_all(&mut full_src);

        let k = 3;
        let shards: Vec<TiledAccumulator> = (0..k)
            .map(|i| {
                let inner = MemBlockSource::new(&x, &labels, 4);
                let mut src = StridedBlockSource::new(inner, i, k).unwrap();
                absorb_all(&mut src)
            })
            .collect();
        let agg = |order: &[usize]| {
            let mut it = order.iter();
            let mut acc = absorb_all(&mut {
                let inner = MemBlockSource::new(&x, &labels, 4);
                StridedBlockSource::new(inner, *it.next().unwrap(), k).unwrap()
            });
            for &i in it {
                acc.merge(&shards[i]).unwrap();
            }
            acc.into_aggregates(3).unwrap()
        };
        let fwd = agg(&[0, 1, 2]);
        let rev = agg(&[2, 1, 0]);
        let single = whole.into_aggregates(3).unwrap();
        // merged ≈ single-pass (f64 addition order differs ⇒ ≤1e-10, not bits)
        assert!(fwd.gram.sub(&single.gram).max_abs() < 1e-10);
        assert!(fwd.class_sums.sub(&single.class_sums).max_abs() < 1e-10);
        assert_eq!(fwd.counts, single.counts);
        assert_eq!(fwd.stats.rows, single.stats.rows);
        // pairwise merge commutes bitwise: shard0+shard1 == shard1+shard0
        let mut ab = absorb_all(&mut StridedBlockSource::new(
            MemBlockSource::new(&x, &labels, 4), 0, k).unwrap());
        ab.merge(&shards[1]).unwrap();
        let mut ba = absorb_all(&mut StridedBlockSource::new(
            MemBlockSource::new(&x, &labels, 4), 1, k).unwrap());
        ba.merge(&shards[0]).unwrap();
        let (a, b) = (ab.into_aggregates(3).unwrap(), ba.into_aggregates(3).unwrap());
        assert!(a.gram.sub(&b.gram).max_abs() == 0.0, "f64 + must commute bitwise");
        assert!(a.class_sums.sub(&b.class_sums).max_abs() == 0.0);
        assert_eq!(a.counts, b.counts);
        // reversed merge order still lands within f.p. reassociation noise
        assert!(rev.gram.sub(&single.gram).max_abs() < 1e-10);
        assert_eq!(rev.counts, single.counts);
    }

    #[test]
    fn merge_rejects_dim_mismatch_with_a_typed_error() {
        let mut a = TiledAccumulator::new(3);
        let b = TiledAccumulator::new(4);
        match a.merge(&b) {
            Err(MergeError::DimMismatch { left: 3, right: 4 }) => {}
            other => panic!("expected DimMismatch, got {other:?}"),
        }
    }

    /// k = 1: tearing the accumulator down and resurrecting it through
    /// `from_aggregates` must reproduce the direct streaming fit bitwise.
    #[test]
    fn from_aggregates_round_trips_the_streaming_fit() {
        let (x, labels) = toy(18, 2, 11);
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.4 }, 24);
        let mut src = MemBlockSource::new(&x, &labels, 6);
        let direct = cfg.prepare_stream(&mut src).unwrap();
        let w_direct = direct.solve_w_multiclass().unwrap();

        let mut acc = TiledAccumulator::new(direct.map.dim());
        acc.stats.n_features = x.cols();
        src.reset().unwrap();
        while let Some(block) = src.next_block().unwrap() {
            let phi = direct.map.transform(&block.x);
            acc.absorb(&phi, &block.labels).unwrap();
        }
        let agg = acc.into_aggregates(2).unwrap();
        let rebuilt =
            PreparedStream::from_aggregates(direct.map.clone(), agg, cfg.eps, cfg.block).unwrap();
        let w = rebuilt.solve_w_multiclass().unwrap();
        assert!(w.sub(&w_direct).max_abs() == 0.0, "k=1 round trip must be bit-for-bit");
        assert!(rebuilt.gram().sub(direct.gram()).max_abs() == 0.0);
    }

    #[test]
    fn from_aggregates_rejects_uncovered_classes() {
        let (x, labels) = toy(12, 2, 12);
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.4 }, 8);
        let mut src = MemBlockSource::new(&x, &labels, 4);
        let map: Arc<dyn FeatureMap> = Arc::from(cfg.build_map_stream(&mut src).unwrap());
        let mut acc = TiledAccumulator::new(map.dim());
        src.reset().unwrap();
        while let Some(block) = src.next_block().unwrap() {
            let phi = map.transform(&block.x);
            acc.absorb(&phi, &block.labels).unwrap();
        }
        // declare 3 classes but the stream only populated 2
        let agg = acc.into_aggregates(3).unwrap();
        assert!(PreparedStream::from_aggregates(map, agg, cfg.eps, cfg.block).is_err());
    }

    #[test]
    fn absorb_rejects_runaway_labels() {
        let mut acc = TiledAccumulator::new(3);
        let phi = Mat::from_fn(2, 3, |r, c| (r + c) as f64);
        assert!(acc.absorb(&phi, &[0, 1]).is_ok());
        assert!(acc.absorb(&phi, &[0, MAX_STREAM_CLASSES]).is_err());
    }

    #[test]
    fn rejects_single_class_and_empty_streams() {
        let (x, _) = toy(10, 2, 9);
        let ones = vec![1usize; x.rows()]; // label 0 never appears
        let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.5 }, 16);
        let mut src = MemBlockSource::new(&x, &ones, 4);
        assert!(cfg.prepare_stream(&mut src).is_err());
    }
}
