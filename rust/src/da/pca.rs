//! PCA baseline — unsupervised linear DR comparator (Sec. 6.3).

use anyhow::Result;

use super::{DrMethod, LinearProjection, Projection};
use crate::linalg::{sym_eig_desc, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Pca {
    /// Keep the smallest number of components whose variance fraction
    /// reaches this threshold …
    pub energy: f64,
    /// … capped at this many components.
    pub max_components: usize,
}

impl Pca {
    pub fn new() -> Self {
        Pca { energy: 0.95, max_components: 64 }
    }
}

impl Default for Pca {
    fn default() -> Self {
        Self::new()
    }
}

impl DrMethod for Pca {
    fn name(&self) -> &'static str {
        "pca"
    }

    fn fit(&self, x: &Mat, _labels: &[usize], _n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let (n, l) = x.shape();
        let mut mean = vec![0.0; l];
        for i in 0..n {
            for j in 0..l {
                mean[j] += x[(i, j)];
            }
        }
        for v in mean.iter_mut() {
            *v /= n as f64;
        }
        let centered = Mat::from_fn(n, l, |i, j| x[(i, j)] - mean[j]);
        let cov = centered.matmul_tn(&centered).scale(1.0 / (n.max(2) - 1) as f64);
        let eig = sym_eig_desc(&cov).map_err(|e| anyhow::anyhow!("PCA EVD: {e}"))?;
        let total: f64 = eig.values.iter().filter(|v| **v > 0.0).sum();
        let mut d = 0;
        let mut acc = 0.0;
        while d < l.min(self.max_components) && acc < self.energy * total {
            acc += eig.values[d].max(0.0);
            d += 1;
        }
        let d = d.max(1);
        let mut w = Mat::zeros(l, d);
        for c in 0..d {
            for r in 0..l {
                w[(r, c)] = eig.vectors[(r, c)];
            }
        }
        Ok(Box::new(LinearProjection { w, mean }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pca_finds_dominant_direction() {
        // data stretched along a known axis
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(200, 4, |_, j| {
            if j == 2 { 10.0 * rng.normal() } else { 0.1 * rng.normal() }
        });
        let proj = Pca { energy: 0.9, max_components: 4 }.fit(&x, &[], 0).unwrap();
        assert_eq!(proj.dim(), 1);
        let z = proj.project(&x);
        // projection variance ≈ variance along axis 2
        let var: f64 = z.data().iter().map(|v| v * v).sum::<f64>() / 200.0;
        assert!(var > 50.0, "var={var}");
    }

    #[test]
    fn pca_energy_keeps_more_components() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(100, 6, |_, j| (j + 1) as f64 * rng.normal());
        let p1 = Pca { energy: 0.5, max_components: 6 }.fit(&x, &[], 0).unwrap();
        let p2 = Pca { energy: 0.999, max_components: 6 }.fit(&x, &[], 0).unwrap();
        assert!(p2.dim() > p1.dim());
    }

    #[test]
    fn pca_projection_is_centered() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(80, 3, |_, _| 5.0 + rng.normal());
        let proj = Pca::new().fit(&x, &[], 0).unwrap();
        let z = proj.project(&x);
        for c in 0..z.cols() {
            let m: f64 = (0..80).map(|i| z[(i, c)]).sum::<f64>() / 80.0;
            assert!(m.abs() < 1e-9);
        }
    }
}
