//! Discriminant-analysis methods: the paper's AKDA/AKSDA plus every
//! baseline it is evaluated against (Sec. 6.3: PCA, LDA, KDA, GDA, SRKDA,
//! KSDA, GSDA), behind one `DrMethod` trait so the evaluation harness and
//! the coordinator treat them uniformly.
//!
//! Module map, in pipeline order:
//!
//! * `core` — the label-side factorization (core matrices, NZEPs, Θ / V
//!   targets) shared by every AKDA-family trainer;
//! * `akda` / `aksda` — the paper's exact engines (Gram + Cholesky,
//!   Algorithms 1–2); `incremental` the multiclass bordered-Cholesky
//!   online variant (Sec. 7 recursive learning — `model::update` runs it
//!   over published registry models);
//! * `akda_approx` — the same solve on an explicit m-dimensional feature
//!   map (Nyström / RFF, m ≪ N): O(N m²) training, full N×m Φ resident;
//! * `akda_stream` — the out-of-core tiling of `akda_approx`: identical
//!   math, peak memory O(B·m + m²) for tile height B, any dataset size;
//! * `kda`, `gda`, `srkda`, `ksda`, `lda`, `pca` — the baseline zoo,
//!   paying their conventional costs for the timing comparisons;
//! * `equivalence` — cross-method identity checks (AKDA vs KDA etc.).

pub mod akda;
pub mod akda_approx;
pub mod akda_stream;
pub mod aksda;
pub mod core;
pub mod equivalence;
pub mod gda;
pub mod incremental;
pub mod kda;
pub mod ksda;
pub mod lda;
pub mod pca;
pub mod srkda;

use crate::linalg::Mat;

/// A fitted dimensionality-reduction model: projects test observations
/// into the discriminant subspace (z = Γᵀφ(x), Eq. 11).
pub trait Projection: Send + Sync {
    fn project(&self, x_test: &Mat) -> Mat;
    /// Discriminant-subspace dimensionality D.
    fn dim(&self) -> usize;
    /// Introspection hook for the model-artifact subsystem: lets
    /// `model::codec` downcast a fitted `Box<dyn Projection>` back to its
    /// concrete type so every trained state can be serialized without the
    /// trait knowing about the on-disk format.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// A dimensionality-reduction method (the "m-th method" of Sec. 6.3.1).
pub trait DrMethod: Send + Sync {
    fn name(&self) -> &'static str;
    /// Fit on training rows `x` with labels in 0..n_classes.
    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> anyhow::Result<Box<dyn Projection>>;
}

/// Identity "projection" — lets raw-input-space SVM baselines flow through
/// the same DR + LSVM pipeline.
pub struct IdentityProjection {
    dim: usize,
}

impl IdentityProjection {
    pub fn new(dim: usize) -> Self {
        Self { dim }
    }
}

impl Projection for IdentityProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        x_test.clone()
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// No-op DR (raw input space), used for the LSVM / KSVM columns.
pub struct NoDr;

impl DrMethod for NoDr {
    fn name(&self) -> &'static str {
        "none"
    }
    fn fit(&self, x: &Mat, _labels: &[usize], _n_classes: usize)
        -> anyhow::Result<Box<dyn Projection>> {
        Ok(Box::new(IdentityProjection::new(x.cols())))
    }
}

/// Kernel-expansion projection shared by every kernel DR method:
/// z = Ψᵀ k(·) with optional feature-space centering (Eq. 22).
pub struct KernelProjection {
    pub x_train: Mat,
    pub psi: Mat,
    pub kernel: crate::kernels::Kernel,
    /// When set, cross-kernel blocks are centered against these training
    /// statistics (GDA/SRKDA/GSDA pay this at test time — Sec. 6.3.2 notes
    /// it makes their testing slower).
    pub center_against: Option<Mat>,
}

impl Projection for KernelProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        let kc = crate::kernels::cross_gram(x_test, &self.x_train, self.kernel);
        let kc = match &self.center_against {
            Some(k_train) => crate::kernels::center_cross(&kc, k_train),
            None => kc,
        };
        kc.matmul(&self.psi)
    }
    fn dim(&self) -> usize {
        self.psi.cols()
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Linear projection z = Wᵀ(x − μ) for the input-space methods (PCA/LDA).
pub struct LinearProjection {
    pub w: Mat,
    pub mean: Vec<f64>,
}

impl Projection for LinearProjection {
    fn project(&self, x_test: &Mat) -> Mat {
        let centered = Mat::from_fn(x_test.rows(), x_test.cols(), |i, j| {
            x_test[(i, j)] - self.mean[j]
        });
        centered.matmul(&self.w)
    }
    fn dim(&self) -> usize {
        self.w.cols()
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}
