//! KSDA [4] and GSDA [27] baselines — the conventional subclass methods.
//!
//! KSDA: the full GEP on (S_bs, S_ws) with NN-chain subclass partitioning
//! [3] — paid at the conventional 40/3·N³ price (Sec. 5.4).
//! GSDA: GDA-style centered-kernel route with k-means subclasses.

use anyhow::Result;

use super::core::{self};
use super::{DrMethod, KernelProjection, Projection};
use crate::cluster::kmeans::{nn_partition, partition_classes};
use crate::kernels::{center_gram, gram, Kernel};
use crate::linalg::{sym_eig_desc, Mat};

#[derive(Debug, Clone, Copy)]
pub struct Ksda {
    pub kernel: Kernel,
    pub eps: f64,
    pub h_per_class: usize,
}

impl Ksda {
    pub fn new(kernel: Kernel, h_per_class: usize) -> Self {
        Ksda { kernel, eps: 1e-3, h_per_class }
    }

    /// NN-chain partitioning per class (the [3] procedure).
    fn partition(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> core::SubclassPartition {
        let mut sub_labels = vec![0usize; labels.len()];
        let mut class_of = Vec::new();
        let mut next = 0;
        for cls in 0..n_classes {
            let idx: Vec<usize> =
                (0..labels.len()).filter(|&i| labels[i] == cls).collect();
            let h = self.h_per_class.min(idx.len()).max(1);
            let part = nn_partition(&x.select_rows(&idx), h);
            let used = part.iter().copied().max().unwrap_or(0) + 1;
            for (pos, &i) in idx.iter().enumerate() {
                sub_labels[i] = next + part[pos];
            }
            for _ in 0..used {
                class_of.push(cls);
            }
            next += used;
        }
        core::SubclassPartition { sub_labels, class_of }
    }
}

impl DrMethod for Ksda {
    fn name(&self) -> &'static str {
        "ksda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let part = self.partition(x, labels, n_classes);
        let k = gram(x, self.kernel);
        let cbs = core::central_factor_bs(&part);
        let cws = core::central_factor_ws(&part);
        let d = part.n_subclasses() - 1;
        let psi = super::kda::Kda::solve_gep(&k, &cbs, &cws, self.eps, d)?;
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: None,
        }))
    }
}

/// GSDA [27]: subclass discriminant analysis on the centered kernel via
/// the range-space EVD route (like GDA), k-means partitioning (Sec. 6.3.1).
#[derive(Debug, Clone, Copy)]
pub struct Gsda {
    pub kernel: Kernel,
    pub eps: f64,
    pub h_per_class: usize,
    pub seed: u64,
}

impl Gsda {
    pub fn new(kernel: Kernel, h_per_class: usize) -> Self {
        Gsda { kernel, eps: 1e-3, h_per_class, seed: 23 }
    }
}

impl DrMethod for Gsda {
    fn name(&self) -> &'static str {
        "gsda"
    }

    fn fit(&self, x: &Mat, labels: &[usize], n_classes: usize)
        -> Result<Box<dyn Projection>> {
        let part = partition_classes(x, labels, n_classes, self.h_per_class, self.seed);
        let k = gram(x, self.kernel);
        let kbar = center_gram(&k);
        // EVD of K̄ (the expensive GDA step), range-space projection
        let eig = sym_eig_desc(&kbar).map_err(|e| anyhow::anyhow!("GSDA EVD: {e}"))?;
        let tol = self.eps * eig.values.first().copied().unwrap_or(1.0).max(1e-12);
        let r = eig.values.iter().take_while(|&&v| v > tol).count().max(1);
        let n = kbar.rows();
        let mut p = Mat::zeros(n, r);
        for c in 0..r {
            for row in 0..n {
                p[(row, c)] = eig.vectors[(row, c)];
            }
        }
        // small GEP in the range space: M = Pᵀ C_bs P
        let cbs = core::central_factor_bs(&part);
        let m = p.matmul_tn(&cbs.matmul(&p));
        let m = m.add(&m.transpose()).scale(0.5);
        let inner = sym_eig_desc(&m).map_err(|e| anyhow::anyhow!("GSDA inner EVD: {e}"))?;
        let d = (part.n_subclasses() - 1).min(r);
        let mut w = Mat::zeros(r, d);
        for c in 0..d {
            for row in 0..r {
                w[(row, c)] = inner.vectors[(row, c)];
            }
        }
        // Ψ = P Λ⁻¹ W
        let mut plinv = Mat::zeros(n, r);
        for c in 0..r {
            let inv = 1.0 / eig.values[c];
            for row in 0..n {
                plinv[(row, c)] = p[(row, c)] * inv;
            }
        }
        let psi = plinv.matmul(&w);
        Ok(Box::new(KernelProjection {
            x_train: x.clone(),
            psi,
            kernel: self.kernel,
            center_against: Some(k),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::xor_blobs;

    #[test]
    fn ksda_handles_multimodal_binary() {
        let (x, labels) = xor_blobs(25, 3, 3.0, 0.3, 5);
        let proj = Ksda::new(Kernel::Rbf { rho: 0.3 }, 2).fit(&x, &labels, 2).unwrap();
        assert_eq!(proj.dim(), 3); // H-1 with 2 subclasses per class
        assert!(proj.project(&x).is_finite());
    }

    #[test]
    fn gsda_produces_finite_projection() {
        let (x, labels) = xor_blobs(20, 3, 2.5, 0.4, 6);
        let proj = Gsda::new(Kernel::Rbf { rho: 0.3 }, 2).fit(&x, &labels, 2).unwrap();
        assert!(proj.dim() >= 1);
        let z = proj.project(&x);
        assert!(z.is_finite());
    }

    #[test]
    fn ksda_h1_reduces_to_kda_dim() {
        use crate::data::synthetic::{gaussian_classes, GaussianSpec};
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![12; 3],
            dim: 4,
            class_sep: 2.0,
            noise: 0.5,
            modes_per_class: 1,
            seed: 4,
        });
        let proj = Ksda::new(Kernel::Rbf { rho: 0.3 }, 1).fit(&x, &labels, 3).unwrap();
        assert_eq!(proj.dim(), 2); // C-1
    }
}
