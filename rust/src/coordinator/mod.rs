//! The L3 coordinator: the paper's training/evaluation protocol at scale.
//!
//! * `jobs` — worker pool scheduling the per-class one-vs-rest jobs.
//! * `protocol` — Sec. 6.3's evaluation loop (binary OvR, DR + LSVM, MAP,
//!   timing) and the 3-fold CV grid search. For the approximate methods
//!   it builds the label-independent training state once per evaluation —
//!   in memory (`da::akda_approx::PreparedFeatures`) or, when
//!   `Hyper::stream_block` is set, through the out-of-core tiled pipeline
//!   (`da::akda_stream::PreparedStream`) — and shares it across the C
//!   per-class fits.
//! * `service` — post-training scoring service with dynamic micro-batching.
//! * `fleet` — multi-tenant serving (L6): every model in a registry served
//!   by one process over a single shared worker pool, one watcher
//!   hot-swapping republished tenants (and onboarding newly published
//!   names), plus the drop-directory auto-update daemon (`akda daemon`).
//! * `wire` — the `akda-wire/1` length-prefixed binary frame codec (L8):
//!   checksummed score/models/error frames, dependency-free.
//! * `net` — the TCP network edge (L8): `NetServer` multiplexes many
//!   connections onto the fleet dispatcher through a bounded shed-oldest
//!   ingress queue; `NetClient` is the matching in-crate client.
//! * `config` — reproducible run configuration (`EvalConfig`), including
//!   the streaming tile height `stream_block`.

pub mod config;
pub mod fleet;
pub mod jobs;
pub mod net;
pub mod protocol;
pub mod service;
pub mod wire;

pub use config::EvalConfig;
pub use fleet::{FleetClient, FleetError, FleetOptions, FleetService, UpdateDaemon};
pub use jobs::WorkPool;
pub use net::{NetClient, NetOptions, NetServer, TracedReply};
pub use protocol::{build_dr, evaluate_ovr, select_hyper, Hyper, MethodId};
pub use service::{BankHandle, DetectorBank, ScoringService};
pub use wire::{ErrorCode, Frame, WireModel};
