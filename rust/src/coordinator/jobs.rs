//! Work-pool job scheduler for the per-class one-vs-rest training protocol
//! and the fleet's shared scoring pool.
//!
//! No tokio offline, so this is a small explicit scheduler: a bounded
//! worker pool over std threads + channels, FIFO queue, per-job wall-clock
//! metrics. The evaluation protocol submits one job per (class, method)
//! pair; the PJRT server serializes artifact executions on its own thread,
//! so CPU-native work overlaps accelerator work naturally. The fleet
//! (`coordinator::fleet`) submits one job per tenant micro-batch, which is
//! what keeps ten tenants from needing ten scoring threads.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Aggregate scheduler metrics.
#[derive(Debug, Default, Clone)]
pub struct PoolMetrics {
    pub jobs_run: usize,
    pub busy_s: f64,
}

/// A fixed-size pool of named worker threads draining one FIFO queue.
///
/// Jobs are closures; [`WorkPool::submit`] hands back a receiver for the
/// job's result (drop it for fire-and-forget), [`WorkPool::map`] is the
/// order-preserving convenience over `0..n`. Dropping the pool closes the
/// queue and joins every worker.
///
/// ```
/// use akda::coordinator::WorkPool;
///
/// let pool = WorkPool::new(4);
/// // map preserves input order even though jobs finish out of order
/// assert_eq!(pool.map(5, |i| i * i), vec![0, 1, 4, 9, 16]);
/// // submit returns a receiver; the job runs on a pool thread
/// let rx = pool.submit(|| "done");
/// assert_eq!(rx.recv().unwrap(), "done");
/// ```
pub struct WorkPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<PoolMetrics>>,
}

impl WorkPool {
    pub fn new(n_workers: usize) -> Self {
        let n = n_workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Mutex::new(PoolMetrics::default()));
        let jobs_total = obs::counter("akda_pool_jobs_total");
        let busy_total = obs::gauge("akda_pool_busy_seconds_total");
        let workers = (0..n)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
                let metrics = metrics.clone();
                let jobs_total = jobs_total.clone();
                let busy_total = busy_total.clone();
                std::thread::Builder::new()
                    .name(format!("akda-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let t0 = Instant::now();
                                job();
                                let dt = t0.elapsed().as_secs_f64();
                                jobs_total.inc();
                                busy_total.add(dt);
                                let mut m = metrics.lock().unwrap();
                                m.jobs_run += 1;
                                m.busy_s += dt;
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkPool { tx: Some(tx), workers, metrics }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Receiver<T> {
        let (rtx, rrx) = channel();
        let job: Job = Box::new(move || {
            let out = f();
            let _ = rtx.send(out);
        });
        self.tx.as_ref().expect("pool alive").send(job).expect("queue open");
        rrx
    }

    /// Run a batch of *borrowing* jobs to completion on the pool — the
    /// scoped-threadpool bridge the `linalg::backend::Parallel` backend
    /// fans its tile jobs through. Blocks until every job has finished
    /// (propagating the first panic, after draining the rest), which is
    /// what makes handing non-`'static` closures to `'static` worker
    /// threads sound: every borrow a job captures outlives its
    /// execution.
    ///
    /// Must not be called from a worker of the *same* pool (a job
    /// waiting on jobs behind it in the queue can starve a small pool);
    /// the linalg backend keeps its own dedicated pool and submits only
    /// leaf tile loops, so that situation cannot arise there.
    pub fn run_scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = jobs.len();
        let (tx, rx) = channel::<std::thread::Result<()>>();
        for job in jobs {
            // SAFETY: the receive loop below blocks until all `n` jobs
            // have signalled completion (the `catch_unwind` guarantees a
            // signal even on panic), so the 'env borrows captured by
            // `job` are live for as long as any worker can run it.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let tx = tx.clone();
            let wrapped: Job = Box::new(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let _ = tx.send(out);
            });
            self.tx.as_ref().expect("pool alive").send(wrapped).expect("queue open");
        }
        drop(tx);
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            match rx.recv().expect("worker signals completion") {
                Ok(()) => {}
                Err(payload) => panic = Some(payload),
            }
        }
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Map a fallible-free closure over 0..n through the pool, preserving
    /// order. Results are collected as they finish.
    pub fn map<T: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let rxs: Vec<Receiver<T>> = (0..n)
            .map(|i| {
                let f = f.clone();
                self.submit(move || f(i))
            })
            .collect();
        rxs.into_iter().map(|r| r.recv().expect("job completed")).collect()
    }

    pub fn metrics(&self) -> PoolMetrics {
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_jobs_and_returns_results_in_order() {
        let pool = WorkPool::new(4);
        let out = pool.map(32, |i| i * 2);
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(pool.metrics().jobs_run, 32);
    }

    #[test]
    fn parallel_speedup_observable() {
        // 8 sleeps of 30ms on 4 workers should take well under 8*30ms
        let pool = WorkPool::new(4);
        let t0 = Instant::now();
        pool.map(8, |_| std::thread::sleep(std::time::Duration::from_millis(30)));
        let dt = t0.elapsed().as_millis();
        assert!(dt < 8 * 30, "no parallelism: {dt}ms");
    }

    #[test]
    fn submit_single_job() {
        let pool = WorkPool::new(1);
        let rx = pool.submit(|| 7usize);
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn run_scoped_sees_borrowed_state() {
        // jobs mutate disjoint stripes of a stack-local buffer — the
        // exact usage pattern of the linalg Parallel backend
        let pool = WorkPool::new(4);
        let mut data = vec![0usize; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(8)
            .enumerate()
            .map(|(ti, chunk)| {
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = ti * 8 + i;
                    }
                });
                f
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(data, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn run_scoped_propagates_panics_after_draining() {
        let pool = WorkPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        if i == 2 {
                            panic!("job {i} failed");
                        }
                    });
                    f
                })
                .collect();
            pool.run_scoped(jobs);
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // the pool survives and keeps serving jobs
        assert_eq!(pool.map(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn run_scoped_empty_batch_is_a_noop() {
        let pool = WorkPool::new(1);
        pool.run_scoped(Vec::new());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkPool::new(2);
        let _ = pool.map(4, |i| i);
        drop(pool); // must not hang
    }
}
