//! Scoring service: the request-path component of the coordinator.
//!
//! After training, a `ScoringService` serves score requests against a
//! detector bank (DR projection + per-class LSVMs) over a channel with
//! dynamic micro-batching: requests arriving within a batching window are
//! projected through the kernel expansion *together* (one cross-kernel
//! block instead of many single-row ones — the same motivation as vLLM's
//! continuous batching, applied to kernel projections).
//!
//! The service does not own the bank directly: it reads it through a
//! [`BankHandle`], a swappable `Arc<DetectorBank>` slot. The model
//! registry's hot-reload watcher (`model::registry::HotReloader`) swaps a
//! freshly published model into the handle while the service is running —
//! each micro-batch picks up the current bank at dispatch time, so
//! in-flight requests finish against the bank they started with and no
//! request is ever dropped across a swap.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use anyhow::Result;

use crate::da::Projection;
use crate::data::Split;
use crate::eval::{average_precision, mean_average_precision};
use crate::linalg::Mat;
use crate::svm::LinearSvm;

/// A trained one-vs-rest detector bank: shared projection + per-class SVMs.
pub struct DetectorBank {
    pub projection: Box<dyn Projection>,
    pub svms: Vec<(String, LinearSvm)>,
}

impl DetectorBank {
    /// Score a batch of observations: rows × detectors.
    pub fn score(&self, x: &Mat) -> Mat {
        let z = self.projection.project(x);
        let mut out = Mat::zeros(x.rows(), self.svms.len());
        for (c, (_, svm)) in self.svms.iter().enumerate() {
            let scores = svm.decision_batch(&z);
            for (r, s) in scores.into_iter().enumerate() {
                out[(r, c)] = s;
            }
        }
        out
    }

    pub fn class_names(&self) -> Vec<String> {
        self.svms.iter().map(|(n, _)| n.clone()).collect()
    }
}

/// Argmax class of one observation's per-class scores — the single
/// prediction rule shared by every consumer of a [`DetectorBank`] (the
/// CLI's train-time evaluation, the serve demo, the fleet demo, and the
/// daemon's re-evaluation), so their printed accuracies can be compared
/// verbatim: tie-breaking is "last maximal class wins" everywhere
/// (`Iterator::max_by` keeps the last of equal maxima).
///
/// ```
/// assert_eq!(akda::coordinator::service::predict(&[0.1, 0.9, 0.4]), 1);
/// assert_eq!(akda::coordinator::service::predict(&[0.5, 0.5]), 1); // tie: last wins
/// ```
pub fn predict(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(c, _)| c)
        .unwrap()
}

/// Direct (service-less) test-split evaluation of a trained bank:
/// `(multiclass accuracy, one-vs-rest MAP)`. `akda train` and `akda
/// update` stamp these numbers into the published manifest; the serve
/// demo reports the same accuracy through the scoring service, so the
/// two paths cross-check each other (CI asserts the printed values are
/// equal — scoring is bit-for-bit identical either way).
pub fn eval_bank(bank: &DetectorBank, split: &Split) -> (f64, f64) {
    let scores = bank.score(&split.x_test);
    let n = split.x_test.rows();
    let mut correct = 0usize;
    for i in 0..n {
        if predict(scores.row(i)) == split.y_test[i] {
            correct += 1;
        }
    }
    let accuracy = correct as f64 / n as f64;
    let aps: Vec<f64> = (0..split.n_classes)
        .map(|cls| {
            let col = scores.col(cls);
            let positive: Vec<bool> = split.y_test.iter().map(|&l| l == cls).collect();
            average_precision(&col, &positive)
        })
        .collect();
    (accuracy, mean_average_precision(&aps))
}

/// A swappable slot holding the currently-served detector bank.
///
/// Cloning the handle shares the slot: `swap` on any clone is visible to
/// every reader at its next `get`. The scoring loop calls `get` once per
/// micro-batch, so a swap takes effect at the next batch boundary without
/// interrupting the batch being scored. The fleet keeps one versioned
/// handle per tenant, which is what gives every tenant an independent
/// hot-swap boundary and a GC identity.
///
/// ```
/// use std::sync::Arc;
/// use akda::coordinator::{BankHandle, DetectorBank};
/// use akda::da::IdentityProjection;
/// use akda::svm::LinearSvm;
///
/// let bank = Arc::new(DetectorBank {
///     projection: Box::new(IdentityProjection::new(2)),
///     svms: vec![("c0".into(), LinearSvm { w: vec![1.0, 0.0], b: 0.0 })],
/// });
/// let handle = BankHandle::new_versioned(bank.clone(), 1);
/// assert_eq!(handle.served_version(), 1);
/// // a hot swap advances the generation and the served version together
/// handle.swap_versioned(bank, 2);
/// assert_eq!((handle.generation(), handle.served_version()), (1, 2));
/// ```
#[derive(Clone)]
pub struct BankHandle {
    slot: Arc<RwLock<Arc<DetectorBank>>>,
    generation: Arc<AtomicUsize>,
    /// Registry version of the served bank (0 = not registry-backed).
    version: Arc<AtomicU32>,
}

impl BankHandle {
    pub fn new(bank: Arc<DetectorBank>) -> Self {
        Self::new_versioned(bank, 0)
    }

    /// A handle serving a specific registry version — lets monitoring,
    /// the continual-learning tests, and GC callers (`Registry::prune`'s
    /// `protect` argument) ask which published version is live right now.
    pub fn new_versioned(bank: Arc<DetectorBank>, version: u32) -> Self {
        BankHandle {
            slot: Arc::new(RwLock::new(bank)),
            generation: Arc::new(AtomicUsize::new(0)),
            version: Arc::new(AtomicU32::new(version)),
        }
    }

    /// The bank to score the next batch with.
    pub fn get(&self) -> Arc<DetectorBank> {
        self.slot.read().expect("bank slot poisoned").clone()
    }

    /// Publish a new bank to every reader (hot reload).
    pub fn swap(&self, bank: Arc<DetectorBank>) {
        *self.slot.write().expect("bank slot poisoned") = bank;
        self.generation.fetch_add(1, Ordering::SeqCst);
    }

    /// `swap` plus the registry version the new bank came from (what the
    /// `HotReloader` calls after decoding a freshly published version).
    pub fn swap_versioned(&self, bank: Arc<DetectorBank>, version: u32) {
        // order matters for readers: the bank lands before the version
        // advances, so a reader seeing version V always gets a bank at
        // least as new as V
        self.swap(bank);
        self.version.store(version, Ordering::SeqCst);
    }

    /// Number of swaps since creation (monitoring / tests).
    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::SeqCst)
    }

    /// Registry version currently served (0 when not registry-backed).
    pub fn served_version(&self) -> u32 {
        self.version.load(Ordering::SeqCst)
    }
}

/// One request: features in, per-class confidence scores out.
pub struct ScoreRequest {
    pub features: Vec<f64>,
    pub reply: Sender<Result<Vec<f64>>>,
}

/// Service statistics (exposed for the serving example / monitoring).
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub requests: usize,
    pub batches: usize,
    pub max_batch: usize,
}

/// Handle for submitting scoring requests.
#[derive(Clone)]
pub struct ScoringClient {
    tx: Sender<ScoreRequest>,
    dim: usize,
}

impl ScoringClient {
    pub fn score(&self, features: Vec<f64>) -> Result<Vec<f64>> {
        anyhow::ensure!(
            features.len() == self.dim,
            "expected {} features, got {}",
            self.dim,
            features.len()
        );
        let (reply, rx) = channel();
        self.tx
            .send(ScoreRequest { features, reply })
            .map_err(|_| anyhow::anyhow!("scoring service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("service dropped reply"))?
    }
}

/// The batching loop. Owns the detector bank on its own thread.
pub struct ScoringService {
    client: ScoringClient,
    stats_rx: Receiver<ServiceStats>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ScoringService {
    /// Serve a fixed bank (no hot reload): convenience over
    /// [`ScoringService::start_reloadable`].
    pub fn start(
        bank: Arc<DetectorBank>,
        input_dim: usize,
        max_batch: usize,
        window: Duration,
    ) -> ScoringService {
        Self::start_reloadable(BankHandle::new(bank), input_dim, max_batch, window)
    }

    /// `max_batch`: flush threshold; `window`: max time the first request
    /// in a batch waits for company. The service reads `handle` at every
    /// batch boundary, so `BankHandle::swap` hot-reloads the model without
    /// dropping queued or in-flight requests.
    pub fn start_reloadable(
        handle: BankHandle,
        input_dim: usize,
        max_batch: usize,
        window: Duration,
    ) -> ScoringService {
        let (tx, rx) = channel::<ScoreRequest>();
        let (stats_tx, stats_rx) = channel();
        let handle = std::thread::Builder::new()
            .name("akda-scoring".into())
            .spawn(move || {
                let mut stats = ServiceStats::default();
                let requests_total = crate::obs::counter("akda_serve_requests_total");
                let rounds_total = crate::obs::counter("akda_serve_rounds_total");
                loop {
                    // block for the first request of a batch
                    let first = match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    };
                    let mut batch = vec![first];
                    let deadline = std::time::Instant::now() + window;
                    while batch.len() < max_batch {
                        let left = deadline.saturating_duration_since(std::time::Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(r) => batch.push(r),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // assemble the batch matrix
                    let x = Mat::from_fn(batch.len(), input_dim, |r, c| {
                        batch[r].features[c]
                    });
                    // re-read the handle per batch: a hot swap lands here
                    let scores = handle.get().score(&x);
                    stats.requests += batch.len();
                    stats.batches += 1;
                    stats.max_batch = stats.max_batch.max(batch.len());
                    requests_total.add(batch.len() as u64);
                    rounds_total.inc();
                    let _ = stats_tx.send(stats.clone());
                    for (r, req) in batch.into_iter().enumerate() {
                        let row = scores.row(r).to_vec();
                        let _ = req.reply.send(Ok(row));
                    }
                }
            })
            .expect("spawn scoring service");
        ScoringService {
            client: ScoringClient { tx, dim: input_dim },
            stats_rx,
            handle: Some(handle),
        }
    }

    pub fn client(&self) -> ScoringClient {
        self.client.clone()
    }

    /// Latest stats snapshot (drains the channel).
    pub fn stats(&self) -> ServiceStats {
        let mut last = ServiceStats::default();
        while let Ok(s) = self.stats_rx.try_recv() {
            last = s;
        }
        last
    }
}

impl Drop for ScoringService {
    fn drop(&mut self) {
        // closing the client channel stops the loop
        let (tx, _) = channel();
        self.client = ScoringClient { tx, dim: self.client.dim };
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::da::akda::Akda;
    use crate::da::DrMethod;
    use crate::data::synthetic::{gaussian_classes, GaussianSpec};
    use crate::kernels::Kernel;
    use crate::svm::LinearSvmConfig;

    fn bank() -> (Arc<DetectorBank>, Mat, Vec<usize>) {
        let (x, labels) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![20; 3],
            dim: 6,
            class_sep: 2.5,
            noise: 0.5,
            modes_per_class: 1,
            seed: 5,
        });
        let projection = Akda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 3).unwrap();
        let z = projection.project(&x);
        let svms = (0..3)
            .map(|cls| {
                let y: Vec<f64> = labels
                    .iter()
                    .map(|&l| if l == cls { 1.0 } else { -1.0 })
                    .collect();
                (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
            })
            .collect();
        (Arc::new(DetectorBank { projection, svms }), x, labels)
    }

    #[test]
    fn bank_scores_classify_training_data() {
        let (bank, x, labels) = bank();
        let scores = bank.score(&x);
        let mut correct = 0;
        for i in 0..60 {
            let mut best = 0;
            for c in 1..3 {
                if scores[(i, c)] > scores[(i, best)] {
                    best = c;
                }
            }
            if best == labels[i] {
                correct += 1;
            }
        }
        assert!(correct >= 55, "correct={correct}/60");
    }

    #[test]
    fn service_answers_requests() {
        let (bank, x, _) = bank();
        let svc = ScoringService::start(bank, 6, 8, Duration::from_millis(5));
        let client = svc.client();
        let scores = client.score(x.row(0).to_vec()).unwrap();
        assert_eq!(scores.len(), 3);
        assert!(scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn service_batches_concurrent_requests() {
        let (bank, x, _) = bank();
        let svc = ScoringService::start(bank, 6, 32, Duration::from_millis(30));
        let client = svc.client();
        std::thread::scope(|s| {
            for i in 0..16 {
                let client = client.clone();
                let row = x.row(i).to_vec();
                s.spawn(move || {
                    let scores = client.score(row).unwrap();
                    assert_eq!(scores.len(), 3);
                });
            }
        });
        let stats = svc.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "batching happened: {stats:?}");
        assert!(stats.max_batch >= 2);
    }

    #[test]
    fn hot_swap_serves_new_bank_without_dropping_requests() {
        let (bank_a, x, _) = bank();
        let handle = BankHandle::new(bank_a.clone());
        let svc = ScoringService::start_reloadable(
            handle.clone(), 6, 4, Duration::from_millis(2));
        let client = svc.client();

        // a second bank with all-zero detectors: every score becomes b = 0
        let labels: Vec<usize> = (0..60).map(|i| i / 20).collect();
        let projection =
            Akda::new(Kernel::Rbf { rho: 0.3 }).fit(&x, &labels, 3).unwrap();
        let zero_svms = (0..3)
            .map(|c| {
                let w = vec![0.0; projection.dim()];
                (format!("class{c}"), LinearSvm { w, b: 0.0 })
            })
            .collect();
        let bank_b = Arc::new(DetectorBank { projection, svms: zero_svms });

        // requests against bank A answer normally
        let before = client.score(x.row(0).to_vec()).unwrap();
        assert!(before.iter().any(|s| *s != 0.0));
        // swap under the running service, then keep issuing requests
        handle.swap(bank_b);
        assert_eq!(handle.generation(), 1);
        let after = client.score(x.row(0).to_vec()).unwrap();
        assert!(after.iter().all(|s| *s == 0.0), "swap must take effect: {after:?}");
        // no request was dropped across the swap
        std::thread::scope(|s| {
            for i in 0..8 {
                let client = client.clone();
                let row = x.row(i).to_vec();
                s.spawn(move || {
                    assert_eq!(client.score(row).unwrap().len(), 3);
                });
            }
        });
    }

    #[test]
    fn bank_handle_tracks_served_version() {
        let (bank, _, _) = bank();
        let handle = BankHandle::new_versioned(bank.clone(), 3);
        assert_eq!(handle.served_version(), 3);
        assert_eq!(handle.generation(), 0);
        handle.swap_versioned(bank.clone(), 4);
        assert_eq!(handle.served_version(), 4);
        assert_eq!(handle.generation(), 1);
        // a plain swap (non-registry bank) leaves the version alone
        handle.swap(bank);
        assert_eq!(handle.served_version(), 4);
        assert_eq!(handle.generation(), 2);
        // unversioned handles report 0
        assert_eq!(BankHandle::new(handle.get()).served_version(), 0);
    }

    #[test]
    fn service_rejects_wrong_dim() {
        let (bank, _, _) = bank();
        let svc = ScoringService::start(bank, 6, 4, Duration::from_millis(1));
        assert!(svc.client().score(vec![0.0; 5]).is_err());
    }
}
