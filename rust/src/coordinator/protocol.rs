//! The paper's evaluation protocol (Sec. 6.3): for every class, build the
//! one-vs-rest binary problem, fit the DR method, train an LSVM in the
//! discriminant subspace, score the test set, and report per-class AP —
//! aggregated to MAP (ϖ), with training/testing wall-clock (ϑ, φ) summed
//! over classes. Hyper-parameters come from 3-fold CV (Sec. 6.3.1); CV
//! time is excluded from the reported training time, as in the paper.

use std::sync::Arc;

use anyhow::Result;

use super::config::EvalConfig;
use super::jobs::WorkPool;
use crate::data::Split;
use crate::da::{self, DrMethod};
use crate::eval::{average_precision, mean_average_precision, MethodResult};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::PjrtEngine;
use crate::svm::{KernelSvm, KernelSvmConfig, LinearSvm, LinearSvmConfig};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Method identifiers — the column set of Tables 2–7.
///
/// The CLI spelling and [`MethodId::name`] round-trip through
/// [`MethodId::from_name`]:
///
/// ```
/// use akda::coordinator::MethodId;
///
/// let id = MethodId::from_name("akda-nystrom").unwrap();
/// assert_eq!(id.name(), "akda-nystrom");
/// assert!(id.uses_landmarks()); // CV also searches the budget m for it
/// assert!(MethodId::from_name("no-such-method").is_none());
/// for id in MethodId::table_columns() {
///     assert_eq!(MethodId::from_name(id.name()), Some(id));
/// }
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MethodId {
    Pca,
    Lda,
    Lsvm,
    Kda,
    Gda,
    Srkda,
    Akda,
    /// AKDA with the hot path on the PJRT artifacts.
    AkdaPjrt,
    /// AKDA on Nyström landmark features (the `approx` subsystem) —
    /// O(N m²) training, m landmarks from k-means.
    AkdaNystrom,
    /// AKDA on random Fourier features (RBF kernel only).
    AkdaRff,
    Ksvm,
    Ksda,
    Gsda,
    Aksda,
    AksdaPjrt,
}

impl MethodId {
    pub fn name(&self) -> &'static str {
        match self {
            MethodId::Pca => "pca",
            MethodId::Lda => "lda",
            MethodId::Lsvm => "lsvm",
            MethodId::Kda => "kda",
            MethodId::Gda => "gda",
            MethodId::Srkda => "srkda",
            MethodId::Akda => "akda",
            MethodId::AkdaPjrt => "akda-pjrt",
            MethodId::AkdaNystrom => "akda-nystrom",
            MethodId::AkdaRff => "akda-rff",
            MethodId::Ksvm => "ksvm",
            MethodId::Ksda => "ksda",
            MethodId::Gsda => "gsda",
            MethodId::Aksda => "aksda",
            MethodId::AksdaPjrt => "aksda-pjrt",
        }
    }

    pub fn from_name(s: &str) -> Option<MethodId> {
        use MethodId::*;
        Some(match s {
            "pca" => Pca,
            "lda" => Lda,
            "lsvm" => Lsvm,
            "kda" => Kda,
            "gda" => Gda,
            "srkda" => Srkda,
            "akda" => Akda,
            "akda-pjrt" => AkdaPjrt,
            "akda-nystrom" => AkdaNystrom,
            "akda-rff" => AkdaRff,
            "ksvm" => Ksvm,
            "ksda" => Ksda,
            "gsda" => Gsda,
            "aksda" => Aksda,
            "aksda-pjrt" => AksdaPjrt,
            _ => return None,
        })
    }

    pub fn uses_kernel(&self) -> bool {
        !matches!(self, MethodId::Pca | MethodId::Lda | MethodId::Lsvm)
    }

    pub fn uses_subclasses(&self) -> bool {
        matches!(
            self,
            MethodId::Ksda | MethodId::Gsda | MethodId::Aksda | MethodId::AksdaPjrt
        )
    }

    /// Whether the method consumes the landmark / random-feature budget m
    /// (the `approx` subsystem methods) — these CV-search `m_grid`.
    pub fn uses_landmarks(&self) -> bool {
        matches!(self, MethodId::AkdaNystrom | MethodId::AkdaRff)
    }

    /// The full column set of Tables 2–7 (native engines).
    pub fn table_columns() -> Vec<MethodId> {
        use MethodId::*;
        vec![
            Pca, Lda, Lsvm, Kda, Gda, Srkda, Akda, AkdaNystrom, AkdaRff, Ksvm, Ksda,
            Gsda, Aksda,
        ]
    }
}

/// One hyper-parameter assignment from the CV grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub rho: f64,
    pub c: f64,
    pub h: usize,
    /// Landmark / random-feature budget m for the approximate methods
    /// (akda-nystrom / akda-rff); ignored by the exact ones.
    pub m: usize,
    /// When set, the approximate methods train through the out-of-core
    /// tiled pipeline (`da::akda_stream`) with this tile height B instead
    /// of materializing the N×m feature matrix — peak accumulator memory
    /// O(B·m + m²) instead of O(N·m). `None` = in-memory (default).
    pub stream_block: Option<usize>,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            rho: 0.1,
            c: 1.0,
            h: 2,
            m: crate::approx::DEFAULT_BUDGET,
            stream_block: None,
        }
    }
}

/// Label-independent approximate-AKDA state shared across the one-vs-rest
/// classes of one `evaluate_ovr` call.
enum SharedApprox {
    /// In-memory: prepared training-side state (map, Φ, Cholesky) plus the
    /// test features Φ_test, both resident for the whole OvR loop.
    Dense { prep: da::akda_approx::PreparedFeatures, phi_test: Mat },
    /// Out-of-core: every one-vs-rest solve comes from the same tiled
    /// accumulation state, so all C directions are stacked into one m×C W
    /// at build time and the train/test rows are projected through the
    /// tiled pipeline exactly once (no N×m feature matrix is ever
    /// resident). Per-class work is a column slice of these N×C scores.
    Stream { z_train: Mat, z_test: Mat },
}

/// The approximate-AKDA configuration for a grid point — one source for
/// `build_dr`, the shared-feature-map path of `evaluate_ovr`, and the
/// serve subcommand's streaming bank (the constructors own the default
/// block/seed).
pub fn approx_config(id: MethodId, hp: Hyper, eps: f64) -> da::akda_approx::AkdaApprox {
    let kernel = Kernel::Rbf { rho: hp.rho };
    let mut dr = if id == MethodId::AkdaRff {
        da::akda_approx::AkdaApprox::rff(kernel, hp.m)
    } else {
        da::akda_approx::AkdaApprox::nystrom(kernel, hp.m)
    };
    dr.eps = eps;
    dr
}

/// The exact-AKDA configuration for a grid point — one source for
/// [`build_dr`] and `akda train`'s factor-retaining path
/// (`Akda::fit_with_factor`), so the model `akda train` publishes can
/// never drift in kernel/ridge/block from the one `akda eval` evaluates.
pub fn akda_config(hp: Hyper, eps: f64) -> da::akda::Akda {
    da::akda::Akda {
        kernel: Kernel::Rbf { rho: hp.rho },
        eps,
        block: crate::linalg::chol::DEFAULT_BLOCK,
    }
}

/// Build the DR method for a spec (None for the pure-SVM columns).
pub fn build_dr(
    id: MethodId,
    hp: Hyper,
    eps: f64,
    engine: Option<&Arc<PjrtEngine>>,
) -> Result<Option<Box<dyn DrMethod>>> {
    let kernel = Kernel::Rbf { rho: hp.rho };
    Ok(match id {
        MethodId::Pca => Some(Box::new(da::pca::Pca::new())),
        MethodId::Lda => Some(Box::new(da::lda::Lda { eps })),
        MethodId::Lsvm | MethodId::Ksvm => None,
        MethodId::Kda => Some(Box::new(da::kda::Kda { kernel, eps })),
        MethodId::Gda => Some(Box::new(da::gda::Gda { kernel, eps })),
        MethodId::Srkda => Some(Box::new(da::srkda::Srkda { kernel, eps })),
        MethodId::Akda => Some(Box::new(akda_config(hp, eps))),
        MethodId::AkdaNystrom | MethodId::AkdaRff => {
            Some(Box::new(approx_config(id, hp, eps)))
        }
        MethodId::AkdaPjrt => {
            let engine = engine
                .ok_or_else(|| anyhow::anyhow!("akda-pjrt needs a PJRT engine"))?;
            Some(Box::new(crate::runtime::AkdaPjrt { kernel, engine: engine.clone() }))
        }
        MethodId::Ksda => Some(Box::new(da::ksda::Ksda {
            kernel,
            eps,
            h_per_class: hp.h,
        })),
        MethodId::Gsda => Some(Box::new(da::ksda::Gsda {
            kernel,
            eps,
            h_per_class: hp.h,
            seed: 23,
        })),
        MethodId::Aksda => Some(Box::new(da::aksda::Aksda {
            kernel,
            eps,
            h_per_class: hp.h,
            seed: 17,
            block: crate::linalg::chol::DEFAULT_BLOCK,
        })),
        MethodId::AksdaPjrt => {
            let engine = engine
                .ok_or_else(|| anyhow::anyhow!("aksda-pjrt needs a PJRT engine"))?;
            Some(Box::new(crate::runtime::AksdaPjrt {
                kernel,
                engine: engine.clone(),
                h_per_class: hp.h,
                seed: 17,
            }))
        }
    })
}

/// One-vs-rest evaluation of one method on one split: returns per-class
/// APs plus summed train/test seconds.
pub fn evaluate_ovr(
    split: &Split,
    id: MethodId,
    hp: Hyper,
    eps: f64,
    engine: Option<&Arc<PjrtEngine>>,
    pool: Option<&WorkPool>,
) -> Result<MethodResult> {
    let classes: Vec<usize> = (0..split.n_classes).collect();
    let engine = engine.cloned();
    let split = Arc::new(split.clone());
    // The approximate methods' state up to the RHS — feature map, Gram
    // Cholesky, and (dense path only) the features Φ / Φ_test — is
    // label-independent: build it once, share it across the C one-vs-rest
    // fits, and charge its cost to the train/test time once (below).
    let mut shared_train_s = 0.0;
    let mut shared_test_s = 0.0;
    let mut peak_f64 = None;
    let shared: Option<Arc<SharedApprox>> = match id {
        MethodId::AkdaNystrom | MethodId::AkdaRff => match hp.stream_block {
            Some(block_rows) => {
                // out-of-core tiling: accumulate ΦᵀΦ + class sums tile by
                // tile, then stack all C one-vs-rest solves into one m×C W
                // so a single tiled pass over train (and test) serves every
                // class — the dense arm's Φ-cache equivalent at O(B·m)
                let span = crate::obs::span("train");
                let mut src = crate::data::stream::MemBlockSource::new(
                    &split.x_train,
                    &split.y_train,
                    block_rows,
                );
                let prep = approx_config(id, hp, eps).prepare_stream(&mut src)?;
                let mut w_all = Mat::zeros(prep.map.dim(), split.n_classes);
                for cls in 0..split.n_classes {
                    w_all.set_col(cls, &prep.solve_w_class(cls)?.col(0));
                }
                let z_train = da::akda_stream::project_blocked(
                    prep.map.as_ref(),
                    &w_all,
                    &split.x_train,
                    block_rows,
                );
                shared_train_s = span.finish();
                let span = crate::obs::span("test");
                let z_test = da::akda_stream::project_blocked(
                    prep.map.as_ref(),
                    &w_all,
                    &split.x_test,
                    block_rows,
                );
                shared_test_s = span.finish();
                peak_f64 = Some(prep.stats.peak_resident_f64());
                Some(Arc::new(SharedApprox::Stream { z_train, z_test }))
            }
            None => {
                let span = crate::obs::span("train");
                let prep = approx_config(id, hp, eps).prepare(&split.x_train)?;
                shared_train_s = span.finish();
                let span = crate::obs::span("test");
                let phi_test = prep.map.transform(&split.x_test);
                shared_test_s = span.finish();
                Some(Arc::new(SharedApprox::Dense { prep, phi_test }))
            }
        },
        _ => None,
    };
    let run_class = {
        let split = split.clone();
        let shared = shared.clone();
        move |cls: usize| -> Result<(f64, f64, f64)> {
            let mut watch = Stopwatch::new();
            // binary relabel: target class → 0, rest → 1 (Sec. 4.4 order)
            let y_bin: Vec<usize> =
                split.y_train.iter().map(|&l| usize::from(l != cls)).collect();
            let scores = match id {
                MethodId::Ksvm => {
                    let y_pm: Vec<f64> = y_bin
                        .iter()
                        .map(|&b| if b == 0 { 1.0 } else { -1.0 })
                        .collect();
                    let svm = watch.train(|| {
                        KernelSvm::train(
                            &split.x_train,
                            &y_pm,
                            KernelSvmConfig {
                                c: hp.c,
                                kernel: Kernel::Rbf { rho: hp.rho },
                                ..Default::default()
                            },
                        )
                    });
                    watch.test(|| svm.decision_batch(&split.x_test))
                }
                MethodId::Lsvm => {
                    let y_pm: Vec<f64> = y_bin
                        .iter()
                        .map(|&b| if b == 0 { 1.0 } else { -1.0 })
                        .collect();
                    let svm = watch.train(|| {
                        LinearSvm::train(
                            &split.x_train,
                            &y_pm,
                            LinearSvmConfig { c: hp.c, ..Default::default() },
                        )
                    });
                    watch.test(|| svm.decision_batch(&split.x_test))
                }
                _ => {
                    let (z_train, z_test) = match shared.as_deref() {
                        Some(SharedApprox::Dense { prep, phi_test }) => {
                            // Φ / Φ_test are cached — z = Φ W, no re-transform
                            let proj = watch.train(|| prep.fit(&y_bin, 2))?;
                            let z_tr = watch.train(|| prep.phi.matmul(&proj.w));
                            let z_te = watch.test(|| phi_test.matmul(&proj.w));
                            (z_tr, z_te)
                        }
                        Some(SharedApprox::Stream { z_train, z_test }) => {
                            // solves + tiled projections were shared and
                            // charged once above; per-class cost is a slice
                            let z_tr = watch.train(|| Mat::col_vec(&z_train.col(cls)));
                            let z_te = watch.test(|| Mat::col_vec(&z_test.col(cls)));
                            (z_tr, z_te)
                        }
                        None => {
                            let dr = build_dr(id, hp, eps, engine.as_ref())?
                                .expect("DR method");
                            let proj =
                                watch.train(|| dr.fit(&split.x_train, &y_bin, 2))?;
                            let z_tr = watch.train(|| proj.project(&split.x_train));
                            let z_te = watch.test(|| proj.project(&split.x_test));
                            (z_tr, z_te)
                        }
                    };
                    let y_pm: Vec<f64> = y_bin
                        .iter()
                        .map(|&b| if b == 0 { 1.0 } else { -1.0 })
                        .collect();
                    let svm = watch.train(|| {
                        LinearSvm::train(
                            &z_train,
                            &y_pm,
                            LinearSvmConfig { c: hp.c, ..Default::default() },
                        )
                    });
                    watch.test(|| svm.decision_batch(&z_test))
                }
            };
            let positive: Vec<bool> = split.y_test.iter().map(|&l| l == cls).collect();
            let ap = average_precision(&scores, &positive);
            Ok((ap, watch.train_s, watch.test_s))
        }
    };

    let per_class: Vec<Result<(f64, f64, f64)>> = match pool {
        Some(pool) => {
            let run_class = Arc::new(run_class);
            let rc = run_class.clone();
            pool.map(classes.len(), move |i| rc(i))
        }
        None => classes.iter().map(|&c| run_class(c)).collect(),
    };

    let mut aps = Vec::new();
    let mut train_s = shared_train_s;
    let mut test_s = shared_test_s;
    for r in per_class {
        let (ap, tr, te) = r?;
        aps.push(ap);
        train_s += tr;
        test_s += te;
    }
    Ok(MethodResult {
        method: id.name().to_string(),
        map: mean_average_precision(&aps),
        train_s,
        test_s,
        peak_f64,
        budget: id.uses_landmarks().then_some(hp.m),
    })
}

/// 3-fold CV hyper-parameter selection (Sec. 6.3.1): per fold, the
/// training set is split 30% learn / 70% validate; the grid point with the
/// best mean validation MAP wins. For the approximate methods the
/// landmark / random-feature budget m joins the grid (`EvalConfig::m_grid`)
/// exactly like rho/C/H; exact methods keep the single configured budget
/// (it is ignored by their trainers anyway).
pub fn select_hyper(
    split: &Split,
    id: MethodId,
    cfg: &EvalConfig,
    engine: Option<&Arc<PjrtEngine>>,
) -> Result<Hyper> {
    let rho_grid: &[f64] = if id.uses_kernel() { &cfg.rho_grid } else { &[0.1] };
    let h_grid: &[usize] = if id.uses_subclasses() { &cfg.h_grid } else { &[1] };
    let single_m = [cfg.landmarks];
    let m_grid: &[usize] = if id.uses_landmarks() && !cfg.m_grid.is_empty() {
        &cfg.m_grid
    } else {
        &single_m
    };
    let mut best = (f64::NEG_INFINITY, Hyper::default());
    let n = split.y_train.len();
    // flatten the (rho, C, H, m) product so the fold loop stays readable
    let mut grid = Vec::new();
    for &rho in rho_grid {
        for &c in &cfg.c_grid {
            for &h in h_grid {
                for &m in m_grid {
                    grid.push(Hyper { rho, c, h, m, stream_block: cfg.stream_block });
                }
            }
        }
    }
    for hp in grid {
        let mut maps = Vec::new();
        for fold in 0..cfg.cv_folds {
            let mut rng = Rng::new(cfg.seed ^ (fold as u64) << 8);
            // stratified learn/validate split
            let mut learn_idx = Vec::new();
            let mut val_idx = Vec::new();
            for cls in 0..split.n_classes {
                let mut idx: Vec<usize> =
                    (0..n).filter(|&i| split.y_train[i] == cls).collect();
                rng.shuffle(&mut idx);
                let k = ((idx.len() as f64 * cfg.cv_learn_frac).round() as usize)
                    .clamp(2.min(idx.len()), idx.len().saturating_sub(1))
                    .max(1);
                learn_idx.extend_from_slice(&idx[..k]);
                val_idx.extend_from_slice(&idx[k..]);
            }
            learn_idx.sort_unstable();
            val_idx.sort_unstable();
            if learn_idx.len() < 2 * split.n_classes || val_idx.is_empty() {
                continue;
            }
            let sub = Split {
                x_train: split.x_train.select_rows(&learn_idx),
                y_train: learn_idx.iter().map(|&i| split.y_train[i]).collect(),
                x_test: split.x_train.select_rows(&val_idx),
                y_test: val_idx.iter().map(|&i| split.y_train[i]).collect(),
                n_classes: split.n_classes,
            };
            if let Ok(res) = evaluate_ovr(&sub, id, hp, cfg.eps, engine, None) {
                maps.push(res.map);
            }
        }
        if !maps.is_empty() {
            let mean = maps.iter().sum::<f64>() / maps.len() as f64;
            if mean > best.0 {
                best = (mean, hp);
            }
        }
    }
    anyhow::ensure!(best.0.is_finite(), "CV produced no valid folds");
    Ok(best.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{by_name, Condition};

    fn small_split() -> Split {
        let mut d = by_name("eth80").unwrap();
        d.n_classes = 4; // trim for test speed
        d.test_per_class = 20;
        d.split(Condition::Ex10)
    }

    #[test]
    fn akda_ovr_beats_chance() {
        let split = small_split();
        let res = evaluate_ovr(
            &split, MethodId::Akda, Hyper { rho: 0.05, c: 1.0, h: 1, ..Default::default() },
            1e-3, None, None,
        )
        .unwrap();
        // chance MAP ≈ positive prevalence = 1/4
        assert!(res.map > 0.5, "MAP={}", res.map);
        assert!(res.train_s > 0.0 && res.test_s > 0.0);
    }

    #[test]
    fn all_methods_run_on_tiny_split() {
        let split = small_split();
        for id in MethodId::table_columns() {
            let res = evaluate_ovr(
                &split,
                id,
                Hyper { rho: 0.05, c: 1.0, h: 2, m: 24, ..Default::default() },
                1e-3,
                None,
                None,
            )
            .unwrap_or_else(|e| panic!("{} failed: {e}", id.name()));
            assert!(res.map >= 0.0 && res.map <= 1.0, "{}", id.name());
        }
    }

    #[test]
    fn pool_and_serial_agree() {
        let split = small_split();
        let hp = Hyper { rho: 0.05, c: 1.0, h: 1, ..Default::default() };
        let serial =
            evaluate_ovr(&split, MethodId::Akda, hp, 1e-3, None, None).unwrap();
        let pool = WorkPool::new(4);
        let parallel =
            evaluate_ovr(&split, MethodId::Akda, hp, 1e-3, None, Some(&pool)).unwrap();
        assert!((serial.map - parallel.map).abs() < 1e-12);
    }

    #[test]
    fn cv_selects_from_grid() {
        let split = small_split();
        let cfg = EvalConfig {
            rho_grid: vec![0.001, 0.05],
            c_grid: vec![1.0],
            h_grid: vec![2],
            cv_folds: 2,
            ..Default::default()
        };
        let hp = select_hyper(&split, MethodId::Akda, &cfg, None).unwrap();
        assert!(cfg.rho_grid.contains(&hp.rho));
        assert!(cfg.c_grid.contains(&hp.c));
    }

    #[test]
    fn cv_searches_the_landmark_grid_for_approx_methods_only() {
        let split = small_split();
        let cfg = EvalConfig {
            rho_grid: vec![0.05],
            c_grid: vec![1.0],
            h_grid: vec![1],
            m_grid: vec![4, 24],
            cv_folds: 2,
            ..Default::default()
        };
        let hp = select_hyper(&split, MethodId::AkdaNystrom, &cfg, None).unwrap();
        assert!(cfg.m_grid.contains(&hp.m), "picked m={}", hp.m);
        // exact methods don't search m: they keep the configured budget
        let hp = select_hyper(&split, MethodId::Akda, &cfg, None).unwrap();
        assert_eq!(hp.m, cfg.landmarks);
    }

    #[test]
    fn results_report_the_budget_for_approx_methods_only() {
        let split = small_split();
        let hp = Hyper { rho: 0.05, c: 1.0, h: 1, m: 24, ..Default::default() };
        let exact =
            evaluate_ovr(&split, MethodId::Akda, hp, 1e-3, None, None).unwrap();
        assert_eq!(exact.budget, None);
        let approx =
            evaluate_ovr(&split, MethodId::AkdaNystrom, hp, 1e-3, None, None).unwrap();
        assert_eq!(approx.budget, Some(24));
    }

    #[test]
    fn approx_akda_tracks_exact_akda_on_ovr() {
        let split = small_split();
        let hp = Hyper { rho: 0.05, c: 1.0, h: 1, m: 24, ..Default::default() };
        let exact =
            evaluate_ovr(&split, MethodId::Akda, hp, 1e-3, None, None).unwrap();
        let nystrom =
            evaluate_ovr(&split, MethodId::AkdaNystrom, hp, 1e-3, None, None).unwrap();
        assert!(
            nystrom.map > exact.map - 0.1,
            "nystrom MAP {} vs exact {}",
            nystrom.map,
            exact.map
        );
    }

    #[test]
    fn streaming_ovr_tracks_dense_ovr_and_reports_memory() {
        // same data, same budget: the tiled path must reproduce the dense
        // approximate path's MAP (solves agree to ~1e-12) and report its
        // peak accumulator residency, which dense runs leave unset
        let split = small_split();
        let hp = Hyper { rho: 0.05, c: 1.0, h: 1, m: 24, ..Default::default() };
        let dense =
            evaluate_ovr(&split, MethodId::AkdaNystrom, hp, 1e-3, None, None).unwrap();
        assert!(dense.peak_f64.is_none());
        let hp_s = Hyper { stream_block: Some(16), ..hp };
        let stream =
            evaluate_ovr(&split, MethodId::AkdaNystrom, hp_s, 1e-3, None, None).unwrap();
        let peak = stream.peak_f64.expect("streaming runs report residency");
        assert!(peak > 0);
        // the whole point: tiles, not the resident N×F input + N×m Φ
        let (n, f) = (split.x_train.rows(), split.x_train.cols());
        let dense_equiv = n * (f + 24) + 24 * 24;
        assert!(
            peak < dense_equiv,
            "peak {peak} should be below the in-memory residency {dense_equiv}"
        );
        assert!(
            (stream.map - dense.map).abs() < 0.02,
            "stream MAP {} vs dense {}",
            stream.map,
            dense.map
        );
    }

    #[test]
    fn method_id_roundtrip() {
        for id in MethodId::table_columns() {
            assert_eq!(MethodId::from_name(id.name()), Some(id));
        }
        assert_eq!(MethodId::from_name("bogus"), None);
    }
}
