//! The network edge (L8): a dependency-free TCP server in front of the
//! fleet, speaking the [`wire`](super::wire) `akda-wire/1` framing.
//!
//! `akda serve --fleet --listen ADDR` binds a [`NetServer`] over the
//! in-process [`FleetClient`]; remote [`NetClient`]s then score any tenant
//! by name, list the live roster, and observe hot swaps and onboarding —
//! the registry watcher keeps working underneath, so a NEW model name
//! published to the registry becomes scorable over an already-open
//! listener without restart.
//!
//! # Connection pipeline
//!
//! ```text
//!  accept thread ──► per-connection reader thread
//!                         │ decode frame (checksummed)
//!                         │   malformed → Error{BadFrame} + close
//!                         │   ModelsRequest → answered inline (roster)
//!                         ▼
//!                 ┌─────────────────────┐  shed-oldest on overflow:
//!                 │ bounded ingress     │  Error{OverCapacity,
//!                 │ queue (server-wide) │        retry_after_ms}
//!                 └─────────┬───────────┘
//!                           ▼ pump thread (paced by max_inflight)
//!                  FleetClient::submit ──► dispatcher micro-batcher
//!                           │ reply closure
//!                           ▼
//!                 per-connection writer thread ──► TCP
//! ```
//!
//! Three design rules keep one bad client from hurting the rest:
//!
//! * **Bounded buffering.** Requests wait in ONE server-wide queue of
//!   fixed capacity. On overflow the *oldest* waiting request is shed
//!   with a typed [`ErrorCode::OverCapacity`] frame carrying a
//!   retry-after hint — freshest-first under overload, and a client
//!   gets an answer, never a hang.
//! * **Paced submission.** The pump keeps at most `max_inflight`
//!   requests inside the fleet dispatcher, so a listener cannot flood
//!   the shared scoring pool past what it can drain.
//! * **Per-connection isolation.** Each connection has its own reader
//!   and writer threads and a private reply channel; a malformed frame
//!   is answered with `Error{BadFrame}` and closes *that* connection
//!   only. Replies are routed by the `req_id` the client chose, so one
//!   connection may pipeline many requests (replies can complete out of
//!   order — the fleet batches per tenant).
//!
//! Everything is instrumented through the process-global [`obs`]
//! registry: `akda_net_connections`, `akda_net_frames_total{type=..}`,
//! `akda_net_errors_total{code=..}`, `akda_net_bytes_{in,out}_total`,
//! `akda_net_sheds_total{reason=..}`, `akda_net_queue_depth`, and the
//! per-frame `akda_net_frame_seconds` latency histogram. Queue and shed
//! instruments carry a `listen` label (the bound address), so several
//! servers in one process — e.g. concurrent integration tests — do not
//! bleed into each other's readings.
//!
//! # Request tracing (L9)
//!
//! Every score request's residency is split into the five sequential
//! stages of [`obs::trace`]: the reader measures `net/read`
//! ([`wire::read_frame_timed`]) and mints a [`TraceStamps`] cell that
//! rides the request into the fleet (`fleet/batch_wait`, `pool/score`);
//! the pump measures `net/queue` when it pops the request; the writer
//! measures `net/write`, echoes the server timings into a traced
//! response, feeds the `akda_trace_stage_seconds{stage=..}` histograms,
//! and offers the assembled [`TraceRecord`] to the server's optional
//! [`TraceSink`] (`--trace-out`). Sheds are traced too — terminal at
//! `net/queue`, with `shed=true`.

use std::collections::{HashMap, VecDeque};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::fleet::{FleetClient, FleetError};
use super::wire::{self, ErrorCode, Frame, ReadError, WireModel};
use crate::obs;
use crate::obs::trace::{
    TraceRecord, TraceSink, TraceStamps, STAGES, STAGE_BATCH_WAIT, STAGE_NET_QUEUE,
    STAGE_NET_READ, STAGE_NET_WRITE, STAGE_POOL_SCORE,
};

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

/// Knobs for [`NetServer::start`].
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Capacity of the server-wide ingress queue. An arriving request
    /// that would overflow it sheds the OLDEST waiting request with an
    /// [`ErrorCode::OverCapacity`] frame.
    pub queue_cap: usize,
    /// Max requests submitted into the fleet dispatcher at once.
    pub max_inflight: usize,
    /// Retry hint (milliseconds) carried by shed responses.
    pub retry_after_ms: u32,
    /// Per-request trace sink (`--trace-out`); `None` disables JSONL
    /// emission (stage histograms and response echoes still work).
    pub trace: Option<Arc<TraceSink>>,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions { queue_cap: 1024, max_inflight: 256, retry_after_ms: 50, trace: None }
    }
}

// ---------------------------------------------------------------------------
// Ingress queue
// ---------------------------------------------------------------------------

/// One admitted score request waiting for a fleet slot.
struct Pending {
    req_id: u64,
    model: String,
    features: Vec<f64>,
    /// Client-minted trace id (0 = untraced).
    trace: u64,
    /// `net/read` duration measured by the reader (seconds).
    read_s: f64,
    /// Stamp cell the fleet writes `batch_wait`/`score` into.
    stamps: Arc<TraceStamps>,
    /// The owning connection's writer channel.
    reply_tx: Sender<Outbound>,
    received_at: Instant,
}

/// One frame on its way out of a connection, plus the trace context the
/// writer needs to finish the record (`None` for roster/metrics answers
/// and protocol errors that never entered the score pipeline).
struct Outbound {
    frame: Frame,
    ctx: Option<Box<TraceCtx>>,
}

impl Outbound {
    fn plain(frame: Frame) -> Outbound {
        Outbound { frame, ctx: None }
    }
}

/// Everything known about one score request when its reply leaves the
/// fleet; the writer thread adds the final `net/write` stage, echoes
/// the stages into a traced response, and emits record + histograms.
struct TraceCtx {
    trace: u64,
    req_id: u64,
    model: String,
    read_s: f64,
    queue_s: f64,
    stamps: Arc<TraceStamps>,
    /// When the fleet reply fired (start of `net/write`).
    done_at: Instant,
}

struct IngressState {
    queue: VecDeque<Pending>,
    inflight: usize,
    stopped: bool,
}

/// The bounded server-wide admission queue (ingress) plus its pacing
/// state. Readers push, the single pump thread pops; the condvar wakes
/// the pump on new work AND on in-flight slots freeing up.
struct Ingress {
    state: Mutex<IngressState>,
    cv: Condvar,
}

impl Ingress {
    fn new() -> Ingress {
        Ingress {
            state: Mutex::new(IngressState {
                queue: VecDeque::new(),
                inflight: 0,
                stopped: false,
            }),
            cv: Condvar::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Obs handles resolved once at server start — the per-frame hot path
/// never touches the registry lock. Error counters are the exception:
/// they are resolved per occurrence (errors are not the hot path) so
/// every [`ErrorCode`] gets its own labeled series lazily.
struct NetMetrics {
    connections: Arc<obs::Gauge>,
    frames_score: Arc<obs::Counter>,
    frames_models: Arc<obs::Counter>,
    frames_metrics: Arc<obs::Counter>,
    bytes_in: Arc<obs::Counter>,
    bytes_out: Arc<obs::Counter>,
    queue_depth: Arc<obs::Gauge>,
    sheds_queue_full: Arc<obs::Counter>,
    frame_seconds: Arc<obs::Histogram>,
    /// `akda_trace_stage_seconds{stage=..}` in [`STAGES`] order — the
    /// aggregate twin of the per-request trace records.
    stage_seconds: [Arc<obs::Histogram>; 5],
    /// The server's `--trace-out` sink, threaded here because this
    /// bundle already reaches every pipeline hop that emits records.
    trace_sink: Option<Arc<TraceSink>>,
}

impl NetMetrics {
    fn new(listen: &str, trace_sink: Option<Arc<TraceSink>>) -> NetMetrics {
        NetMetrics {
            connections: obs::gauge_with("akda_net_connections", &[("listen", listen)]),
            frames_score: obs::counter_with(
                "akda_net_frames_total",
                &[("type", "score_request")],
            ),
            frames_models: obs::counter_with(
                "akda_net_frames_total",
                &[("type", "models_request")],
            ),
            frames_metrics: obs::counter_with(
                "akda_net_frames_total",
                &[("type", "metrics_request")],
            ),
            bytes_in: obs::counter("akda_net_bytes_in_total"),
            bytes_out: obs::counter("akda_net_bytes_out_total"),
            queue_depth: obs::gauge_with("akda_net_queue_depth", &[("listen", listen)]),
            sheds_queue_full: obs::counter_with(
                "akda_net_sheds_total",
                &[("listen", listen), ("reason", "queue_full")],
            ),
            frame_seconds: obs::histogram("akda_net_frame_seconds"),
            stage_seconds: std::array::from_fn(|i| {
                obs::histogram_with("akda_trace_stage_seconds", &[("stage", STAGES[i].1)])
            }),
            trace_sink,
        }
    }

    fn error(code: ErrorCode) {
        obs::counter_with("akda_net_errors_total", &[("code", code.name())]).inc();
    }
}

/// Map a fleet rejection to its wire frame.
fn error_frame(req_id: u64, err: &FleetError) -> Frame {
    let (code, retry_after_ms) = match err {
        FleetError::UnknownModel { .. } => (ErrorCode::UnknownModel, 0),
        FleetError::WrongDim { .. } => (ErrorCode::WrongDim, 0),
        FleetError::ServiceDown => (ErrorCode::ServiceDown, 0),
        FleetError::OverCapacity { retry_after_ms } => {
            (ErrorCode::OverCapacity, *retry_after_ms)
        }
    };
    Frame::Error { req_id, code, retry_after_ms, message: err.to_string() }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// TCP front of a [`FleetService`](super::FleetService) — see the module
/// docs for the pipeline. Bind with [`NetServer::start`]; dropping the
/// server closes the listener and every connection and joins all its
/// threads.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    ingress: Arc<Ingress>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    accept: Option<std::thread::JoinHandle<()>>,
    pump: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:4780"`; port 0 picks a free one —
    /// read it back from [`NetServer::local_addr`]) and start serving
    /// `client`'s fleet over it.
    pub fn start(addr: &str, client: FleetClient, opts: NetOptions) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding wire listener on {addr}"))?;
        let local_addr = listener.local_addr().context("listener local addr")?;
        let listen_label = local_addr.to_string();
        let metrics = Arc::new(NetMetrics::new(&listen_label, opts.trace.clone()));
        let stop = Arc::new(AtomicBool::new(false));
        let ingress = Arc::new(Ingress::new());
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let pump = std::thread::Builder::new()
            .name("akda-net-pump".into())
            .spawn({
                let ingress = ingress.clone();
                let client = client.clone();
                let metrics = metrics.clone();
                let max_inflight = opts.max_inflight.max(1);
                move || Self::pump_loop(&ingress, &client, &metrics, max_inflight)
            })
            .expect("spawn net pump");

        let accept = std::thread::Builder::new()
            .name("akda-net-accept".into())
            .spawn({
                let stop = stop.clone();
                let ingress = ingress.clone();
                let conns = conns.clone();
                let threads = threads.clone();
                let metrics = metrics.clone();
                let queue_cap = opts.queue_cap.max(1);
                let retry_after_ms = opts.retry_after_ms;
                move || {
                    let next_conn = AtomicU64::new(0);
                    for stream in listener.incoming() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let conn_id = next_conn.fetch_add(1, Ordering::Relaxed);
                        Self::spawn_connection(
                            conn_id,
                            stream,
                            &client,
                            &ingress,
                            &conns,
                            &threads,
                            &metrics,
                            queue_cap,
                            retry_after_ms,
                        );
                    }
                }
            })
            .expect("spawn net accept");

        Ok(NetServer {
            local_addr,
            stop,
            ingress,
            conns,
            threads,
            accept: Some(accept),
            pump: Some(pump),
        })
    }

    /// The address actually bound (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Requests currently waiting in the ingress queue (tests/monitoring;
    /// the live gauge is `akda_net_queue_depth{listen=..}`).
    pub fn queue_depth(&self) -> usize {
        self.ingress.state.lock().expect("ingress").queue.len()
    }

    /// The pump: moves admitted requests into the fleet, keeping at most
    /// `max_inflight` outstanding so the listener cannot flood the
    /// shared scoring pool. Reply closures route straight to the owning
    /// connection's writer channel.
    fn pump_loop(
        ingress: &Arc<Ingress>,
        client: &FleetClient,
        metrics: &Arc<NetMetrics>,
        max_inflight: usize,
    ) {
        loop {
            let pending = {
                let mut st = ingress.state.lock().expect("ingress");
                loop {
                    if st.stopped {
                        return;
                    }
                    if !st.queue.is_empty() && st.inflight < max_inflight {
                        break;
                    }
                    st = ingress.cv.wait(st).expect("ingress");
                }
                st.inflight += 1;
                let p = st.queue.pop_front().expect("non-empty ingress queue");
                metrics.queue_depth.set(st.queue.len() as f64);
                p
            };
            let Pending { req_id, model, features, trace, read_s, stamps, reply_tx, received_at } =
                pending;
            // net/queue ends here: the request leaves the ingress for
            // the fleet in the next statement
            let queue_s = received_at.elapsed().as_secs_f64();
            let ingress = ingress.clone();
            let metrics = metrics.clone();
            let ctx_model = model.clone();
            let ctx_stamps = stamps.clone();
            client.submit_traced(&model, features, Some(stamps), move |result| {
                let frame = match result {
                    Ok(scores) => Frame::ScoreResponse { req_id, scores, timings: Vec::new() },
                    Err(e) => {
                        let f = error_frame(req_id, &e);
                        if let Frame::Error { code, .. } = &f {
                            NetMetrics::error(*code);
                        }
                        f
                    }
                };
                let ctx = TraceCtx {
                    trace,
                    req_id,
                    model: ctx_model,
                    read_s,
                    queue_s,
                    stamps: ctx_stamps,
                    done_at: Instant::now(),
                };
                let _ = reply_tx.send(Outbound { frame, ctx: Some(Box::new(ctx)) });
                metrics.frame_seconds.record(received_at.elapsed().as_secs_f64());
                let mut st = ingress.state.lock().expect("ingress");
                st.inflight -= 1;
                ingress.cv.notify_all();
            });
        }
    }

    /// Admit one score request, shedding the OLDEST waiting request on
    /// overflow — under sustained overload every client keeps getting
    /// answers (typed, with a retry hint) and the freshest traffic wins.
    fn admit(
        ingress: &Ingress,
        metrics: &NetMetrics,
        queue_cap: usize,
        retry_after_ms: u32,
        pending: Pending,
    ) {
        let shed = {
            let mut st = ingress.state.lock().expect("ingress");
            if st.stopped {
                let frame = error_frame(pending.req_id, &FleetError::ServiceDown);
                let _ = pending.reply_tx.send(Outbound::plain(frame));
                return;
            }
            let shed = if st.queue.len() >= queue_cap { st.queue.pop_front() } else { None };
            st.queue.push_back(pending);
            metrics.queue_depth.set(st.queue.len() as f64);
            ingress.cv.notify_all();
            shed
        };
        if let Some(old) = shed {
            metrics.sheds_queue_full.inc();
            NetMetrics::error(ErrorCode::OverCapacity);
            // a shed is a terminal net/queue trace: the request dies in
            // the ingress, so its record has exactly two stages
            let queue_s = old.received_at.elapsed().as_secs_f64();
            metrics.stage_seconds[0].record(old.read_s);
            metrics.stage_seconds[1].record(queue_s);
            if let Some(sink) = &metrics.trace_sink {
                sink.offer(&TraceRecord {
                    trace: old.trace,
                    req_id: old.req_id,
                    model: old.model.clone(),
                    shed: true,
                    stages: vec![(STAGE_NET_READ, old.read_s), (STAGE_NET_QUEUE, queue_s)],
                });
            }
            let err = FleetError::OverCapacity { retry_after_ms };
            let _ = old.reply_tx.send(Outbound::plain(error_frame(old.req_id, &err)));
        }
    }

    /// Start the reader + writer thread pair of one connection.
    #[allow(clippy::too_many_arguments)]
    fn spawn_connection(
        conn_id: u64,
        stream: TcpStream,
        client: &FleetClient,
        ingress: &Arc<Ingress>,
        conns: &Arc<Mutex<HashMap<u64, TcpStream>>>,
        threads: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
        metrics: &Arc<NetMetrics>,
        queue_cap: usize,
        retry_after_ms: u32,
    ) {
        let _ = stream.set_nodelay(true);
        let Ok(write_half) = stream.try_clone() else { return };
        let Ok(registered) = stream.try_clone() else { return };
        conns.lock().expect("conns").insert(conn_id, registered);
        metrics.connections.add(1.0);

        let (reply_tx, reply_rx) = channel::<Outbound>();

        let writer = std::thread::Builder::new()
            .name(format!("akda-net-write-{conn_id}"))
            .spawn({
                let metrics = metrics.clone();
                move || Self::writer_loop(write_half, reply_rx, &metrics)
            })
            .expect("spawn net writer");

        let reader = std::thread::Builder::new()
            .name(format!("akda-net-read-{conn_id}"))
            .spawn({
                let client = client.clone();
                let ingress = ingress.clone();
                let conns = conns.clone();
                let metrics = metrics.clone();
                move || {
                    Self::reader_loop(
                        stream,
                        reply_tx,
                        &client,
                        &ingress,
                        &metrics,
                        queue_cap,
                        retry_after_ms,
                    );
                    conns.lock().expect("conns").remove(&conn_id);
                    metrics.connections.add(-1.0);
                }
            })
            .expect("spawn net reader");

        let mut ts = threads.lock().expect("threads");
        ts.push(writer);
        ts.push(reader);
    }

    /// Read frames until the peer closes, the transport dies, or a frame
    /// fails to decode. A malformed frame gets a typed `Error{BadFrame}`
    /// answer and closes this connection — once the framing is
    /// untrustworthy there is no safe way to resynchronise the stream —
    /// but never panics and never touches other connections.
    fn reader_loop(
        mut stream: TcpStream,
        reply_tx: Sender<Outbound>,
        client: &FleetClient,
        ingress: &Ingress,
        metrics: &NetMetrics,
        queue_cap: usize,
        retry_after_ms: u32,
    ) {
        loop {
            match wire::read_frame_timed(&mut stream) {
                Ok((frame, n, read_s)) => {
                    metrics.bytes_in.add(n as u64);
                    match frame {
                        Frame::ScoreRequest { req_id, model, features, trace } => {
                            metrics.frames_score.inc();
                            let pending = Pending {
                                req_id,
                                model,
                                features,
                                trace,
                                read_s,
                                stamps: Arc::new(TraceStamps::default()),
                                reply_tx: reply_tx.clone(),
                                received_at: Instant::now(),
                            };
                            Self::admit(ingress, metrics, queue_cap, retry_after_ms, pending);
                        }
                        Frame::ModelsRequest { req_id } => {
                            metrics.frames_models.inc();
                            let models = client
                                .roster()
                                .into_iter()
                                .map(|(name, dim, version)| WireModel {
                                    name,
                                    input_dim: dim as u32,
                                    version,
                                })
                                .collect();
                            let _ = reply_tx
                                .send(Outbound::plain(Frame::ModelsResponse { req_id, models }));
                        }
                        Frame::MetricsRequest { req_id } => {
                            // answered inline like the roster: a metrics
                            // scrape must work even when the score
                            // pipeline is saturated
                            metrics.frames_metrics.inc();
                            let payload = obs::global()
                                .snapshot()
                                .to_json(obs::unix_now())
                                .to_string()
                                .into_bytes();
                            let _ = reply_tx
                                .send(Outbound::plain(Frame::MetricsResponse { req_id, payload }));
                        }
                        // response-type frames have no business arriving
                        // at a server; protocol violation, close
                        other => {
                            NetMetrics::error(ErrorCode::BadFrame);
                            let _ = reply_tx.send(Outbound::plain(Frame::Error {
                                req_id: other.req_id(),
                                code: ErrorCode::BadFrame,
                                retry_after_ms: 0,
                                message: "unexpected frame type from a client".to_string(),
                            }));
                            break;
                        }
                    }
                }
                // clean close at a frame boundary, or mid-frame
                // disconnect — either way the peer is gone
                Err(ReadError::Eof) | Err(ReadError::Io(_)) => break,
                Err(ReadError::Malformed(why)) => {
                    NetMetrics::error(ErrorCode::BadFrame);
                    let _ = reply_tx.send(Outbound::plain(Frame::Error {
                        req_id: 0,
                        code: ErrorCode::BadFrame,
                        retry_after_ms: 0,
                        message: why,
                    }));
                    break;
                }
            }
        }
        // dropping reply_tx lets the writer drain outstanding replies
        // (in-flight fleet work may still complete) and then exit
    }

    /// Serialize every reply for one connection. Write failures mean the
    /// peer is gone: stop writing, let the channel drain into the void.
    ///
    /// This is also where a score request's trace completes: the echo's
    /// `net/write` necessarily ends *before* serialization (a frame
    /// cannot contain the duration of its own send), while the JSONL
    /// record and the stage histograms — written after the syscall —
    /// carry the full write duration.
    fn writer_loop(mut stream: TcpStream, rx: Receiver<Outbound>, metrics: &NetMetrics) {
        for Outbound { mut frame, ctx } in rx {
            if let Some(ctx) = &ctx {
                if ctx.trace != 0 {
                    if let Frame::ScoreResponse { timings, .. } = &mut frame {
                        let (batch_wait_s, score_s) = ctx.stamps.load();
                        let nanos = |s: f64| (s * 1e9) as u64;
                        *timings = vec![
                            (STAGE_NET_READ, nanos(ctx.read_s)),
                            (STAGE_NET_QUEUE, nanos(ctx.queue_s)),
                            (STAGE_BATCH_WAIT, nanos(batch_wait_s)),
                            (STAGE_POOL_SCORE, nanos(score_s)),
                            (STAGE_NET_WRITE, ctx.done_at.elapsed().as_nanos() as u64),
                        ];
                    }
                }
            }
            let scored = matches!(frame, Frame::ScoreResponse { .. });
            match wire::write_frame(&mut stream, &frame) {
                Ok(n) => metrics.bytes_out.add(n as u64),
                Err(_) => break,
            }
            if let Some(ctx) = ctx {
                let write_s = ctx.done_at.elapsed().as_secs_f64();
                let (batch_wait_s, score_s) = ctx.stamps.load();
                let stages = [ctx.read_s, ctx.queue_s, batch_wait_s, score_s, write_s];
                if scored {
                    // rejections never reached the fleet; keep their
                    // zero batch_wait/score out of the histograms
                    for (h, s) in metrics.stage_seconds.iter().zip(stages) {
                        h.record(s);
                    }
                }
                if let Some(sink) = &metrics.trace_sink {
                    let mut rec_stages =
                        vec![(STAGE_NET_READ, ctx.read_s), (STAGE_NET_QUEUE, ctx.queue_s)];
                    if scored {
                        rec_stages.push((STAGE_BATCH_WAIT, batch_wait_s));
                        rec_stages.push((STAGE_POOL_SCORE, score_s));
                    }
                    rec_stages.push((STAGE_NET_WRITE, write_s));
                    sink.offer(&TraceRecord {
                        trace: ctx.trace,
                        req_id: ctx.req_id,
                        model: ctx.model.clone(),
                        shed: false,
                        stages: rec_stages,
                    });
                }
            }
        }
        let _ = stream.shutdown(Shutdown::Both);
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // stop the pump; still-queued requests are abandoned (their
        // connections are about to be shut down anyway)
        {
            let mut st = self.ingress.state.lock().expect("ingress");
            st.stopped = true;
            st.queue.clear();
            self.ingress.cv.notify_all();
        }
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect_timeout(&self.local_addr, Duration::from_secs(1));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // shut every connection: readers see EOF/error and exit, writers
        // drain and exit once the last reply sender drops
        for (_, stream) in self.conns.lock().expect("conns").drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let threads: Vec<_> = std::mem::take(&mut *self.threads.lock().expect("threads"));
        for t in threads {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// The client
// ---------------------------------------------------------------------------

/// Outcome of one [`NetClient::score`] call: per-class scores, or the
/// server's typed rejection (which is an *answer*, not a transport
/// failure — transport failures are `Err` on the call itself).
#[derive(Debug, Clone, PartialEq)]
pub enum NetReply {
    Scores(Vec<f64>),
    Rejected { code: ErrorCode, retry_after_ms: u32, message: String },
}

/// A [`NetClient::score_traced`] outcome: the reply, the server-timing
/// echo `(stage id, nanoseconds)` from the traced response, and the
/// client-observed round-trip time.
#[derive(Debug, Clone)]
pub struct TracedReply {
    pub reply: NetReply,
    pub timings: Vec<(u8, u64)>,
    pub rtt: Duration,
}

/// Blocking `akda-wire/1` client over one TCP connection. Used by the
/// integration tests, `akda client`, and the `--connect` mode of the
/// `fleet_load` bench; doubles as the reference implementation of the
/// protocol's client side.
///
/// One call at a time is the simple mode ([`NetClient::score`] /
/// [`NetClient::models`]); the split [`NetClient::send_score`] +
/// [`NetClient::recv`] surface pipelines many requests on one
/// connection, matching replies back by `req_id`.
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect to a [`NetServer`]. `read_timeout` bounds every blocking
    /// receive, so a wedged server surfaces as an error, not a hang.
    pub fn connect(addr: impl ToSocketAddrs, read_timeout: Duration) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connecting to akda wire server")?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(read_timeout))
            .context("setting wire read timeout")?;
        Ok(NetClient { stream, next_id: 1 })
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one score request without waiting; returns its `req_id` for
    /// matching the eventual reply (pipelining surface).
    pub fn send_score(&mut self, model: &str, features: &[f64]) -> Result<u64> {
        self.send_score_traced(model, features, 0)
    }

    /// [`NetClient::send_score`] carrying a trace id (0 = untraced; mint
    /// nonzero ids with [`TraceIdGen`](crate::obs::trace::TraceIdGen)).
    pub fn send_score_traced(&mut self, model: &str, features: &[f64], trace: u64) -> Result<u64> {
        let req_id = self.fresh_id();
        let frame = Frame::ScoreRequest {
            req_id,
            model: model.to_string(),
            features: features.to_vec(),
            trace,
        };
        wire::write_frame(&mut self.stream, &frame).context("sending score request")?;
        Ok(req_id)
    }

    /// Receive the next frame from the server (any type, any `req_id`).
    pub fn recv(&mut self) -> Result<Frame> {
        match wire::read_frame(&mut self.stream) {
            Ok((frame, _)) => Ok(frame),
            Err(e) => Err(anyhow::anyhow!("receiving wire frame: {e}")),
        }
    }

    /// Score `features` against tenant `model`, blocking for the answer.
    pub fn score(&mut self, model: &str, features: &[f64]) -> Result<NetReply> {
        Ok(self.score_traced(model, features, 0)?.reply)
    }

    /// Score with a trace id, blocking; returns the reply plus the
    /// server-timing echo (empty for untraced requests and rejections)
    /// and the client-observed round-trip time. The sum of the echoed
    /// stage durations is ≤ `rtt` — the stages are sequential,
    /// non-overlapping segments of the server-side residency.
    pub fn score_traced(
        &mut self,
        model: &str,
        features: &[f64],
        trace: u64,
    ) -> Result<TracedReply> {
        let t0 = Instant::now();
        let req_id = self.send_score_traced(model, features, trace)?;
        loop {
            match self.recv()? {
                Frame::ScoreResponse { req_id: id, scores, timings } if id == req_id => {
                    return Ok(TracedReply {
                        reply: NetReply::Scores(scores),
                        timings,
                        rtt: t0.elapsed(),
                    });
                }
                Frame::Error { req_id: id, code, retry_after_ms, message }
                    if id == req_id || id == 0 =>
                {
                    return Ok(TracedReply {
                        reply: NetReply::Rejected { code, retry_after_ms, message },
                        timings: Vec::new(),
                        rtt: t0.elapsed(),
                    });
                }
                // a stale reply to an earlier pipelined request — skip
                _ => continue,
            }
        }
    }

    /// Scrape the server's `akda-metrics/1` JSON snapshot over the
    /// existing socket (no separate HTTP port) — `akda client --metrics`.
    pub fn metrics(&mut self) -> Result<String> {
        let req_id = self.fresh_id();
        wire::write_frame(&mut self.stream, &Frame::MetricsRequest { req_id })
            .context("sending metrics request")?;
        loop {
            match self.recv()? {
                Frame::MetricsResponse { req_id: id, payload } if id == req_id => {
                    return String::from_utf8(payload).context("metrics payload is not UTF-8");
                }
                Frame::Error { req_id: id, code, message, .. } if id == req_id => {
                    anyhow::bail!("metrics request rejected: {code}: {message}");
                }
                _ => continue,
            }
        }
    }

    /// The server's live tenant roster (name, input dim, served version).
    pub fn models(&mut self) -> Result<Vec<WireModel>> {
        let req_id = self.fresh_id();
        wire::write_frame(&mut self.stream, &Frame::ModelsRequest { req_id })
            .context("sending models request")?;
        loop {
            match self.recv()? {
                Frame::ModelsResponse { req_id: id, models } if id == req_id => {
                    return Ok(models);
                }
                Frame::Error { req_id: id, code, message, .. } if id == req_id => {
                    anyhow::bail!("models request rejected: {code}: {message}");
                }
                _ => continue,
            }
        }
    }

    /// Write raw bytes onto the connection — the torture tests' and
    /// `akda client --probe`'s way of sending garbage past the encoder.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        use std::io::Write;
        self.stream.write_all(bytes).context("sending raw bytes")?;
        Ok(())
    }

    /// Half-close the sending direction (the server sees a clean EOF).
    pub fn shutdown_write(&mut self) -> Result<()> {
        self.stream.shutdown(Shutdown::Write).context("shutting down write half")?;
        Ok(())
    }
}
