//! `akda-wire/1` — the length-prefixed binary framing the network edge
//! (`coordinator::net`) speaks over TCP.
//!
//! Every frame is a fixed 18-byte header followed by a typed body:
//!
//! ```text
//!  offset  size  field
//!  0       4     magic  b"AKDW"
//!  4       1     protocol version (1)
//!  5       1     frame type (see [`Frame`])
//!  6       4     body length, u32 LE (<= MAX_BODY_LEN)
//!  10      8     FNV-1a 64 checksum, u64 LE, over bytes 0..10 ++ body
//!  18      len   body
//! ```
//!
//! The checksum covers the *entire* frame except itself — header fields
//! included — so any byte mutation anywhere (magic, type, a length made
//! shorter or longer, one bit of one f64) is rejected with a typed
//! [`DecodeError`], never decoded into a plausible-but-wrong frame. This
//! mirrors the `.akda` artifact format's stance: corruption is a checksum
//! error, not garbage data (same [`fnv1a64`] implementation).
//!
//! All integers and f64s are little-endian. Strings are u16-length-
//! prefixed UTF-8. The codec is pure (`encode`/`decode` over byte
//! slices); [`write_frame`]/[`read_frame`] are the blocking-I/O wrappers
//! the server and [`NetClient`](crate::coordinator::net::NetClient) use.
//!
//! # Tracing extension (backward compatible)
//!
//! [`Frame::ScoreRequest`] may carry a trailing 64-bit trace id and
//! [`Frame::ScoreResponse`] a trailing per-stage server-timing echo
//! (see `obs::trace`). Both are encoded **only when present** (trace id
//! nonzero / timings non-empty), so an untraced frame is byte-identical
//! to the pre-extension wire format, and a pre-extension frame (no
//! trailing field) still decodes — the decoder treats a missing tail as
//! "untraced" rather than an error.
//!
//! ```
//! use akda::coordinator::wire::{decode, encode, Frame};
//!
//! let frame = Frame::ScoreRequest {
//!     req_id: 7,
//!     model: "eth80".into(),
//!     features: vec![1.0, -2.5],
//!     trace: 0,
//! };
//! let bytes = encode(&frame);
//! let (back, consumed) = decode(&bytes).unwrap();
//! assert_eq!(back, frame);
//! assert_eq!(consumed, bytes.len());
//! // flip one bit anywhere: the frame is rejected, not misread
//! let mut bad = bytes.clone();
//! bad[20] ^= 0x01;
//! assert!(decode(&bad).is_err());
//! ```

use std::io::{Read, Write};

use crate::model::artifact::fnv1a64;

/// Frame magic: the first four bytes of every `akda-wire/1` frame.
pub const MAGIC: [u8; 4] = *b"AKDW";
/// Protocol version carried in byte 4 of the header.
pub const VERSION: u8 = 1;
/// Fixed header size (magic + version + type + body len + checksum).
pub const HEADER_LEN: usize = 18;
/// Hard cap on a frame body. A length prefix above this is a protocol
/// violation answered (and rejected) immediately — a client cannot make
/// the server buffer unbounded garbage by lying about the length.
pub const MAX_BODY_LEN: u32 = 1 << 22; // 4 MiB

const TYPE_SCORE_REQUEST: u8 = 1;
const TYPE_SCORE_RESPONSE: u8 = 2;
const TYPE_ERROR: u8 = 3;
const TYPE_MODELS_REQUEST: u8 = 4;
const TYPE_MODELS_RESPONSE: u8 = 5;
const TYPE_METRICS_REQUEST: u8 = 6;
const TYPE_METRICS_RESPONSE: u8 = 7;

/// Typed error codes carried in [`Frame::Error`] — the wire image of
/// [`FleetError`](crate::coordinator::FleetError) plus the two codes only
/// the network edge can produce (`OverCapacity`, `BadFrame`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// No tenant with the requested model id.
    UnknownModel,
    /// Feature vector width does not match the tenant's input dim.
    WrongDim,
    /// The fleet behind the listener is shutting down.
    ServiceDown,
    /// The ingress queue shed this request; retry after the hinted delay.
    OverCapacity,
    /// The bytes received were not a valid `akda-wire/1` frame.
    BadFrame,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        match self {
            ErrorCode::UnknownModel => 1,
            ErrorCode::WrongDim => 2,
            ErrorCode::ServiceDown => 3,
            ErrorCode::OverCapacity => 4,
            ErrorCode::BadFrame => 5,
        }
    }

    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        Some(match b {
            1 => ErrorCode::UnknownModel,
            2 => ErrorCode::WrongDim,
            3 => ErrorCode::ServiceDown,
            4 => ErrorCode::OverCapacity,
            5 => ErrorCode::BadFrame,
            _ => return None,
        })
    }

    /// Stable lower-snake name, used as the `code` metrics label.
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::UnknownModel => "unknown_model",
            ErrorCode::WrongDim => "wrong_dim",
            ErrorCode::ServiceDown => "service_down",
            ErrorCode::OverCapacity => "over_capacity",
            ErrorCode::BadFrame => "bad_frame",
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One served tenant as reported by [`Frame::ModelsResponse`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireModel {
    pub name: String,
    pub input_dim: u32,
    pub version: u32,
}

/// One `akda-wire/1` frame. Requests carry a client-chosen `req_id`
/// echoed verbatim in the matching response, so one connection can keep
/// many requests in flight (the fleet batches per tenant, so replies may
/// complete out of order).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Score `features` against tenant `model`. `trace` is the 64-bit
    /// distributed-tracing id minted by the client (`obs::trace`); 0
    /// means "untraced" and is the only value that elides the field on
    /// the wire, keeping untraced frames byte-identical to the
    /// pre-extension format.
    ScoreRequest { req_id: u64, model: String, features: Vec<f64>, trace: u64 },
    /// Per-class scores for the matching request. `timings` is the
    /// optional server-timing echo — `(stage id, nanoseconds)` pairs
    /// (see `obs::trace` stage constants) — populated only for traced
    /// requests; empty timings are elided on the wire.
    ScoreResponse { req_id: u64, scores: Vec<f64>, timings: Vec<(u8, u64)> },
    /// Typed failure for the matching request (`req_id` 0 when the
    /// request could not even be parsed). `retry_after_ms` is nonzero
    /// only for [`ErrorCode::OverCapacity`].
    Error { req_id: u64, code: ErrorCode, retry_after_ms: u32, message: String },
    /// Ask for the served tenant roster.
    ModelsRequest { req_id: u64 },
    /// The roster: name, input dim, and served registry version per
    /// tenant — how a client observes hot swaps and onboarding over TCP.
    ModelsResponse { req_id: u64, models: Vec<WireModel> },
    /// Ask for the server's current `akda-metrics/1` snapshot — remote
    /// scraping over the scoring socket, no separate HTTP port.
    MetricsRequest { req_id: u64 },
    /// The snapshot: UTF-8 `akda-metrics/1` JSON bytes (u32-length-
    /// prefixed — a large registry can exceed the u16 string cap).
    MetricsResponse { req_id: u64, payload: Vec<u8> },
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            Frame::ScoreRequest { .. } => TYPE_SCORE_REQUEST,
            Frame::ScoreResponse { .. } => TYPE_SCORE_RESPONSE,
            Frame::Error { .. } => TYPE_ERROR,
            Frame::ModelsRequest { .. } => TYPE_MODELS_REQUEST,
            Frame::ModelsResponse { .. } => TYPE_MODELS_RESPONSE,
            Frame::MetricsRequest { .. } => TYPE_METRICS_REQUEST,
            Frame::MetricsResponse { .. } => TYPE_METRICS_RESPONSE,
        }
    }

    /// The request id this frame carries (every frame type has one).
    pub fn req_id(&self) -> u64 {
        match self {
            Frame::ScoreRequest { req_id, .. }
            | Frame::ScoreResponse { req_id, .. }
            | Frame::Error { req_id, .. }
            | Frame::ModelsRequest { req_id }
            | Frame::ModelsResponse { req_id, .. }
            | Frame::MetricsRequest { req_id }
            | Frame::MetricsResponse { req_id, .. } => *req_id,
        }
    }
}

/// Why a byte sequence failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Not enough bytes for a whole frame yet — on a live stream this
    /// means "read more", on a fixed buffer it means "truncated".
    /// `need` is the total frame size once the header is readable.
    Incomplete { need: usize },
    /// The bytes can never be a valid frame: bad magic, unknown version
    /// or type, oversized length, checksum mismatch, malformed body.
    Malformed(String),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Incomplete { need } => {
                write!(f, "incomplete frame (need {need} bytes)")
            }
            DecodeError::Malformed(why) => write!(f, "malformed frame: {why}"),
        }
    }
}

impl std::error::Error for DecodeError {}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    debug_assert!(bytes.len() <= u16::MAX as usize, "string too long for wire");
    out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn encode_body(frame: &Frame) -> Vec<u8> {
    let mut b = Vec::new();
    match frame {
        Frame::ScoreRequest { req_id, model, features, trace } => {
            b.extend_from_slice(&req_id.to_le_bytes());
            put_str(&mut b, model);
            b.extend_from_slice(&(features.len() as u32).to_le_bytes());
            for v in features {
                b.extend_from_slice(&v.to_le_bytes());
            }
            // trailing trace id, elided when 0: untraced frames stay
            // byte-identical to the pre-extension format
            if *trace != 0 {
                b.extend_from_slice(&trace.to_le_bytes());
            }
        }
        Frame::ScoreResponse { req_id, scores, timings } => {
            b.extend_from_slice(&req_id.to_le_bytes());
            b.extend_from_slice(&(scores.len() as u32).to_le_bytes());
            for v in scores {
                b.extend_from_slice(&v.to_le_bytes());
            }
            // trailing server-timing echo, elided when empty
            if !timings.is_empty() {
                debug_assert!(timings.len() <= u8::MAX as usize, "too many stages");
                b.push(timings.len() as u8);
                for (stage, nanos) in timings {
                    b.push(*stage);
                    b.extend_from_slice(&nanos.to_le_bytes());
                }
            }
        }
        Frame::Error { req_id, code, retry_after_ms, message } => {
            b.extend_from_slice(&req_id.to_le_bytes());
            b.push(code.as_u8());
            b.extend_from_slice(&retry_after_ms.to_le_bytes());
            put_str(&mut b, message);
        }
        Frame::ModelsRequest { req_id } => {
            b.extend_from_slice(&req_id.to_le_bytes());
        }
        Frame::ModelsResponse { req_id, models } => {
            b.extend_from_slice(&req_id.to_le_bytes());
            b.extend_from_slice(&(models.len() as u32).to_le_bytes());
            for m in models {
                put_str(&mut b, &m.name);
                b.extend_from_slice(&m.input_dim.to_le_bytes());
                b.extend_from_slice(&m.version.to_le_bytes());
            }
        }
        Frame::MetricsRequest { req_id } => {
            b.extend_from_slice(&req_id.to_le_bytes());
        }
        Frame::MetricsResponse { req_id, payload } => {
            b.extend_from_slice(&req_id.to_le_bytes());
            b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            b.extend_from_slice(payload);
        }
    }
    b
}

/// Encode one frame to its wire bytes (header + checksummed body).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let body = encode_body(frame);
    debug_assert!(body.len() <= MAX_BODY_LEN as usize, "frame body over the wire cap");
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(frame.type_byte());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    // checksum over everything so far (magic, version, type, len) + body
    let mut sum = fnv1a64(&out);
    sum = fnv1a64_concat(sum, &body);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Continue an FNV-1a 64 hash over more bytes (the artifact module's
/// `fnv1a64` hashes one slice; frames hash header and body separately).
fn fnv1a64_concat(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Cursor over a frame body that fails loudly on any inconsistency.
struct Body<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Body<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeError::Malformed(format!(
                "body ends early: wanted {n} bytes at offset {}, body is {} bytes",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>, DecodeError> {
        let bytes = self.take(n.checked_mul(8).ok_or_else(|| {
            DecodeError::Malformed("f64 count overflows".to_string())
        })?)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn string(&mut self) -> Result<String, DecodeError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DecodeError::Malformed("string is not UTF-8".to_string()))
    }

    /// Bytes left after the cursor — how the optional trailing tracing
    /// fields are detected without breaking pre-extension frames.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(self) -> Result<(), DecodeError> {
        if self.pos != self.buf.len() {
            return Err(DecodeError::Malformed(format!(
                "{} trailing bytes after the body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_body(frame_type: u8, body: &[u8]) -> Result<Frame, DecodeError> {
    let mut b = Body { buf: body, pos: 0 };
    let frame = match frame_type {
        TYPE_SCORE_REQUEST => {
            let req_id = b.u64()?;
            let model = b.string()?;
            let n = b.u32()? as usize;
            let features = b.f64s(n)?;
            // optional trailing trace id: a pre-extension frame ends
            // here (trace 0); anything other than exactly 8 remaining
            // bytes falls through to finish() and is rejected
            let trace = if b.remaining() == 8 {
                match b.u64()? {
                    // present-but-zero is non-canonical: the encoder
                    // elides a zero id, so re-encode would change bytes
                    0 => {
                        return Err(DecodeError::Malformed(
                            "zero trace id must be elided".to_string(),
                        ))
                    }
                    t => t,
                }
            } else {
                0
            };
            Frame::ScoreRequest { req_id, model, features, trace }
        }
        TYPE_SCORE_RESPONSE => {
            let req_id = b.u64()?;
            let n = b.u32()? as usize;
            let scores = b.f64s(n)?;
            // optional trailing server-timing echo (count + 9B entries)
            let mut timings = Vec::new();
            if b.remaining() > 0 {
                let k = b.u8()? as usize;
                if k == 0 {
                    // same canonicality rule as the trace id
                    return Err(DecodeError::Malformed(
                        "empty timing echo must be elided".to_string(),
                    ));
                }
                for _ in 0..k {
                    let stage = b.u8()?;
                    timings.push((stage, b.u64()?));
                }
            }
            Frame::ScoreResponse { req_id, scores, timings }
        }
        TYPE_ERROR => {
            let req_id = b.u64()?;
            let code = b.u8()?;
            let code = ErrorCode::from_u8(code)
                .ok_or_else(|| DecodeError::Malformed(format!("unknown error code {code}")))?;
            let retry_after_ms = b.u32()?;
            Frame::Error { req_id, code, retry_after_ms, message: b.string()? }
        }
        TYPE_MODELS_REQUEST => Frame::ModelsRequest { req_id: b.u64()? },
        TYPE_MODELS_RESPONSE => {
            let req_id = b.u64()?;
            let n = b.u32()? as usize;
            let mut models = Vec::new();
            for _ in 0..n {
                let name = b.string()?;
                let input_dim = b.u32()?;
                let version = b.u32()?;
                models.push(WireModel { name, input_dim, version });
            }
            Frame::ModelsResponse { req_id, models }
        }
        TYPE_METRICS_REQUEST => Frame::MetricsRequest { req_id: b.u64()? },
        TYPE_METRICS_RESPONSE => {
            let req_id = b.u64()?;
            let n = b.u32()? as usize;
            Frame::MetricsResponse { req_id, payload: b.take(n)?.to_vec() }
        }
        other => return Err(DecodeError::Malformed(format!("unknown frame type {other}"))),
    };
    b.finish()?;
    Ok(frame)
}

/// Decode exactly one frame from the front of `buf`. Returns the frame
/// and the bytes consumed (trailing bytes belong to the next frame).
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), DecodeError> {
    if buf.len() < HEADER_LEN {
        return Err(DecodeError::Incomplete { need: HEADER_LEN });
    }
    if buf[0..4] != MAGIC {
        return Err(DecodeError::Malformed(format!(
            "bad magic {:02x?} (expected {:02x?} — not an akda-wire stream)",
            &buf[0..4],
            MAGIC
        )));
    }
    if buf[4] != VERSION {
        return Err(DecodeError::Malformed(format!(
            "unsupported wire version {} (this side speaks {VERSION})",
            buf[4]
        )));
    }
    let frame_type = buf[5];
    let body_len = u32::from_le_bytes(buf[6..10].try_into().unwrap());
    if body_len > MAX_BODY_LEN {
        return Err(DecodeError::Malformed(format!(
            "oversized frame: body claims {body_len} bytes (cap {MAX_BODY_LEN})"
        )));
    }
    let total = HEADER_LEN + body_len as usize;
    if buf.len() < total {
        return Err(DecodeError::Incomplete { need: total });
    }
    let stored = u64::from_le_bytes(buf[10..18].try_into().unwrap());
    let mut sum = fnv1a64(&buf[0..10]);
    sum = fnv1a64_concat(sum, &buf[HEADER_LEN..total]);
    if stored != sum {
        return Err(DecodeError::Malformed(format!(
            "checksum mismatch: stored {stored:#018x}, computed {sum:#018x}"
        )));
    }
    let frame = decode_body(frame_type, &buf[HEADER_LEN..total])?;
    Ok((frame, total))
}

// ---------------------------------------------------------------------------
// Blocking I/O wrappers
// ---------------------------------------------------------------------------

/// Why [`read_frame`] stopped.
#[derive(Debug)]
pub enum ReadError {
    /// The peer closed the connection cleanly at a frame boundary.
    Eof,
    /// The connection died mid-frame (or another transport error).
    Io(std::io::Error),
    /// The header/body arrived but is not a valid frame.
    Malformed(String),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Eof => write!(f, "connection closed"),
            ReadError::Io(e) => write!(f, "transport error: {e}"),
            ReadError::Malformed(why) => write!(f, "malformed frame: {why}"),
        }
    }
}

impl std::error::Error for ReadError {}

/// Write one frame; returns the bytes put on the wire.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<usize> {
    let bytes = encode(frame);
    w.write_all(&bytes)?;
    Ok(bytes.len())
}

/// Read exactly one frame. EOF before the first header byte is a clean
/// close ([`ReadError::Eof`]); EOF anywhere later is a mid-frame
/// disconnect ([`ReadError::Io`]). Returns the frame and its wire size.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, usize), ReadError> {
    read_frame_timed(r).map(|(frame, n, _)| (frame, n))
}

/// [`read_frame`] plus the transfer time: seconds from the first header
/// byte arriving to the frame fully read and decoded — the `net/read`
/// trace stage. The blocking wait *before* the first byte (connection
/// idle between requests) is deliberately excluded, so the stage
/// measures wire transfer + decode, not client think time.
pub fn read_frame_timed(r: &mut impl Read) -> Result<(Frame, usize, f64), ReadError> {
    let mut header = [0u8; HEADER_LEN];
    // first byte separately: EOF here is a clean close, not an error
    match r.read(&mut header[..1]) {
        Ok(0) => return Err(ReadError::Eof),
        Ok(_) => {}
        Err(e) => return Err(ReadError::Io(e)),
    }
    let t0 = std::time::Instant::now();
    r.read_exact(&mut header[1..]).map_err(ReadError::Io)?;
    // validate the header before trusting the length prefix
    let body_len = match decode(&header) {
        // header alone never completes a frame with a body; `need` is the
        // full frame size, so the body is need - HEADER_LEN
        Err(DecodeError::Incomplete { need }) => need - HEADER_LEN,
        Err(DecodeError::Malformed(why)) => return Err(ReadError::Malformed(why)),
        // a body-less frame could in principle complete here, but every
        // frame type carries at least a req_id — treat it as malformed
        Ok(_) => return Err(ReadError::Malformed("empty frame body".to_string())),
    };
    let mut bytes = Vec::with_capacity(HEADER_LEN + body_len);
    bytes.extend_from_slice(&header);
    bytes.resize(HEADER_LEN + body_len, 0);
    r.read_exact(&mut bytes[HEADER_LEN..]).map_err(ReadError::Io)?;
    match decode(&bytes) {
        Ok((frame, n)) => Ok((frame, n, t0.elapsed().as_secs_f64())),
        Err(e) => Err(ReadError::Malformed(e.to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames() -> Vec<Frame> {
        vec![
            Frame::ScoreRequest {
                req_id: 1,
                model: "eth80".into(),
                features: vec![1.5, -2.0],
                trace: 0,
            },
            Frame::ScoreRequest {
                req_id: 2,
                model: String::new(),
                features: vec![],
                trace: 0xDEAD_BEEF_0000_0001,
            },
            Frame::ScoreResponse { req_id: 3, scores: vec![0.25; 7], timings: vec![] },
            Frame::ScoreResponse {
                req_id: 9,
                scores: vec![1.0],
                timings: vec![(1, 1_000), (4, 750_000), (5, 12)],
            },
            Frame::Error {
                req_id: 4,
                code: ErrorCode::OverCapacity,
                retry_after_ms: 50,
                message: "shed".into(),
            },
            Frame::ModelsRequest { req_id: 5 },
            Frame::ModelsResponse {
                req_id: 6,
                models: vec![WireModel { name: "aa".into(), input_dim: 6, version: 2 }],
            },
            Frame::MetricsRequest { req_id: 7 },
            Frame::MetricsResponse { req_id: 8, payload: br#"{"schema":"x"}"#.to_vec() },
        ]
    }

    #[test]
    fn every_frame_type_round_trips() {
        for frame in frames() {
            let bytes = encode(&frame);
            let (back, n) = decode(&bytes).unwrap();
            assert_eq!(back, frame);
            assert_eq!(n, bytes.len());
        }
    }

    #[test]
    fn streamed_frames_decode_one_at_a_time() {
        let all: Vec<u8> = frames().iter().flat_map(encode).collect();
        let mut pos = 0;
        for frame in frames() {
            let (back, n) = decode(&all[pos..]).unwrap();
            assert_eq!(back, frame);
            pos += n;
        }
        assert_eq!(pos, all.len());
    }

    #[test]
    fn every_prefix_is_incomplete_never_ok() {
        let bytes = encode(&frames()[0]);
        for cut in 0..bytes.len() {
            match decode(&bytes[..cut]) {
                Err(DecodeError::Incomplete { .. }) => {}
                other => panic!("prefix of {cut} bytes must be Incomplete, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_version_type_len_and_code_are_malformed() {
        let good = encode(&frames()[0]);
        let mutate = |at: usize, to: u8| {
            let mut b = good.clone();
            b[at] = to;
            decode(&b)
        };
        assert!(matches!(mutate(0, b'X'), Err(DecodeError::Malformed(_))), "magic");
        assert!(matches!(mutate(4, 9), Err(DecodeError::Malformed(_))), "version");
        assert!(matches!(mutate(5, 99), Err(DecodeError::Malformed(_))), "type");
        // oversized length prefix: rejected before any body is wanted
        let mut big = good.clone();
        big[6..10].copy_from_slice(&(MAX_BODY_LEN + 1).to_le_bytes());
        match decode(&big) {
            Err(DecodeError::Malformed(why)) => assert!(why.contains("oversized"), "{why}"),
            other => panic!("oversized len must be Malformed, got {other:?}"),
        }
    }

    #[test]
    fn read_frame_distinguishes_clean_close_from_mid_frame_disconnect() {
        let bytes = encode(&frames()[0]);
        // clean close: empty stream
        let mut empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut empty), Err(ReadError::Eof)));
        // mid-frame disconnect: stream ends inside the body
        let mut cut: &[u8] = &bytes[..bytes.len() - 3];
        assert!(matches!(read_frame(&mut cut), Err(ReadError::Io(_))));
        // whole frame: fine
        let mut whole: &[u8] = &bytes;
        let (frame, n) = read_frame(&mut whole).unwrap();
        assert_eq!(frame, frames()[0]);
        assert_eq!(n, bytes.len());
    }

    #[test]
    fn untraced_request_is_byte_identical_to_pre_extension_format() {
        // hand-build the pre-extension (PR 7) body layout: req_id +
        // u16-prefixed model + f64 count + raw f64s, no trailing field
        let (req_id, model, features) = (42u64, "ten", vec![0.5, -1.25, 3.0]);
        let mut body = Vec::new();
        body.extend_from_slice(&req_id.to_le_bytes());
        body.extend_from_slice(&(model.len() as u16).to_le_bytes());
        body.extend_from_slice(model.as_bytes());
        body.extend_from_slice(&(features.len() as u32).to_le_bytes());
        for v in &features {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let mut old = Vec::new();
        old.extend_from_slice(&MAGIC);
        old.push(VERSION);
        old.push(TYPE_SCORE_REQUEST);
        old.extend_from_slice(&(body.len() as u32).to_le_bytes());
        let mut sum = fnv1a64(&old);
        sum = fnv1a64_concat(sum, &body);
        old.extend_from_slice(&sum.to_le_bytes());
        old.extend_from_slice(&body);

        // the new encoder reproduces those exact bytes for trace = 0 ...
        let frame =
            Frame::ScoreRequest { req_id, model: model.into(), features, trace: 0 };
        assert_eq!(encode(&frame), old, "untraced encoding must not change the wire");
        // ... and the new decoder accepts the old bytes as trace = 0
        let (back, n) = decode(&old).unwrap();
        assert_eq!(back, frame);
        assert_eq!(n, old.len());
    }

    #[test]
    fn traced_request_costs_exactly_eight_bytes() {
        let untraced = Frame::ScoreRequest {
            req_id: 1,
            model: "m".into(),
            features: vec![1.0],
            trace: 0,
        };
        let traced = Frame::ScoreRequest {
            req_id: 1,
            model: "m".into(),
            features: vec![1.0],
            trace: u64::MAX,
        };
        assert_eq!(encode(&traced).len(), encode(&untraced).len() + 8);
        let (back, _) = decode(&encode(&traced)).unwrap();
        assert_eq!(back, traced, "trace id must survive bit-for-bit");
    }

    #[test]
    fn non_canonical_trailing_fields_are_rejected() {
        // a ScoreRequest whose trailing trace id is literally 0 must be
        // rejected: re-encoding would elide it and change the bytes
        let base = Frame::ScoreRequest {
            req_id: 1,
            model: "m".into(),
            features: vec![2.0],
            trace: 7,
        };
        let mut bytes = encode(&base);
        let len = bytes.len();
        bytes[len - 8..].fill(0); // zero the trace id in place
        // fix the checksum so only the canonicality rule can reject it
        let body_len = len - HEADER_LEN;
        let mut sum = fnv1a64(&bytes[0..10]);
        sum = fnv1a64_concat(sum, &bytes[HEADER_LEN..HEADER_LEN + body_len]);
        bytes[10..18].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(DecodeError::Malformed(_))));
    }

    #[test]
    fn error_code_round_trips_and_names_are_stable() {
        for code in [
            ErrorCode::UnknownModel,
            ErrorCode::WrongDim,
            ErrorCode::ServiceDown,
            ErrorCode::OverCapacity,
            ErrorCode::BadFrame,
        ] {
            assert_eq!(ErrorCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::OverCapacity.name(), "over_capacity");
    }
}
