//! Run configuration: hyper-parameter grids (the paper's CV search space,
//! Sec. 6.3.1) and execution knobs, loadable from a simple `key = value`
//! file so experiments are reproducible from checked-in configs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct EvalConfig {
    /// RBF bandwidth grid (paper: {0.01,0.1,0.6} ∪ {1,1.5,…,7}).
    pub rho_grid: Vec<f64>,
    /// SVM penalty grid ς (paper: {0.1,1,10,100}).
    pub c_grid: Vec<f64>,
    /// Subclass count grid H (paper: {2,…,5}).
    pub h_grid: Vec<usize>,
    /// CV folds (paper: 3).
    pub cv_folds: usize,
    /// Fraction of the training set used as the learning split per fold
    /// (paper: 30% learn / 70% validate).
    pub cv_learn_frac: f64,
    /// Worker threads for per-class jobs.
    pub workers: usize,
    /// Kernel ridge ε (paper: 1e-3).
    pub eps: f64,
    /// Landmark / random-feature budget m for the approximate methods
    /// (akda-nystrom / akda-rff) — used both during CV and the final fit.
    /// Setting it (config `landmarks = M` or CLI `--landmarks M`) also
    /// pins `m_grid` to `[M]` so CV cannot override the explicit budget;
    /// an explicit `m_grid` key wins regardless of line order (keys are
    /// processed in sorted order, `landmarks` before `m_grid`).
    pub landmarks: usize,
    /// CV grid over the landmark budget m, searched like rho/C/H by
    /// `select_hyper` for the approximate methods only. Empty = don't
    /// search, always use `landmarks`.
    pub m_grid: Vec<usize>,
    /// Tile height B for the out-of-core streaming path: when set, the
    /// approximate methods accumulate ΦᵀΦ and the class sums tile by tile
    /// (`da::akda_stream`) instead of materializing the N×m Φ. `None`
    /// (default) = in-memory.
    pub stream_block: Option<usize>,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            // a compressed version of the paper's grid — full grid via config
            rho_grid: vec![0.01, 0.1, 0.6, 1.0, 3.0],
            c_grid: vec![0.1, 1.0, 10.0],
            h_grid: vec![2, 3],
            cv_folds: 3,
            cv_learn_frac: 0.3,
            workers: crate::util::threads::available(),
            eps: 1e-3,
            landmarks: crate::approx::DEFAULT_BUDGET,
            // compressed like rho/C/H: bracket the default budget
            m_grid: vec![32, crate::approx::DEFAULT_BUDGET, 128],
            stream_block: None,
            seed: 2024,
        }
    }
}

impl EvalConfig {
    /// The paper's full CV grid (Sec. 6.3.1).
    pub fn paper_grid() -> Self {
        let mut rho = vec![0.01, 0.1, 0.6];
        let mut v = 1.0;
        while v <= 7.0 + 1e-9 {
            rho.push(v);
            v += 0.5;
        }
        EvalConfig {
            rho_grid: rho,
            c_grid: vec![0.1, 1.0, 10.0, 100.0],
            h_grid: vec![2, 3, 4, 5],
            ..Default::default()
        }
    }

    /// Parse `key = value` lines; unknown keys are rejected. Lists are
    /// comma-separated.
    pub fn from_str_cfg(text: &str) -> Result<Self> {
        let mut cfg = EvalConfig::default();
        let mut map = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        let parse_f64s = |s: &str| -> Result<Vec<f64>> {
            s.split(',').map(|p| Ok(p.trim().parse::<f64>()?)).collect()
        };
        for (k, v) in map {
            match k.as_str() {
                "rho_grid" => cfg.rho_grid = parse_f64s(&v)?,
                "c_grid" => cfg.c_grid = parse_f64s(&v)?,
                "h_grid" => {
                    cfg.h_grid = v
                        .split(',')
                        .map(|p| Ok(p.trim().parse::<usize>()?))
                        .collect::<Result<_>>()?
                }
                "cv_folds" => cfg.cv_folds = v.parse()?,
                "cv_learn_frac" => cfg.cv_learn_frac = v.parse()?,
                "workers" => cfg.workers = v.parse()?,
                "eps" => cfg.eps = v.parse()?,
                "landmarks" => {
                    cfg.landmarks = v.parse()?;
                    // an explicit budget pins the CV grid; a later (sorted
                    // after) explicit m_grid key overrides this
                    cfg.m_grid = vec![cfg.landmarks];
                }
                "m_grid" => {
                    cfg.m_grid = v
                        .split(',')
                        .map(|p| Ok(p.trim().parse::<usize>()?))
                        .collect::<Result<_>>()?
                }
                "stream_block" => cfg.stream_block = Some(v.parse()?),
                "seed" => cfg.seed = v.parse()?,
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        anyhow::ensure!(!cfg.rho_grid.is_empty() && !cfg.c_grid.is_empty());
        anyhow::ensure!(cfg.landmarks >= 1, "landmarks must be >= 1");
        anyhow::ensure!(
            cfg.m_grid.iter().all(|&m| m >= 1),
            "m_grid entries must be >= 1"
        );
        anyhow::ensure!(
            !matches!(cfg.stream_block, Some(0)),
            "stream_block must be >= 1"
        );
        anyhow::ensure!(cfg.cv_folds >= 2, "cv_folds must be >= 2");
        anyhow::ensure!(
            cfg.cv_learn_frac > 0.0 && cfg.cv_learn_frac < 1.0,
            "cv_learn_frac in (0,1)"
        );
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        Self::from_str_cfg(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = EvalConfig::default();
        assert!(c.cv_folds == 3 && !c.rho_grid.is_empty());
    }

    #[test]
    fn paper_grid_matches_sec_631() {
        let c = EvalConfig::paper_grid();
        assert!(c.rho_grid.contains(&0.01));
        assert!(c.rho_grid.contains(&7.0));
        assert_eq!(c.c_grid, vec![0.1, 1.0, 10.0, 100.0]);
        assert_eq!(c.h_grid, vec![2, 3, 4, 5]);
        assert_eq!(c.rho_grid.len(), 3 + 13);
    }

    #[test]
    fn parses_config_text() {
        let c = EvalConfig::from_str_cfg(
            "rho_grid = 0.5, 1.0\nc_grid=1\n# comment\ncv_folds = 4\nseed=7\nlandmarks=128\n",
        )
        .unwrap();
        assert_eq!(c.rho_grid, vec![0.5, 1.0]);
        assert_eq!(c.c_grid, vec![1.0]);
        assert_eq!(c.cv_folds, 4);
        assert_eq!(c.seed, 7);
        assert_eq!(c.landmarks, 128);
        // an explicit landmarks key pins the CV m-grid too
        assert_eq!(c.m_grid, vec![128]);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(EvalConfig::from_str_cfg("nope = 1").is_err());
        assert!(EvalConfig::from_str_cfg("cv_folds = 1").is_err());
        assert!(EvalConfig::from_str_cfg("cv_learn_frac = 1.5").is_err());
        assert!(EvalConfig::from_str_cfg("landmarks = 0").is_err());
        assert!(EvalConfig::from_str_cfg("stream_block = 0").is_err());
    }

    #[test]
    fn parses_m_grid() {
        assert_eq!(EvalConfig::default().m_grid, vec![32, 64, 128]);
        let c = EvalConfig::from_str_cfg("m_grid = 16, 48").unwrap();
        assert_eq!(c.m_grid, vec![16, 48]);
        assert!(EvalConfig::from_str_cfg("m_grid = 16, 0").is_err());
        // explicit m_grid beats the landmarks pin, whatever the line order
        let c = EvalConfig::from_str_cfg("m_grid = 16, 48\nlandmarks = 99").unwrap();
        assert_eq!(c.landmarks, 99);
        assert_eq!(c.m_grid, vec![16, 48]);
    }

    #[test]
    fn parses_stream_block() {
        assert_eq!(EvalConfig::default().stream_block, None);
        let c = EvalConfig::from_str_cfg("stream_block = 4096").unwrap();
        assert_eq!(c.stream_block, Some(4096));
    }
}
