//! Multi-tenant fleet serving (L6): every model in a registry served by
//! **one** process, plus the drop-directory auto-update daemon that keeps
//! the fleet fresh.
//!
//! AKDA's cheap training (and the Sec. 7 recursive updates of
//! `model::update`) only pay off at scale if one process can serve and
//! refresh *many* trained models at once. This module is that step:
//!
//! * [`FleetService`] owns one versioned [`BankHandle`] per model *name*
//!   loaded from a [`ModelRegistry`], routes incoming score requests by
//!   model id over a **single shared [`WorkPool`]** (no per-tenant thread
//!   explosion — ten tenants on a four-core box still run four scoring
//!   threads), and runs **one** registry watcher that hot-swaps any
//!   tenant's bank on publish without stalling the others.
//! * [`UpdateDaemon`] watches a drop directory of labeled CSVs
//!   (`NAME.csv` targets model `NAME`), applies
//!   [`model::update::update_registry_model`](crate::model::update_registry_model)
//!   — the exact engine behind `akda update` — and republishes; the fleet
//!   watcher then picks the new version up. Together they close the loop
//!   train → publish → serve-fleet → drop-data → auto-update → hot-swap
//!   inside one process.
//!
//! # Request routing
//!
//! ```text
//!  FleetClient::score("eth80", x)          one dispatcher thread
//!        │                                        │
//!        ▼                                        ▼
//!  ┌───────────┐   micro-batch    ┌──────────────────────────────┐
//!  │ mpsc queue│ ───────────────► │ group by model id            │
//!  └───────────┘   (window/size)  │  "eth80"  → [r0, r2]         │
//!                                 │  "mscorid"→ [r1]             │
//!                                 │  "nope"   → protocol error   │
//!                                 └──────────┬───────────────────┘
//!                                            │ one job per tenant group
//!                                            ▼
//!                                 ┌──────────────────────────────┐
//!                                 │ shared WorkPool (N threads)  │
//!                                 │ handle.get().score(batch)    │──► replies
//!                                 └──────────────────────────────┘
//! ```
//!
//! Unknown model ids are answered with [`FleetError::UnknownModel`] —
//! a *protocol* error on the reply channel, never a panic — and the
//! request never reaches the pool. Each tenant group reads its
//! [`BankHandle`] at dispatch time, so a hot swap lands at the next batch
//! boundary of that tenant only.
//!
//! # GC safety
//!
//! The fleet drops a [`ServeMarker`] per tenant (a
//! `<registry>/<name>/.served-<pid>-<seq>` lease holding the served
//! version, re-pointed on every hot swap), so `akda models --prune` run
//! from another process auto-protects every tenant's live version — no
//! per-tenant `--protect` flags needed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{Context, Result};

use super::jobs::WorkPool;
use super::service::BankHandle;
use crate::linalg::Mat;
use crate::model::registry::HotReloader;
use crate::model::{self, ModelRegistry, ServeMarker, UpdateOptions};
use crate::obs;
use crate::obs::trace::TraceStamps;

// ---------------------------------------------------------------------------
// Protocol errors
// ---------------------------------------------------------------------------

/// Protocol-level rejection of a fleet score request. These travel back
/// over the reply channel — a bad request can never panic the service or
/// poison another tenant's traffic.
///
/// ```
/// use akda::coordinator::FleetError;
///
/// let err = FleetError::UnknownModel { model: "x".into(), known: vec!["a".into()] };
/// assert_eq!(err.to_string(), "unknown model \"x\" (serving: a)");
/// // it is a std error, so `?` lifts it into anyhow contexts
/// let any: anyhow::Error = err.into();
/// assert!(any.to_string().contains("unknown model"));
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetError {
    /// No tenant with this model id. Carries the known ids so a caller
    /// (or a log reader) can spot typos immediately.
    UnknownModel { model: String, known: Vec<String> },
    /// The feature vector does not match the tenant's input width.
    WrongDim { model: String, expected: usize, got: usize },
    /// The fleet is shutting down (request or reply channel closed).
    ServiceDown,
    /// An admission queue in front of the fleet (the TCP ingress of
    /// `coordinator::net`) shed this request instead of buffering it
    /// unboundedly; the caller should retry after the hinted delay.
    OverCapacity { retry_after_ms: u32 },
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::UnknownModel { model, known } => {
                write!(f, "unknown model {model:?} (serving: {})", known.join(", "))
            }
            FleetError::WrongDim { model, expected, got } => {
                write!(f, "model {model:?} expects {expected} features, got {got}")
            }
            FleetError::ServiceDown => write!(f, "fleet service is down"),
            FleetError::OverCapacity { retry_after_ms } => {
                write!(f, "fleet over capacity — retry after {retry_after_ms}ms")
            }
        }
    }
}

impl std::error::Error for FleetError {}

// ---------------------------------------------------------------------------
// Requests and clients
// ---------------------------------------------------------------------------

/// How a [`FleetRequest`]'s outcome travels back to its origin: a
/// call-once closure. `FleetClient::score` wraps a channel sender in one;
/// the TCP edge (`coordinator::net`) wraps "encode a response frame onto
/// this connection" — which is what lets one dispatcher serve both
/// in-process and network callers without knowing the difference.
type Replier = Box<dyn FnOnce(Result<Vec<f64>, FleetError>) + Send + 'static>;

/// One routed request: model id + features in, per-class scores (or a
/// [`FleetError`]) delivered to `reply` — exactly once, always.
pub struct FleetRequest {
    model: String,
    features: Vec<f64>,
    reply: Replier,
    /// Stamped at submission; drives the per-tenant end-to-end
    /// `akda_fleet_latency_seconds` histogram.
    enqueued_at: Instant,
    /// Trace stamp cell of the request's origin (the TCP edge passes
    /// one per request, in-process callers pass `None`): the scoring
    /// job writes the `fleet/batch_wait` and `pool/score` stage
    /// durations into it as the batch executes.
    stamps: Option<Arc<TraceStamps>>,
}

/// The live tenant set, shared by the dispatcher, the watcher (which
/// hot-swaps banks and onboards newly published names), and every
/// [`FleetClient`] clone.
type TenantMap = Arc<RwLock<BTreeMap<String, Arc<Tenant>>>>;

/// Handle for submitting score requests to a [`FleetService`]. Cloneable
/// and cheap; all clones feed the same dispatcher queue. Any live clone
/// keeps the dispatcher's queue open — drop every client before dropping
/// the service, or its `Drop` will wait on them (same contract as
/// `ScoringService`).
#[derive(Clone)]
pub struct FleetClient {
    tx: Sender<FleetRequest>,
    tenants: TenantMap,
    queue_depth: Arc<obs::Gauge>,
}

impl FleetClient {
    /// The model ids this fleet currently serves. With a watcher running,
    /// the set is dynamic: a NEW name published to the registry is
    /// onboarded at the next poll, no restart.
    pub fn models(&self) -> Vec<String> {
        self.tenants.read().expect("tenant map").keys().cloned().collect()
    }

    /// Input width of one tenant (`None` for unknown ids).
    pub fn input_dim(&self, model: &str) -> Option<usize> {
        self.tenants.read().expect("tenant map").get(model).map(|t| t.input_dim)
    }

    /// `(name, input dim, served registry version)` per tenant — what the
    /// wire protocol's `ModelsResponse` reports, so hot swaps and
    /// onboarding are observable over TCP.
    pub fn roster(&self) -> Vec<(String, usize, u32)> {
        self.tenants
            .read()
            .expect("tenant map")
            .iter()
            .map(|(n, t)| (n.clone(), t.input_dim, t.handle.served_version()))
            .collect()
    }

    /// Enqueue one request without blocking on its result; `on_reply` is
    /// called exactly once — from the scoring pool on success, from the
    /// dispatcher on protocol rejection, or right here when the fleet is
    /// already down. Validation is the dispatcher's job — the single
    /// protocol authority — so unknown ids and wrong feature widths come
    /// back as [`FleetError`]s and are counted in [`FleetStats::rejected`].
    pub fn submit(
        &self,
        model: &str,
        features: Vec<f64>,
        on_reply: impl FnOnce(Result<Vec<f64>, FleetError>) + Send + 'static,
    ) {
        self.submit_traced(model, features, None, on_reply);
    }

    /// [`FleetClient::submit`] with a trace stamp cell attached: the
    /// dispatch path writes the request's `fleet/batch_wait` and
    /// `pool/score` stage durations into `stamps` before the reply
    /// fires, so the caller (the TCP edge) can assemble a full
    /// [`TraceRecord`](crate::obs::trace::TraceRecord).
    pub fn submit_traced(
        &self,
        model: &str,
        features: Vec<f64>,
        stamps: Option<Arc<TraceStamps>>,
        on_reply: impl FnOnce(Result<Vec<f64>, FleetError>) + Send + 'static,
    ) {
        let req = FleetRequest {
            model: model.to_string(),
            features,
            reply: Box::new(on_reply),
            enqueued_at: Instant::now(),
            stamps,
        };
        self.queue_depth.add(1.0);
        if let Err(send_err) = self.tx.send(req) {
            self.queue_depth.add(-1.0);
            (send_err.0.reply)(Err(FleetError::ServiceDown));
        }
    }

    /// Score one observation against tenant `model`, blocking for the
    /// reply (the channel-based convenience over [`FleetClient::submit`]).
    pub fn score(&self, model: &str, features: Vec<f64>) -> Result<Vec<f64>, FleetError> {
        let (tx, rx) = channel();
        self.submit(model, features, move |result| {
            let _ = tx.send(result);
        });
        rx.recv().map_err(|_| FleetError::ServiceDown)?
    }
}

/// Aggregate fleet statistics (monitoring / tests). A point-in-time
/// snapshot assembled from lock-free counters by [`FleetService::stats`].
#[derive(Debug, Default, Clone)]
pub struct FleetStats {
    /// Requests accepted into tenant batches.
    pub requests: usize,
    /// Dispatch rounds (one round may score several tenants).
    pub batches: usize,
    /// Largest single dispatch round.
    pub max_batch: usize,
    /// Requests rejected with a protocol error by the dispatcher.
    pub rejected: usize,
    /// Accepted requests per model id.
    pub per_tenant: BTreeMap<String, usize>,
}

/// Per-tenant live counters: one atomic for the stats snapshot plus the
/// cached global-registry handles, resolved once at fleet start so the
/// dispatch path never touches the registry lock.
struct TenantMetrics {
    requests: AtomicUsize,
    requests_total: Arc<obs::Counter>,
    latency: Arc<obs::Histogram>,
    rejects_wrong_dim: Arc<obs::Counter>,
}

impl TenantMetrics {
    fn new(name: &str) -> TenantMetrics {
        TenantMetrics {
            requests: AtomicUsize::new(0),
            requests_total: obs::counter_with("akda_fleet_requests_total", &[("tenant", name)]),
            latency: obs::histogram_with("akda_fleet_latency_seconds", &[("tenant", name)]),
            rejects_wrong_dim: obs::counter_with(
                "akda_fleet_rejects_total",
                &[("kind", "wrong_dim"), ("tenant", name)],
            ),
        }
    }
}

/// All-atomic fleet telemetry. Replaces the old `Mutex<FleetStats>`: the
/// dispatcher updates these with relaxed atomics, so `stats()` readers
/// and metric scrapes never contend with scoring. Per-tenant counters
/// live on the [`Tenant`] itself (the set is dynamic since the network
/// edge landed — onboarded tenants bring their own instruments).
struct FleetCounters {
    requests: AtomicUsize,
    batches: AtomicUsize,
    max_batch: AtomicUsize,
    rejected: AtomicUsize,
    rejects_unknown: Arc<obs::Counter>,
    batch_size: Arc<obs::Histogram>,
    queue_depth: Arc<obs::Gauge>,
}

impl FleetCounters {
    fn new() -> FleetCounters {
        FleetCounters {
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            rejects_unknown: obs::counter_with(
                "akda_fleet_rejects_total",
                &[("kind", "unknown_model"), ("tenant", "(unknown)")],
            ),
            batch_size: obs::histogram("akda_fleet_batch_size"),
            queue_depth: obs::gauge("akda_fleet_queue_depth"),
        }
    }
}

/// Sleep up to `total`, waking within ~50ms of `stop` being set — keeps
/// the `Drop` latency of the watcher/daemon threads bounded no matter how
/// long their poll interval is. Crate-visible: `model::registry`'s
/// `HotReloader` paces its polls with the same helper.
pub(crate) fn sleep_until_stopped(stop: &AtomicBool, total: Duration) {
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        std::thread::sleep(left.min(Duration::from_millis(50)));
    }
}

// ---------------------------------------------------------------------------
// The fleet service
// ---------------------------------------------------------------------------

struct Tenant {
    handle: BankHandle,
    input_dim: usize,
    /// GC lease; released when the last `Arc<Tenant>` drops.
    #[allow(dead_code)]
    marker: ServeMarker,
    metrics: TenantMetrics,
}

impl Tenant {
    /// Load one tenant from the registry's latest published version:
    /// checksum-verified decode, serve-marker lease, obs gauges. Shared
    /// by [`FleetService::start`] and the watcher's onboarding path.
    fn load(registry: &ModelRegistry, name: &str) -> Result<Arc<Tenant>> {
        let (entry, artifact) = registry.load_artifact(name)?;
        let input_dim = model::codec::input_dim(&artifact)?;
        let bank = model::codec::decode_bank(&artifact)
            .with_context(|| format!("decoding tenant {}", entry.spec()))?;
        let handle = BankHandle::new_versioned(Arc::new(bank), entry.version);
        let marker = ServeMarker::publish(registry, name, entry.version)?;
        obs::gauge_with("akda_fleet_served_version", &[("model", name)])
            .set(entry.version as f64);
        Ok(Arc::new(Tenant { handle, input_dim, marker, metrics: TenantMetrics::new(name) }))
    }
}

/// Knobs for [`FleetService::start`].
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Threads in the shared scoring pool (shared across ALL tenants).
    pub workers: usize,
    /// Flush threshold of one dispatch round.
    pub max_batch: usize,
    /// Max time the first request of a round waits for company.
    pub window: Duration,
    /// Registry poll interval of the hot-swap watcher; `None` disables
    /// watching (serve the versions loaded at start, forever).
    pub watch: Option<Duration>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            workers: crate::util::threads::available().clamp(2, 16),
            max_batch: 64,
            window: Duration::from_millis(5),
            watch: None,
        }
    }
}

/// One process serving every model name in a registry — see the module
/// docs for the routing diagram. Construction loads the latest published
/// version of each name; [`FleetService::client`] hands out routing
/// handles; the optional watcher hot-swaps republished tenants in place.
pub struct FleetService {
    client: FleetClient,
    tenants: TenantMap,
    counters: Arc<FleetCounters>,
    stop: Arc<AtomicBool>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    watcher: Option<std::thread::JoinHandle<()>>,
}

impl FleetService {
    /// Load every model in `registry` (latest version each) and start the
    /// dispatcher, the shared pool, and — when `opts.watch` is set — the
    /// single multi-tenant watcher, which both hot-swaps republished
    /// tenants AND onboards names newly published to the registry (a new
    /// model joins the fleet without restart). Fails if the registry is
    /// empty or any artifact fails its checksum/decode.
    pub fn start(registry: &ModelRegistry, opts: FleetOptions) -> Result<FleetService> {
        let names = registry.models()?;
        anyhow::ensure!(
            !names.is_empty(),
            "no models in {:?} — train some with `akda train` first",
            registry.root()
        );
        let mut tenants = BTreeMap::new();
        for name in &names {
            tenants.insert(name.clone(), Tenant::load(registry, name)?);
        }
        let tenants: TenantMap = Arc::new(RwLock::new(tenants));
        let counters = Arc::new(FleetCounters::new());
        let stop = Arc::new(AtomicBool::new(false));

        let (tx, rx) = channel::<FleetRequest>();
        let dispatcher = std::thread::Builder::new()
            .name("akda-fleet-dispatch".into())
            .spawn({
                let tenants = tenants.clone();
                let counters = counters.clone();
                let pool = WorkPool::new(opts.workers);
                let (max_batch, window) = (opts.max_batch.max(1), opts.window);
                move || {
                    loop {
                        let first = match rx.recv() {
                            Ok(r) => r,
                            Err(_) => break,
                        };
                        let mut round = vec![first];
                        let deadline = Instant::now() + window;
                        while round.len() < max_batch {
                            let left = deadline.saturating_duration_since(Instant::now());
                            match rx.recv_timeout(left) {
                                Ok(r) => round.push(r),
                                Err(RecvTimeoutError::Timeout)
                                | Err(RecvTimeoutError::Disconnected) => break,
                            }
                        }
                        Self::dispatch_round(round, &tenants, &pool, &counters);
                    }
                    // pool dropped here: workers drain and join
                }
            })
            .expect("spawn fleet dispatcher");

        let watcher = opts.watch.map(|poll| {
            let registry = registry.clone();
            let tenants = tenants.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("akda-fleet-watch".into())
                .spawn(move || Self::watch_loop(&registry, &tenants, &stop, poll))
                .expect("spawn fleet watcher")
        });

        Ok(FleetService {
            client: FleetClient {
                tx,
                tenants: tenants.clone(),
                queue_depth: counters.queue_depth.clone(),
            },
            tenants,
            counters,
            stop,
            dispatcher: Some(dispatcher),
            watcher,
        })
    }

    /// One dispatch round: partition by model id (protocol-rejecting
    /// unroutable requests) and submit one scoring job per tenant group
    /// to the shared pool. The dispatcher never scores anything itself,
    /// so a slow tenant cannot starve the routing of the others beyond
    /// pool capacity.
    fn dispatch_round(
        round: Vec<FleetRequest>,
        tenants: &TenantMap,
        pool: &WorkPool,
        counters: &FleetCounters,
    ) {
        let round_len = round.len();
        counters.queue_depth.add(-(round_len as f64));
        counters.batch_size.record(round_len as f64);
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters.max_batch.fetch_max(round_len, Ordering::Relaxed);
        let mut groups: BTreeMap<String, (Arc<Tenant>, Vec<FleetRequest>)> = BTreeMap::new();
        {
            // hold the read lock for routing only — scoring runs on the
            // pool with per-tenant Arcs, so an onboarding watcher blocks
            // at most a round boundary, never a batch execution
            let map = tenants.read().expect("tenant map");
            for req in round {
                match map.get(&req.model) {
                    None => {
                        counters.rejected.fetch_add(1, Ordering::Relaxed);
                        counters.rejects_unknown.inc();
                        let known = map.keys().cloned().collect();
                        let err = FleetError::UnknownModel { model: req.model.clone(), known };
                        (req.reply)(Err(err));
                    }
                    Some(t) if req.features.len() != t.input_dim => {
                        counters.rejected.fetch_add(1, Ordering::Relaxed);
                        t.metrics.rejects_wrong_dim.inc();
                        let err = FleetError::WrongDim {
                            model: req.model.clone(),
                            expected: t.input_dim,
                            got: req.features.len(),
                        };
                        (req.reply)(Err(err));
                    }
                    Some(t) => {
                        let (_, group) = groups
                            .entry(req.model.clone())
                            .or_insert_with(|| (t.clone(), Vec::new()));
                        group.push(req);
                    }
                }
            }
        }
        for (_, (tenant, group)) in groups {
            counters.requests.fetch_add(group.len(), Ordering::Relaxed);
            tenant.metrics.requests.fetch_add(group.len(), Ordering::Relaxed);
            tenant.metrics.requests_total.add(group.len() as u64);
            // the handle is read inside the job, at score time: a hot swap
            // between dispatch and execution is picked up, not raced
            let _ = pool.submit(move || {
                // batch_wait ends where compute begins: everything from
                // submit (micro-batch window + pool queue) up to here
                for req in &group {
                    if let Some(stamps) = &req.stamps {
                        stamps
                            .batch_wait_nanos
                            .store(req.enqueued_at.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                }
                let compute_start = Instant::now();
                let dim = tenant.input_dim;
                let x = Mat::from_fn(group.len(), dim, |r, c| group[r].features[c]);
                let scores = tenant.handle.get().score(&x);
                let score_nanos = compute_start.elapsed().as_nanos() as u64;
                for (r, req) in group.into_iter().enumerate() {
                    if let Some(stamps) = &req.stamps {
                        stamps.score_nanos.store(score_nanos, Ordering::Relaxed);
                    }
                    (req.reply)(Ok(scores.row(r).to_vec()));
                    tenant.metrics.latency.record(req.enqueued_at.elapsed().as_secs_f64());
                }
            });
        }
    }

    /// The single registry watcher, now with two duties per cycle:
    ///
    /// 1. **Hot swap** — one `HotReloader::poll_once` step per existing
    ///    tenant. Decode happens on this thread, never on the dispatcher
    ///    or the pool, so a tenant mid-swap does not stall the scoring of
    ///    the others; its serve marker is re-pointed after each swap.
    /// 2. **Onboarding** — any model *name* in the registry that is not a
    ///    tenant yet is loaded and inserted, so a brand-new model joins a
    ///    live fleet (and its TCP listener) without restart. A name whose
    ///    artifact fails to load is retried next cycle (e.g. a publish
    ///    mid-flight); tenants are never removed — like version
    ///    downgrades, a vanished registry entry keeps serving from RAM.
    fn watch_loop(
        registry: &ModelRegistry,
        tenants: &TenantMap,
        stop: &AtomicBool,
        poll: Duration,
    ) {
        let mut examined: BTreeMap<String, (u32, Option<SystemTime>)> = tenants
            .read()
            .expect("tenant map")
            .iter()
            .map(|(n, t)| (n.clone(), (t.handle.served_version(), None)))
            .collect();
        while !stop.load(Ordering::Relaxed) {
            // snapshot the Arcs so poll_once (decode!) runs without the lock
            let snapshot: Vec<(String, Arc<Tenant>)> = tenants
                .read()
                .expect("tenant map")
                .iter()
                .map(|(n, t)| (n.clone(), t.clone()))
                .collect();
            for (name, tenant) in &snapshot {
                let ex = examined
                    .entry(name.clone())
                    .or_insert_with(|| (tenant.handle.served_version(), None));
                let old = ex.0;
                match HotReloader::poll_once(
                    registry,
                    name,
                    &tenant.handle,
                    tenant.input_dim,
                    ex,
                ) {
                    Ok(true) => {
                        let v = tenant.handle.served_version();
                        if let Err(e) = tenant.marker.update(v) {
                            eprintln!("fleet: serve-marker update for {name:?}: {e:#}");
                        }
                        let (from, to) = (old.to_string(), v.to_string());
                        obs::counter_with(
                            "akda_fleet_swaps_total",
                            &[("from", &from), ("model", name), ("to", &to)],
                        )
                        .inc();
                        obs::gauge_with("akda_fleet_served_version", &[("model", name)])
                            .set(v as f64);
                        eprintln!("fleet: hot-swapped tenant {name}@{v} (from v{old})");
                    }
                    Ok(false) => {}
                    Err(e) => eprintln!("fleet: reload of tenant {name:?} failed: {e:#}"),
                }
            }
            // discovery: registry names that are not tenants yet
            if let Ok(names) = registry.models() {
                for name in names {
                    let known = tenants.read().expect("tenant map").contains_key(&name);
                    if known {
                        continue;
                    }
                    match Tenant::load(registry, &name) {
                        Ok(tenant) => {
                            let v = tenant.handle.served_version();
                            examined.insert(name.clone(), (v, None));
                            obs::counter_with("akda_fleet_onboards_total", &[("model", &name)])
                                .inc();
                            tenants.write().expect("tenant map").insert(name.clone(), tenant);
                            eprintln!("fleet: onboarded tenant {name}@{v}");
                        }
                        Err(e) => {
                            eprintln!("fleet: onboarding of tenant {name:?} failed: {e:#}")
                        }
                    }
                }
            }
            sleep_until_stopped(stop, poll);
        }
    }

    pub fn client(&self) -> FleetClient {
        self.client.clone()
    }

    /// Latest stats snapshot, assembled from the lock-free counters —
    /// reading it never contends with the dispatch path. Every tenant
    /// appears in `per_tenant` (zero if it has seen no traffic).
    pub fn stats(&self) -> FleetStats {
        let c = &self.counters;
        FleetStats {
            requests: c.requests.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            max_batch: c.max_batch.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            per_tenant: self
                .tenants
                .read()
                .expect("tenant map")
                .iter()
                .map(|(n, t)| (n.clone(), t.metrics.requests.load(Ordering::Relaxed)))
                .collect(),
        }
    }

    /// `(name, served registry version)` per tenant — what monitoring
    /// prints and what the GC shield protects.
    pub fn served_versions(&self) -> Vec<(String, u32)> {
        self.tenants
            .read()
            .expect("tenant map")
            .iter()
            .map(|(n, t)| (n.clone(), t.handle.served_version()))
            .collect()
    }

    /// The served version of one tenant (`None` for unknown ids).
    pub fn served_version(&self, model: &str) -> Option<u32> {
        self.tenants
            .read()
            .expect("tenant map")
            .get(model)
            .map(|t| t.handle.served_version())
    }

    /// Total hot swaps across all tenants since start.
    pub fn swaps(&self) -> usize {
        self.tenants
            .read()
            .expect("tenant map")
            .values()
            .map(|t| t.handle.generation())
            .sum()
    }
}

impl Drop for FleetService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.watcher.take() {
            let _ = w.join();
        }
        // closing our sender ends the dispatcher once outstanding client
        // clones are gone (mirrors ScoringService::drop)
        let (tx, _) = channel();
        self.client = FleetClient {
            tx,
            tenants: Arc::new(RwLock::new(BTreeMap::new())),
            queue_depth: self.client.queue_depth.clone(),
        };
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // release the serve-marker leases deterministically, even if a
        // stray client clone still holds the map Arc
        self.tenants.write().expect("tenant map").clear();
    }
}

// ---------------------------------------------------------------------------
// Drop-directory auto-update daemon
// ---------------------------------------------------------------------------

/// What one daemon poll observed for one file.
#[derive(Debug, Clone)]
pub enum DropEvent {
    /// `NAME.csv` settled, parsed, and the update published a new version
    /// (the file is deleted afterwards). `accuracy` is the post-update
    /// held-out accuracy when the model's dataset allows re-evaluation.
    Updated { model: String, file: PathBuf, version: u32, accuracy: Option<f64> },
    /// The file could not be consumed (malformed CSV, unknown model,
    /// update failure); it was quarantined as `<file>.rejected` so it can
    /// never wedge the polling loop.
    Rejected { file: PathBuf, reason: String },
    /// First sighting (or still changing): consumed only after its size
    /// and mtime are stable across two consecutive polls, so a file still
    /// being written is never half-read.
    Waiting { file: PathBuf },
}

impl std::fmt::Display for DropEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DropEvent::Updated { model, file, version, accuracy } => {
                write!(f, "updated {file:?} -> {model}@{version}")?;
                if let Some(acc) = accuracy {
                    write!(f, " (accuracy {:.2}%)", 100.0 * acc)?;
                }
                Ok(())
            }
            DropEvent::Rejected { file, reason } => {
                write!(f, "rejected {file:?}: {reason}")
            }
            DropEvent::Waiting { file } => write!(f, "waiting for {file:?} to settle"),
        }
    }
}

/// The poll engine of the [`UpdateDaemon`], exposed separately so tests
/// (and embedders) can drive polls synchronously.
///
/// Filename convention: `NAME.csv` targets model `NAME` (latest version)
/// with `label,f1,f2,...` rows — exactly what `akda export` writes and
/// `akda update --data` consumes. Non-CSV and dot-files are ignored.
pub struct DropDirWatcher {
    registry: ModelRegistry,
    drop_dir: PathBuf,
    opts: UpdateOptions,
    /// `(len, mtime)` last observed per not-yet-settled file.
    pending: BTreeMap<PathBuf, (u64, Option<SystemTime>)>,
    /// Signatures of files already handled whose delete/quarantine failed
    /// (e.g. an unwritable drop directory) — matching files are skipped,
    /// never re-applied, so one update can never publish twice.
    consumed: BTreeMap<PathBuf, (u64, Option<SystemTime>)>,
    /// Cached obs handles, resolved once at construction.
    drops_seen: Arc<obs::Counter>,
    drops_settled: Arc<obs::Counter>,
    update_seconds: Arc<obs::Histogram>,
}

impl DropDirWatcher {
    pub fn new(
        registry: ModelRegistry,
        drop_dir: impl Into<PathBuf>,
        opts: UpdateOptions,
    ) -> DropDirWatcher {
        DropDirWatcher {
            registry,
            drop_dir: drop_dir.into(),
            opts,
            pending: BTreeMap::new(),
            consumed: BTreeMap::new(),
            drops_seen: obs::counter("akda_daemon_drops_seen_total"),
            drops_settled: obs::counter("akda_daemon_drops_settled_total"),
            update_seconds: obs::histogram("akda_daemon_update_seconds"),
        }
    }

    /// One poll: scan the drop directory, settle-check every candidate,
    /// consume the stable ones. A missing or unreadable drop directory
    /// yields no events (the daemon keeps polling — the directory may
    /// appear later).
    pub fn poll(&mut self) -> Vec<DropEvent> {
        let mut events = Vec::new();
        let entries = match std::fs::read_dir(&self.drop_dir) {
            Ok(e) => e,
            Err(_) => return events,
        };
        let mut seen = Vec::new();
        for entry in entries.flatten() {
            let path = entry.path();
            let is_csv = path.extension().is_some_and(|e| e == "csv");
            let visible = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| !n.starts_with('.'));
            let is_file = entry.file_type().map(|t| t.is_file()).unwrap_or(false);
            if !is_csv || !visible || !is_file {
                continue;
            }
            let Ok(meta) = entry.metadata() else { continue };
            let sig = (meta.len(), meta.modified().ok());
            seen.push(path.clone());
            match self.consumed.get(&path) {
                // already handled but undeletable (read-only drop dir):
                // skip for as long as the content is unchanged
                Some(prev) if *prev == sig => continue,
                Some(_) => {
                    self.consumed.remove(&path);
                }
                None => {}
            }
            match self.pending.get(&path) {
                Some(prev) if *prev == sig => {
                    // two identical sightings: the writer is done
                    self.pending.remove(&path);
                    events.push(self.consume(&path, sig));
                }
                _ => {
                    if !self.pending.contains_key(&path) {
                        self.drops_seen.inc();
                    }
                    self.pending.insert(path.clone(), sig);
                    events.push(DropEvent::Waiting { file: path });
                }
            }
        }
        // forget files that vanished between polls
        self.pending.retain(|p, _| seen.contains(p));
        self.consumed.retain(|p, _| seen.contains(p));
        events
    }

    /// Consume one settled file: success deletes it, any failure —
    /// including a *panic* anywhere in the parse/update path (e.g. NaN
    /// features poisoning a comparison) — quarantines it as
    /// `<file>.rejected` (best-effort delete if even the rename fails).
    /// Whatever cleanup achieves, the file's signature is remembered as
    /// consumed, so a file that cannot be removed is still never applied
    /// twice, and no drop file can kill the polling thread.
    fn consume(&mut self, path: &Path, sig: (u64, Option<SystemTime>)) -> DropEvent {
        self.consumed.insert(path.to_path_buf(), sig);
        self.drops_settled.inc();
        let t0 = Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.try_update(path)
        }));
        self.update_seconds.record(t0.elapsed().as_secs_f64());
        match outcome {
            Ok(Ok(event)) => {
                let _ = std::fs::remove_file(path);
                event
            }
            Ok(Err(e)) => self.quarantine(path, format!("{e:#}")),
            Err(panic) => {
                // a panicking update is exactly the moment telemetry is
                // most wanted and clean Drop paths are least trusted —
                // flush a final snapshot to every --metrics-out target
                obs::writer::flush_all();
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                self.quarantine(path, format!("update panicked: {what}"))
            }
        }
    }

    /// Quarantine `path` as `<file>.rejected` and record *why* in a
    /// `<file>.rejected.reason` sidecar plus the
    /// `akda_daemon_rejects_total{kind=...}` counter — a rejected drop
    /// is diagnosable without rerunning the daemon.
    fn quarantine(&self, path: &Path, reason: String) -> DropEvent {
        let mut quarantine = path.as_os_str().to_os_string();
        quarantine.push(".rejected");
        let quarantine = PathBuf::from(quarantine);
        let mut reason_file = quarantine.clone().into_os_string();
        reason_file.push(".reason");
        let _ = std::fs::remove_file(&quarantine);
        let _ = std::fs::write(PathBuf::from(reason_file), format!("{reason}\n"));
        if std::fs::rename(path, &quarantine).is_err() {
            let _ = std::fs::remove_file(path);
        }
        obs::counter_with("akda_daemon_rejects_total", &[("kind", Self::reject_kind(&reason))])
            .inc();
        DropEvent::Rejected { file: path.to_path_buf(), reason }
    }

    /// Bounded-cardinality classification of a quarantine reason for the
    /// `kind` metric label (full text goes in the `.reason` sidecar).
    fn reject_kind(reason: &str) -> &'static str {
        let r = reason.to_ascii_lowercase();
        if r.contains("panic") {
            "panic"
        } else if r.contains("unknown model") || r.contains("no versions") {
            "unknown_model"
        } else if r.contains("utf-8") {
            "bad_name"
        } else if r.contains("csv") || r.contains("parse") || r.contains("label") {
            "bad_csv"
        } else {
            "update_failed"
        }
    }

    fn try_update(&self, path: &Path) -> Result<DropEvent> {
        let model = path
            .file_stem()
            .and_then(|s| s.to_str())
            .context("drop file name is not valid UTF-8")?
            .to_string();
        let (x_new, y_new) = crate::data::csv::load_labeled(path)?;
        let up = model::update_registry_model(&self.registry, &model, &x_new, &y_new, &self.opts)?;
        Ok(DropEvent::Updated {
            model,
            file: path.to_path_buf(),
            version: up.published.version,
            accuracy: up.eval.map(|(acc, _)| acc),
        })
    }
}

/// The scheduled auto-update daemon (`akda daemon`): a thread around
/// [`DropDirWatcher`] polling every `interval`. Updated/rejected events
/// are logged to stderr; [`UpdateDaemon::updates`] / [`UpdateDaemon::rejects`]
/// expose counters for monitoring and the smoke tests. Drop (or
/// [`UpdateDaemon::stop`]) to halt.
pub struct UpdateDaemon {
    stop: Arc<AtomicBool>,
    updates: Arc<AtomicUsize>,
    rejects: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl UpdateDaemon {
    pub fn start(
        registry: ModelRegistry,
        drop_dir: impl Into<PathBuf>,
        interval: Duration,
        opts: UpdateOptions,
    ) -> UpdateDaemon {
        let stop = Arc::new(AtomicBool::new(false));
        let updates = Arc::new(AtomicUsize::new(0));
        let rejects = Arc::new(AtomicUsize::new(0));
        let (stop2, updates2, rejects2) = (stop.clone(), updates.clone(), rejects.clone());
        let mut watcher = DropDirWatcher::new(registry, drop_dir, opts);
        let handle = std::thread::Builder::new()
            .name("akda-update-daemon".into())
            .spawn(move || {
                let heartbeat = obs::gauge("akda_daemon_heartbeat_unix");
                let updates_total = obs::counter("akda_daemon_updates_total");
                while !stop2.load(Ordering::Relaxed) {
                    heartbeat.set(obs::unix_now() as f64);
                    for event in watcher.poll() {
                        match &event {
                            DropEvent::Updated { .. } => {
                                updates2.fetch_add(1, Ordering::SeqCst);
                                updates_total.inc();
                                eprintln!("daemon: {event}");
                            }
                            DropEvent::Rejected { .. } => {
                                rejects2.fetch_add(1, Ordering::SeqCst);
                                eprintln!("daemon: {event}");
                            }
                            // settle-waits are normal operation, not news
                            DropEvent::Waiting { .. } => {}
                        }
                    }
                    sleep_until_stopped(&stop2, interval);
                }
            })
            .expect("spawn update daemon");
        UpdateDaemon { stop, updates, rejects, handle: Some(handle) }
    }

    /// Updates published since start.
    pub fn updates(&self) -> usize {
        self.updates.load(Ordering::SeqCst)
    }

    /// Files quarantined since start.
    pub fn rejects(&self) -> usize {
        self.rejects.load(Ordering::SeqCst)
    }

    /// Whether the polling thread is still running. Per-file panics are
    /// contained (see [`DropDirWatcher`]), so this going false means
    /// something unexpected killed the thread — a foreground supervisor
    /// (`akda daemon`) should exit loudly rather than sleep forever.
    pub fn is_alive(&self) -> bool {
        self.handle.as_ref().map(|h| !h.is_finished()).unwrap_or(false)
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for UpdateDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_error_display_names_the_protocol() {
        let e = FleetError::UnknownModel {
            model: "nope".into(),
            known: vec!["a".into(), "b".into()],
        };
        assert_eq!(format!("{e}"), "unknown model \"nope\" (serving: a, b)");
        let e = FleetError::WrongDim { model: "a".into(), expected: 6, got: 5 };
        assert!(format!("{e}").contains("expects 6 features, got 5"));
        assert_eq!(format!("{}", FleetError::ServiceDown), "fleet service is down");
        let e = FleetError::OverCapacity { retry_after_ms: 50 };
        assert_eq!(format!("{e}"), "fleet over capacity — retry after 50ms");
    }

    #[test]
    fn drop_watcher_ignores_non_csv_and_missing_dir() {
        let dir = std::env::temp_dir().join(format!("akda_dropdir_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let registry = ModelRegistry::open(dir.join("registry"));
        // missing drop dir: no events, no error
        let opts = UpdateOptions::default();
        let mut w = DropDirWatcher::new(registry.clone(), dir.join("drop"), opts);
        assert!(w.poll().is_empty());
        // non-CSV and dot-files are invisible
        std::fs::create_dir_all(dir.join("drop")).unwrap();
        std::fs::write(dir.join("drop").join("notes.txt"), "hi").unwrap();
        std::fs::write(dir.join("drop").join(".hidden.csv"), "0,1.0").unwrap();
        assert!(w.poll().is_empty());
        // a real candidate first shows up as Waiting (settle check)
        std::fs::write(dir.join("drop").join("m.csv"), "0,1.0\n").unwrap();
        let events = w.poll();
        assert!(
            matches!(events.as_slice(), [DropEvent::Waiting { .. }]),
            "{events:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
