//! RAII phase spans: nested wall-clock timers that record into the
//! global `akda_phase_seconds{path=...}` histogram family.
//!
//! Spans nest per thread: opening `span("train")` and then
//! `span("gram")` inside it records the inner timing under the path
//! `train/gram`, giving the paper's ϑ breakdown (Gram, Cholesky, NZEP,
//! solve) for free wherever the outer phase is already wrapped.
//!
//! The elapsed time is captured *before* the histogram record happens,
//! so the cost of recording is never attributed to the phase itself.

use std::cell::RefCell;
use std::time::Instant;

thread_local! {
    static PATH: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

/// An open phase timer. Closes (and records) on [`Span::finish`] or drop.
#[derive(Debug)]
pub struct Span {
    start: Option<Instant>,
}

/// Open a phase span named `name`, nested under any span already open on
/// this thread.
pub fn span(name: &'static str) -> Span {
    PATH.with(|p| p.borrow_mut().push(name));
    Span { start: Some(Instant::now()) }
}

impl Span {
    /// Close the span, record its duration, and return the elapsed
    /// seconds — so callers that also need the number (e.g. the ϑ/φ
    /// tables) measure exactly once.
    pub fn finish(mut self) -> f64 {
        self.close()
    }

    fn close(&mut self) -> f64 {
        let Some(t0) = self.start.take() else {
            return 0.0;
        };
        let secs = t0.elapsed().as_secs_f64();
        let path = PATH.with(|p| {
            let mut stack = p.borrow_mut();
            let joined = stack.join("/");
            stack.pop();
            joined
        });
        super::metrics::global()
            .histogram("akda_phase_seconds", &[("path", &path)])
            .record(secs);
        secs
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_build_paths() {
        let outer = span("t_outer");
        {
            let inner = span("t_inner");
            assert!(inner.finish() >= 0.0);
        }
        let secs = outer.finish();
        assert!(secs >= 0.0);
        let reg = super::super::metrics::global();
        let keys: Vec<String> = reg.instruments().into_iter().map(|(k, _)| k.render()).collect();
        assert!(keys.iter().any(|k| k.contains("t_outer/t_inner")), "{keys:?}");
    }

    #[test]
    fn span_records_once_even_with_finish() {
        let h = super::super::metrics::global()
            .histogram("akda_phase_seconds", &[("path", "t_once")]);
        let before = h.count();
        span("t_once").finish();
        assert_eq!(h.count(), before + 1);
    }
}
