//! Schema validators for the files this crate emits: `--metrics-out`
//! JSONL (`akda-metrics/1`), `BENCH_train.json` (`akda-bench-train/1`,
//! or `/2` when the bench swept linalg backends — v2 requires a
//! `backend` tag on every method row) and `BENCH_serve.json`
//! (`akda-bench-serve/1`, or `/2` when the TCP
//! bench recorded the per-stage timing breakdown from the server-timing
//! echo — v2 requires a non-empty `stages` object). CI runs these via
//! `akda metrics --validate FILE` so a schema drift fails the build
//! instead of silently breaking downstream dashboards.

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{parse, Json};

/// Validate `path` against whichever schema its `"schema"` tag claims.
/// Returns a one-line human summary of what was checked.
pub fn validate_file(path: &std::path::Path) -> Result<String> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    ensure!(!text.trim().is_empty(), "{path:?} is empty");
    // whole-file JSON → bench document; line-delimited → metrics JSONL
    if let Ok(doc) = parse(text.trim()) {
        if let Some(schema) = doc.get("schema").and_then(Json::as_str) {
            match schema {
                "akda-bench-train/1" | "akda-bench-train/2" => {
                    return validate_bench_train(&doc)
                }
                "akda-bench-serve/1" | "akda-bench-serve/2" => {
                    return validate_bench_serve(&doc)
                }
                "akda-metrics/1" => {
                    validate_metrics_line(&doc)?;
                    return Ok("akda-metrics/1: 1 snapshot ok".to_string());
                }
                other => bail!("unknown schema {other:?} in {path:?}"),
            }
        }
        bail!("{path:?} has no \"schema\" key");
    }
    let mut n = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = parse(line).with_context(|| format!("{path:?} line {}", i + 1))?;
        let schema = doc.req("schema")?.as_str().context("schema is not a string")?;
        ensure!(schema == "akda-metrics/1", "line {}: unexpected schema {schema:?}", i + 1);
        validate_metrics_line(&doc).with_context(|| format!("{path:?} line {}", i + 1))?;
        n += 1;
    }
    ensure!(n > 0, "{path:?} contains no snapshots");
    Ok(format!("akda-metrics/1: {n} snapshots ok"))
}

/// Check one `akda-metrics/1` snapshot object.
pub fn validate_metrics_line(doc: &Json) -> Result<()> {
    doc.req("unix_time")?.as_usize().context("unix_time is not an integer")?;
    for section in ["counters", "gauges", "summaries"] {
        let Json::Obj(map) = doc.req(section)? else {
            bail!("{section} is not an object");
        };
        if section == "summaries" {
            for (k, v) in map {
                for field in ["count", "sum", "p50", "p90", "p99"] {
                    ensure!(
                        matches!(v.get(field), Some(Json::Num(_))),
                        "summary {k:?} missing numeric {field:?}"
                    );
                }
            }
        } else {
            for (k, v) in map {
                ensure!(matches!(v, Json::Num(_)), "{section} entry {k:?} is not a number");
            }
        }
    }
    Ok(())
}

/// Assert that the metric named by each `key` is present and nonzero in
/// the snapshot `doc` (counters/gauges: value > 0; summaries: count > 0).
/// A key matches if an instrument id equals it or starts with `key{`.
/// Heartbeat gauges (name contains "heartbeat") must additionally be
/// within 600 s of the snapshot's own `unix_time` — i.e. fresh.
pub fn require_nonzero(doc: &Json, keys: &[&str]) -> Result<()> {
    let unix_time = doc.req("unix_time")?.as_usize().unwrap_or(0) as f64;
    for key in keys {
        let mut found = false;
        for section in ["counters", "gauges", "summaries"] {
            let Some(Json::Obj(map)) = doc.get(section) else { continue };
            for (id, v) in map {
                if id != key && !id.starts_with(&format!("{key}{{")) {
                    continue;
                }
                let value = match v {
                    Json::Num(n) => *n,
                    obj => match obj.get("count") {
                        Some(Json::Num(n)) => *n,
                        _ => 0.0,
                    },
                };
                ensure!(value > 0.0, "metric {id:?} is zero");
                if key.contains("heartbeat") {
                    ensure!(
                        (unix_time - value).abs() <= 600.0,
                        "heartbeat {id:?} is stale: {value} vs snapshot time {unix_time}"
                    );
                }
                found = true;
            }
        }
        ensure!(found, "required metric {key:?} not found in snapshot");
    }
    Ok(())
}

fn num(doc: &Json, key: &str) -> Result<f64> {
    match doc.req(key)? {
        Json::Num(n) => Ok(*n),
        other => bail!("{key:?} is not a number: {other:?}"),
    }
}

fn validate_bench_train(doc: &Json) -> Result<String> {
    let schema =
        doc.req("schema")?.as_str().context("schema is not a string")?.to_string();
    doc.req("suite")?.as_str().context("suite is not a string")?;
    ensure!(matches!(doc.req("fast")?, Json::Bool(_)), "fast is not a bool");
    let datasets = doc.req("datasets")?.as_arr().context("datasets is not an array")?;
    ensure!(!datasets.is_empty(), "datasets is empty");
    let mut methods = 0usize;
    for ds in datasets {
        let name = ds.req("name")?.as_str().context("dataset name")?.to_string();
        let rows = ds.req("methods")?.as_arr().context("methods is not an array")?;
        ensure!(!rows.is_empty(), "dataset {name:?} has no methods");
        for m in rows {
            m.req("method")?.as_str().context("method name")?;
            for field in ["map", "train_s", "test_s"] {
                num(m, field).with_context(|| format!("dataset {name:?}"))?;
            }
            // v2 rows carry the linalg backend dimension: every method
            // row is tagged with the backend it was timed under
            if schema == "akda-bench-train/2" {
                let b = m
                    .req("backend")
                    .with_context(|| format!("dataset {name:?}: v2 row missing backend"))?
                    .as_str()
                    .context("backend is not a string")?;
                ensure!(
                    ["scalar", "blocked", "parallel", "auto"].contains(&b),
                    "dataset {name:?}: unknown backend {b:?}"
                );
            }
            methods += 1;
        }
    }
    Ok(format!("{schema}: {} datasets, {methods} method rows ok", datasets.len()))
}

fn validate_bench_serve(doc: &Json) -> Result<String> {
    let schema =
        doc.req("schema")?.as_str().context("schema is not a string")?.to_string();
    num(doc, "duration_s")?;
    let tenants = doc.req("tenants")?.as_arr().context("tenants is not an array")?;
    ensure!(!tenants.is_empty(), "tenants is empty");
    for t in tenants {
        t.req("model")?.as_str().context("tenant model")?;
        for field in ["requests", "rejected", "req_per_s", "p50_ms", "p99_ms"] {
            num(t, field)?;
        }
    }
    let total = doc.req("total")?;
    num(total, "requests")?;
    num(total, "req_per_s")?;
    // v2 additionally carries the per-stage server-timing breakdown the
    // TCP bench aggregated from traced responses — it must be non-empty
    // (an empty echo means the bench should have emitted v1)
    let mut n_stages = 0usize;
    if schema == "akda-bench-serve/2" {
        let Json::Obj(stages) = doc.req("stages")? else {
            bail!("stages is not an object");
        };
        ensure!(!stages.is_empty(), "akda-bench-serve/2 requires a non-empty stages object");
        for (name, s) in stages {
            for field in ["p50_ms", "p99_ms", "share"] {
                num(s, field).with_context(|| format!("stage {name:?}"))?;
            }
        }
        n_stages = stages.len();
    }
    if n_stages > 0 {
        Ok(format!("{schema}: {} tenants, {n_stages} stages ok", tenants.len()))
    } else {
        Ok(format!("{schema}: {} tenants ok", tenants.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_line_validates() {
        let line = r#"{"schema":"akda-metrics/1","unix_time":100,
            "counters":{"a_total":3},"gauges":{"g":1.5},
            "summaries":{"s{path=\"x\"}":{"count":2,"sum":0.1,"p50":0.05,"p90":0.05,"p99":0.05}}}"#;
        let doc = parse(line).unwrap();
        validate_metrics_line(&doc).unwrap();
        require_nonzero(&doc, &["a_total", "g", "s"]).unwrap();
        assert!(require_nonzero(&doc, &["missing_total"]).is_err());
    }

    #[test]
    fn stale_heartbeat_rejected() {
        let line = r#"{"schema":"akda-metrics/1","unix_time":10000,
            "counters":{},"gauges":{"x_heartbeat_unix":100},"summaries":{}}"#;
        let doc = parse(line).unwrap();
        assert!(require_nonzero(&doc, &["x_heartbeat_unix"]).is_err());
    }

    #[test]
    fn heartbeat_staleness_boundary_is_600s() {
        // exactly at the 600 s freshness budget: still fresh
        let fresh = r#"{"schema":"akda-metrics/1","unix_time":10600,
            "counters":{},"gauges":{"x_heartbeat_unix":10000},"summaries":{}}"#;
        require_nonzero(&parse(fresh).unwrap(), &["x_heartbeat_unix"]).unwrap();
        // one second past the budget: stale, and the error says so
        let stale = r#"{"schema":"akda-metrics/1","unix_time":10601,
            "counters":{},"gauges":{"x_heartbeat_unix":10000},"summaries":{}}"#;
        let err = require_nonzero(&parse(stale).unwrap(), &["x_heartbeat_unix"])
            .expect_err("601 s old heartbeat must be rejected");
        assert!(format!("{err:#}").contains("stale"), "{err:#}");
    }

    #[test]
    fn bench_schemas_validate() {
        let train = r#"{"schema":"akda-bench-train/1","suite":"small","fast":true,
            "datasets":[{"name":"iris","methods":[
              {"method":"AKDA","map":0.9,"train_s":0.1,"test_s":0.01,
               "speedup_train":10.0,"speedup_test":5.0}]}]}"#;
        validate_bench_train(&parse(train).unwrap()).unwrap();
        let serve = r#"{"schema":"akda-bench-serve/1","duration_s":2.0,
            "tenants":[{"model":"aa","requests":100,"rejected":0,"req_per_s":50.0,
                        "p50_ms":1.0,"p99_ms":2.0}],
            "total":{"requests":100,"req_per_s":50.0}}"#;
        validate_bench_serve(&parse(serve).unwrap()).unwrap();
    }

    #[test]
    fn bench_train_v2_requires_backend_tags() {
        let v2 = r#"{"schema":"akda-bench-train/2","suite":"small","fast":true,
            "datasets":[{"name":"iris","methods":[
              {"method":"AKDA","backend":"scalar","map":0.9,"train_s":0.2,"test_s":0.01},
              {"method":"AKDA","backend":"parallel","map":0.9,"train_s":0.05,"test_s":0.01}]}]}"#;
        let summary = validate_bench_train(&parse(v2).unwrap()).unwrap();
        assert!(summary.contains("akda-bench-train/2"), "{summary}");
        assert!(summary.contains("2 method rows"), "{summary}");

        // v2 without a backend tag — or with an unknown one — is invalid
        let missing = r#"{"schema":"akda-bench-train/2","suite":"small","fast":true,
            "datasets":[{"name":"iris","methods":[
              {"method":"AKDA","map":0.9,"train_s":0.2,"test_s":0.01}]}]}"#;
        assert!(validate_bench_train(&parse(missing).unwrap()).is_err());
        let unknown = r#"{"schema":"akda-bench-train/2","suite":"small","fast":true,
            "datasets":[{"name":"iris","methods":[
              {"method":"AKDA","backend":"gpu","map":0.9,"train_s":0.2,"test_s":0.01}]}]}"#;
        assert!(validate_bench_train(&parse(unknown).unwrap()).is_err());
        // v1 rows never need the tag
        let v1 = r#"{"schema":"akda-bench-train/1","suite":"small","fast":true,
            "datasets":[{"name":"iris","methods":[
              {"method":"AKDA","map":0.9,"train_s":0.2,"test_s":0.01}]}]}"#;
        validate_bench_train(&parse(v1).unwrap()).unwrap();
    }

    #[test]
    fn bench_serve_v2_requires_stages() {
        let v2 = r#"{"schema":"akda-bench-serve/2","duration_s":2.0,
            "tenants":[{"model":"aa","requests":100,"rejected":0,"req_per_s":50.0,
                        "p50_ms":1.0,"p99_ms":2.0}],
            "stages":{"net/read":{"p50_ms":0.01,"p99_ms":0.05,"share":0.1},
                      "pool/score":{"p50_ms":0.4,"p99_ms":1.2,"share":0.9}},
            "total":{"requests":100,"req_per_s":50.0}}"#;
        let summary = validate_bench_serve(&parse(v2).unwrap()).unwrap();
        assert!(summary.contains("2 stages"), "{summary}");

        // v2 without stages — or with an empty stages object — is invalid
        let missing = r#"{"schema":"akda-bench-serve/2","duration_s":2.0,
            "tenants":[{"model":"aa","requests":1,"rejected":0,"req_per_s":1.0,
                        "p50_ms":1.0,"p99_ms":2.0}],
            "total":{"requests":1,"req_per_s":1.0}}"#;
        assert!(validate_bench_serve(&parse(missing).unwrap()).is_err());
        let empty = r#"{"schema":"akda-bench-serve/2","duration_s":2.0,
            "tenants":[{"model":"aa","requests":1,"rejected":0,"req_per_s":1.0,
                        "p50_ms":1.0,"p99_ms":2.0}],
            "stages":{},
            "total":{"requests":1,"req_per_s":1.0}}"#;
        assert!(validate_bench_serve(&parse(empty).unwrap()).is_err());
    }

    #[test]
    fn garbage_rejected() {
        let path = std::env::temp_dir().join(format!("akda_val_{}.json", std::process::id()));
        std::fs::write(&path, "{\"schema\":\"nope/9\"}").unwrap();
        assert!(validate_file(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
