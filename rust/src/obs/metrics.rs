//! Core instruments: sharded atomic counters, f64 gauges, and
//! log-bucketed latency histograms with quantile estimation.
//!
//! Everything here is dependency-free and lock-free on the hot path:
//! counters stripe increments over cache-line-aligned shards so
//! concurrent writers never bounce the same line, gauges store f64 bits
//! in an `AtomicU64`, and histograms bucket observations on a
//! log-spaced grid (factor 2^(1/4) per bucket, ~9% worst-case relative
//! error on quantiles) so recording is one `fetch_add`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of counter shards; power of two so the thread id maps with a mask.
const SHARDS: usize = 8;

/// One cache line per shard so concurrent increments never false-share.
#[repr(align(64))]
#[derive(Debug)]
struct Shard(AtomicU64);

/// Per-thread shard slot, assigned round-robin on first use.
fn shard_idx() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SLOT.with(|s| {
        let mut idx = s.get();
        if idx == usize::MAX {
            idx = NEXT.fetch_add(1, Ordering::Relaxed);
            s.set(idx);
        }
        idx & (SHARDS - 1)
    })
}

/// Monotone event counter, striped over [`SHARDS`] cache lines.
#[derive(Debug)]
pub struct Counter {
    shards: [Shard; SHARDS],
}

impl Counter {
    pub fn new() -> Self {
        Self { shards: std::array::from_fn(|_| Shard(AtomicU64::new(0))) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.shards[shard_idx()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Sum over shards. Reads are racy-but-monotone: a concurrent `add`
    /// may or may not be visible, but the value never goes backwards.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-write-wins f64 gauge (bits stored in an `AtomicU64`).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self { bits: AtomicU64::new(0f64.to_bits()) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Atomically add a delta (CAS loop; fine for warm-path accumulation).
    pub fn add(&self, d: f64) {
        let _ = self.bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            Some((f64::from_bits(b) + d).to_bits())
        });
    }

    /// Ratchet the gauge up to `v` if `v` exceeds the current value.
    pub fn set_max(&self, v: f64) {
        let _ = self.bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            if v > f64::from_bits(b) {
                Some(v.to_bits())
            } else {
                None
            }
        });
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Number of log-spaced histogram buckets.
pub const HIST_BUCKETS: usize = 128;
/// Lower edge of bucket 0, in the recorded unit (seconds for latencies).
const HIST_MIN: f64 = 1e-6;
/// log2 growth per bucket: each bucket is 2^(1/4) ≈ 1.19x wider, so 128
/// buckets span 1 µs .. 2^32 µs ≈ 1.2 h.
const BUCKETS_PER_OCTAVE: f64 = 4.0;

/// Log-bucketed histogram for non-negative observations (latencies,
/// batch sizes). Quantiles are estimated as the geometric midpoint of
/// the bucket holding the requested rank — worst-case relative error is
/// half a bucket width, ~9%.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    /// Sum in nanounits (1e-9 of the recorded unit) so accumulation is a
    /// single integer `fetch_add`.
    sum_nano: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_nano: AtomicU64::new(0),
        }
    }

    fn bucket_of(v: f64) -> usize {
        if !v.is_finite() || v <= HIST_MIN {
            return 0;
        }
        let idx = ((v / HIST_MIN).log2() * BUCKETS_PER_OCTAVE) as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Lower edge of bucket `i`.
    fn bucket_lo(i: usize) -> f64 {
        HIST_MIN * (i as f64 / BUCKETS_PER_OCTAVE).exp2()
    }

    #[inline]
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nano.fetch_add((v * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations, in the recorded unit.
    pub fn sum(&self) -> f64 {
        self.sum_nano.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Estimated `q`-quantile (q in [0, 1]): geometric midpoint of the
    /// bucket containing rank ceil(q·count). Returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                // geometric midpoint: lo · 2^(1/8)
                return Self::bucket_lo(i) * (0.5 / BUCKETS_PER_OCTAVE).exp2();
            }
        }
        Self::bucket_lo(HIST_BUCKETS - 1)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Instrument identity: a metric name plus sorted `(label, value)` pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl Key {
    pub fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        Self { name: name.to_string(), labels }
    }

    /// Render as `name{k="v",...}` (bare name when label-free) — the
    /// identity used by both the Prometheus and JSON snapshot formats.
    pub fn render(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let inner: Vec<String> = self.labels.iter().map(|(k, v)| format!("{k}={v:?}")).collect();
        format!("{}{{{}}}", self.name, inner.join(","))
    }
}

/// A registered instrument, shared by handle.
#[derive(Debug, Clone)]
pub enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Named instrument store. Lookup takes a short mutex; hot paths should
/// look an instrument up once and cache the returned `Arc`.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    instruments: Mutex<BTreeMap<Key, Instrument>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = Key::new(name, labels);
        let mut map = self.instruments.lock().unwrap();
        let ins = map.entry(key).or_insert_with(|| Instrument::Counter(Arc::new(Counter::new())));
        match ins {
            Instrument::Counter(c) => c.clone(),
            // name/type mismatch is a programming error; degrade to a
            // detached instrument rather than panicking a server
            _ => Arc::new(Counter::new()),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = Key::new(name, labels);
        let mut map = self.instruments.lock().unwrap();
        let ins = map.entry(key).or_insert_with(|| Instrument::Gauge(Arc::new(Gauge::new())));
        match ins {
            Instrument::Gauge(g) => g.clone(),
            _ => Arc::new(Gauge::new()),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let key = Key::new(name, labels);
        let mut map = self.instruments.lock().unwrap();
        let ins =
            map.entry(key).or_insert_with(|| Instrument::Histogram(Arc::new(Histogram::new())));
        match ins {
            Instrument::Histogram(h) => h.clone(),
            _ => Arc::new(Histogram::new()),
        }
    }

    /// Stable-ordered copy of every registered instrument handle.
    pub fn instruments(&self) -> Vec<(Key, Instrument)> {
        self.instruments.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

/// The process-wide registry every layer records into.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts_across_shards() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn gauge_set_add_max() {
        let g = Gauge::new();
        g.set(2.5);
        g.add(0.5);
        assert_eq!(g.get(), 3.0);
        g.set_max(1.0);
        assert_eq!(g.get(), 3.0);
        g.set_max(7.0);
        assert_eq!(g.get(), 7.0);
    }

    #[test]
    fn histogram_buckets_are_monotone() {
        let mut last = 0usize;
        for &v in &[1e-7, 1e-6, 3e-6, 1e-3, 0.1, 1.0, 60.0, 1e9] {
            let b = Histogram::bucket_of(v);
            assert!(b >= last, "bucket_of({v}) = {b} < {last}");
            last = b;
        }
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(1e9), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantile_tracks_point_mass() {
        let h = Histogram::new();
        for _ in 0..1000 {
            h.record(0.010);
        }
        for q in [0.5, 0.9, 0.99] {
            let est = h.quantile(q);
            assert!((est - 0.010).abs() / 0.010 < 0.15, "q{q}: {est}");
        }
        assert_eq!(h.count(), 1000);
        assert!((h.sum() - 10.0).abs() < 0.01);
    }

    #[test]
    fn registry_returns_same_handle() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("x_total", &[("t", "a")]);
        let b = reg.counter("x_total", &[("t", "a")]);
        a.inc();
        assert_eq!(b.get(), 1);
        assert_eq!(reg.instruments().len(), 1);
    }
}
