//! Point-in-time registry snapshots, rendered two ways: Prometheus text
//! exposition format (histograms as `summary` families with
//! p50/p90/p99 quantile labels) and the crate's `util::json` value tree
//! under the stable `akda-metrics/1` schema.
//!
//! Both renderings use the same instrument identity string,
//! `name{label="value",...}`, so a metric found in one surface can be
//! looked up verbatim in the other.

use std::collections::BTreeMap;

use super::metrics::{Instrument, Key, MetricsRegistry};
use crate::util::json::Json;

/// Version tag stamped on every JSON snapshot line.
pub const METRICS_SCHEMA: &str = "akda-metrics/1";

/// One rendered instrument value.
#[derive(Debug, Clone)]
pub enum Value {
    Counter(u64),
    Gauge(f64),
    /// Histogram digest: count, sum, and estimated quantiles.
    Summary { count: u64, sum: f64, p50: f64, p90: f64, p99: f64 },
}

/// A consistent-enough copy of every instrument at one moment.
/// (Individual reads are atomic; the set is collected under the
/// registry lock, values are read racily afterwards.)
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub entries: Vec<(Key, Value)>,
}

impl MetricsRegistry {
    /// Capture every registered instrument's current value.
    pub fn snapshot(&self) -> Snapshot {
        let entries = self
            .instruments()
            .into_iter()
            .map(|(key, ins)| {
                let value = match ins {
                    Instrument::Counter(c) => Value::Counter(c.get()),
                    Instrument::Gauge(g) => Value::Gauge(g.get()),
                    Instrument::Histogram(h) => Value::Summary {
                        count: h.count(),
                        sum: h.sum(),
                        p50: h.quantile(0.50),
                        p90: h.quantile(0.90),
                        p99: h.quantile(0.99),
                    },
                };
                (key, value)
            })
            .collect();
        Snapshot { entries }
    }
}

impl Snapshot {
    /// Prometheus text exposition format. Counters and gauges render as
    /// their native types; histograms render as `summary` families
    /// (quantile labels + `_sum`/`_count`) to keep the output compact.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name = "";
        for (key, value) in &self.entries {
            if key.name != last_name {
                let kind = match value {
                    Value::Counter(_) => "counter",
                    Value::Gauge(_) => "gauge",
                    Value::Summary { .. } => "summary",
                };
                out.push_str(&format!("# TYPE {} {kind}\n", key.name));
                last_name = &key.name;
            }
            match value {
                Value::Counter(n) => out.push_str(&format!("{} {n}\n", key.render())),
                Value::Gauge(v) => out.push_str(&format!("{} {v}\n", key.render())),
                Value::Summary { count, sum, p50, p90, p99 } => {
                    for (q, v) in [("0.5", p50), ("0.9", p90), ("0.99", p99)] {
                        out.push_str(&format!("{} {v}\n", render_with(key, &[("quantile", q)])));
                    }
                    out.push_str(&format!("{}_sum{} {sum}\n", key.name, label_block(key)));
                    out.push_str(&format!("{}_count{} {count}\n", key.name, label_block(key)));
                }
            }
        }
        out
    }

    /// JSON snapshot under the `akda-metrics/1` schema:
    ///
    /// ```text
    /// {"schema": "akda-metrics/1", "unix_time": <secs>,
    ///  "counters":  {"<name{labels}>": <u64>, ...},
    ///  "gauges":    {"<name{labels}>": <f64>, ...},
    ///  "summaries": {"<name{labels}>": {"count":..., "sum":...,
    ///                                   "p50":..., "p90":..., "p99":...}}}
    /// ```
    pub fn to_json(&self, unix_time: u64) -> Json {
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut summaries = BTreeMap::new();
        for (key, value) in &self.entries {
            let id = key.render();
            match value {
                Value::Counter(n) => {
                    counters.insert(id, Json::Num(*n as f64));
                }
                Value::Gauge(v) => {
                    gauges.insert(id, Json::Num(*v));
                }
                Value::Summary { count, sum, p50, p90, p99 } => {
                    let mut m = BTreeMap::new();
                    m.insert("count".to_string(), Json::Num(*count as f64));
                    m.insert("sum".to_string(), Json::Num(*sum));
                    m.insert("p50".to_string(), Json::Num(*p50));
                    m.insert("p90".to_string(), Json::Num(*p90));
                    m.insert("p99".to_string(), Json::Num(*p99));
                    summaries.insert(id, Json::Obj(m));
                }
            }
        }
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(METRICS_SCHEMA.to_string()));
        root.insert("unix_time".to_string(), Json::Num(unix_time as f64));
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        root.insert("summaries".to_string(), Json::Obj(summaries));
        Json::Obj(root)
    }
}

/// `{k="v",...}` for a key's own labels, or the empty string.
fn label_block(key: &Key) -> String {
    if key.labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = key.labels.iter().map(|(k, v)| format!("{k}={v:?}")).collect();
    format!("{{{}}}", inner.join(","))
}

/// Render `key` with `extra` label pairs appended (for quantile labels).
fn render_with(key: &Key, extra: &[(&str, &str)]) -> String {
    let mut inner: Vec<String> = key.labels.iter().map(|(k, v)| format!("{k}={v:?}")).collect();
    inner.extend(extra.iter().map(|(k, v)| format!("{k}={v:?}")));
    format!("{}{{{}}}", key.name, inner.join(","))
}

/// Seconds since the Unix epoch (0 if the clock is before 1970).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.counter("demo_total", &[("tenant", "aa")]).add(3);
        reg.gauge("demo_depth", &[]).set(1.5);
        let h = reg.histogram("demo_seconds", &[("path", "train")]);
        h.record(0.002);
        h.record(0.004);
        reg
    }

    #[test]
    fn prometheus_renders_all_types() {
        let text = demo_registry().snapshot().to_prometheus();
        assert!(text.contains("# TYPE demo_total counter"), "{text}");
        assert!(text.contains("demo_total{tenant=\"aa\"} 3"), "{text}");
        assert!(text.contains("# TYPE demo_depth gauge"), "{text}");
        assert!(text.contains("demo_depth 1.5"), "{text}");
        assert!(text.contains("# TYPE demo_seconds summary"), "{text}");
        assert!(text.contains("demo_seconds{path=\"train\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("demo_seconds_count{path=\"train\"} 2"), "{text}");
    }

    #[test]
    fn json_round_trips_through_parser() {
        let line = demo_registry().snapshot().to_json(1234).to_string();
        let back = crate::util::json::parse(&line).unwrap();
        assert_eq!(back.req("schema").unwrap().as_str(), Some(METRICS_SCHEMA));
        assert_eq!(back.req("unix_time").unwrap().as_usize(), Some(1234));
        let counters = back.req("counters").unwrap();
        assert_eq!(counters.get("demo_total{tenant=\"aa\"}").unwrap().as_usize(), Some(3));
        let s = back.req("summaries").unwrap().get("demo_seconds{path=\"train\"}").unwrap();
        assert_eq!(s.req("count").unwrap().as_usize(), Some(2));
        assert!(s.get("p99").is_some());
    }
}
