//! Background JSONL metrics writer: the `--metrics-out FILE` flag.
//!
//! A `MetricsWriter` appends one `akda-metrics/1` JSON line (see
//! [`super::snapshot`]) immediately on start, then every `period`, then
//! once more on shutdown — so even a short-lived process leaves at
//! least two observable snapshots behind.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::metrics::global;
use super::snapshot::unix_now;

/// Handle to the writer thread; flushes a final snapshot on drop.
#[derive(Debug)]
pub struct MetricsWriter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsWriter {
    /// Start appending periodic snapshots of the global registry to
    /// `path`. Write errors are reported once on stderr, not fatal —
    /// telemetry must never take down the service it observes.
    pub fn start(path: &Path, period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let path: PathBuf = path.to_path_buf();
        let handle = std::thread::spawn(move || {
            let mut warned = false;
            append_snapshot(&path, &mut warned);
            while !stop2.load(Ordering::Relaxed) {
                // sleep in short slices so shutdown is prompt
                let mut left = period;
                while left > Duration::ZERO && !stop2.load(Ordering::Relaxed) {
                    let step = left.min(Duration::from_millis(50));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                append_snapshot(&path, &mut warned);
            }
            append_snapshot(&path, &mut warned);
        });
        Self { stop, handle: Some(handle) }
    }
}

impl Drop for MetricsWriter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Append one snapshot line to `path` (best-effort).
fn append_snapshot(path: &Path, warned: &mut bool) {
    let line = global().snapshot().to_json(unix_now()).to_string();
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        if !*warned {
            eprintln!("metrics: cannot write {path:?}: {e}");
            *warned = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_appends_parsable_lines() {
        let path =
            std::env::temp_dir().join(format!("akda_obs_writer_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        global().counter("writer_test_total", &[]).inc();
        {
            let _w = MetricsWriter::start(&path, Duration::from_secs(60));
            std::thread::sleep(Duration::from_millis(30));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "want >=2 snapshots, got {}", lines.len());
        for line in &lines {
            let j = crate::util::json::parse(line).unwrap();
            assert_eq!(j.req("schema").unwrap().as_str(), Some("akda-metrics/1"));
        }
        let _ = std::fs::remove_file(&path);
    }
}
