//! Background JSONL metrics writer: the `--metrics-out FILE` flag.
//!
//! A `MetricsWriter` appends one `akda-metrics/1` JSON line (see
//! [`super::snapshot`]) immediately on start, then every `period`, then
//! once more on shutdown — so even a short-lived process leaves at
//! least two observable snapshots behind. The shutdown line only covers
//! clean `Drop`; panic/abort paths that want a last observable state
//! call [`flush_all`], which appends one snapshot to every writer
//! target currently active in the process.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::metrics::global;
use super::snapshot::unix_now;

/// Targets of every live `MetricsWriter`, so [`flush_all`] can reach
/// them from panic paths that never see the writer handles.
static ACTIVE: Mutex<Vec<PathBuf>> = Mutex::new(Vec::new());

/// Append one final snapshot to every active `--metrics-out` target —
/// the best-effort flush for panic/abort paths (e.g. the update
/// daemon's quarantine arm), where clean `Drop` never runs. A no-op
/// when no writer is active; never fails, never panics.
pub fn flush_all() {
    let paths: Vec<PathBuf> = match ACTIVE.lock() {
        Ok(v) => v.clone(),
        Err(_) => return,
    };
    for path in paths {
        let mut warned = true; // panic path: skip the stderr report
        append_snapshot(&path, &mut warned);
    }
}

/// Handle to the writer thread; flushes a final snapshot on drop.
#[derive(Debug)]
pub struct MetricsWriter {
    path: PathBuf,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsWriter {
    /// Start appending periodic snapshots of the global registry to
    /// `path`. Write errors are reported once on stderr, not fatal —
    /// telemetry must never take down the service it observes.
    pub fn start(path: &Path, period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let path: PathBuf = path.to_path_buf();
        if let Ok(mut active) = ACTIVE.lock() {
            active.push(path.clone());
        }
        let registered = path.clone();
        let handle = std::thread::spawn(move || {
            let mut warned = false;
            append_snapshot(&path, &mut warned);
            while !stop2.load(Ordering::Relaxed) {
                // sleep in short slices so shutdown is prompt
                let mut left = period;
                while left > Duration::ZERO && !stop2.load(Ordering::Relaxed) {
                    let step = left.min(Duration::from_millis(50));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                append_snapshot(&path, &mut warned);
            }
            append_snapshot(&path, &mut warned);
        });
        Self { path: registered, stop, handle: Some(handle) }
    }
}

impl Drop for MetricsWriter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Ok(mut active) = ACTIVE.lock() {
            if let Some(i) = active.iter().position(|p| *p == self.path) {
                active.remove(i);
            }
        }
    }
}

/// Append one snapshot line to `path` (best-effort).
fn append_snapshot(path: &Path, warned: &mut bool) {
    let line = global().snapshot().to_json(unix_now()).to_string();
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        if !*warned {
            eprintln!("metrics: cannot write {path:?}: {e}");
            *warned = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_appends_parsable_lines() {
        let path =
            std::env::temp_dir().join(format!("akda_obs_writer_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        global().counter("writer_test_total", &[]).inc();
        {
            let _w = MetricsWriter::start(&path, Duration::from_secs(60));
            std::thread::sleep(Duration::from_millis(30));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "want >=2 snapshots, got {}", lines.len());
        for line in &lines {
            let j = crate::util::json::parse(line).unwrap();
            assert_eq!(j.req("schema").unwrap().as_str(), Some("akda-metrics/1"));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flush_all_reaches_active_writers_and_forgets_dropped_ones() {
        let path =
            std::env::temp_dir().join(format!("akda_obs_flushall_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let w = MetricsWriter::start(&path, Duration::from_secs(3600));
        // wait for the initial line so the count below is stable
        for _ in 0..200 {
            if path.exists() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let count = |p: &Path| {
            std::fs::read_to_string(p).map(|t| t.lines().count()).unwrap_or(0)
        };
        let before = count(&path);
        flush_all();
        assert_eq!(count(&path), before + 1, "flush_all must append one snapshot");
        drop(w);
        let settled = count(&path);
        flush_all();
        assert_eq!(count(&path), settled, "dropped writers must be forgotten");
        let _ = std::fs::remove_file(&path);
    }
}
