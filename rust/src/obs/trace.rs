//! Per-request distributed tracing across the `akda-wire/1` edge.
//!
//! A traced request carries a client-minted 64-bit id ([`TraceIdGen`])
//! in its ScoreRequest frame; every hop stamps a monotonic stage
//! duration into a [`TraceRecord`]:
//!
//! ```text
//!  client ──► net/read ──► net/queue ──► fleet/batch_wait ──► pool/score ──► net/write ──► client
//!             (socket      (ingress,     (dispatcher          (WorkPool      (serialize
//!              transfer     incl. shed    micro-batch          batch          + send)
//!              + decode)    decisions)    collection)          compute)
//! ```
//!
//! The five stages are sequential, non-overlapping segments of the
//! server-side residency, so their sum is always ≤ the client-observed
//! RTT (the difference is the wire + client stack). Records are emitted
//! as [`TRACE_SCHEMA`] JSONL by a sampling [`TraceSink`] (`--trace-out`
//! on `akda serve`), and the same stage durations feed the
//! `akda_trace_stage_seconds{stage=...}` histograms so the aggregate
//! and per-request views share instrument identity. `akda trace FILE`
//! runs [`analyze`] over a sink file: top-k slowest requests, per-stage
//! p50/p99, and a stage-share attribution table ("p99 is 71%
//! fleet/batch_wait").

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Schema tag carried on every trace JSONL line.
pub const TRACE_SCHEMA: &str = "akda-trace/1";

/// Stage id of `net/read` (socket transfer + frame decode) in the wire
/// timing echo. Ids are stable wire vocabulary — never renumber.
pub const STAGE_NET_READ: u8 = 1;
/// Stage id of `net/queue` (ingress queue residency, incl. sheds).
pub const STAGE_NET_QUEUE: u8 = 2;
/// Stage id of `fleet/batch_wait` (dispatcher micro-batch collection).
pub const STAGE_BATCH_WAIT: u8 = 3;
/// Stage id of `pool/score` (WorkPool batch compute).
pub const STAGE_POOL_SCORE: u8 = 4;
/// Stage id of `net/write` (response serialize + send).
pub const STAGE_NET_WRITE: u8 = 5;

/// Every stage in hop order: `(wire id, name)`.
pub const STAGES: [(u8, &str); 5] = [
    (STAGE_NET_READ, "net/read"),
    (STAGE_NET_QUEUE, "net/queue"),
    (STAGE_BATCH_WAIT, "fleet/batch_wait"),
    (STAGE_POOL_SCORE, "pool/score"),
    (STAGE_NET_WRITE, "net/write"),
];

/// The stable name of a stage id, if known.
pub fn stage_name(id: u8) -> Option<&'static str> {
    STAGES.iter().find(|(i, _)| *i == id).map(|(_, n)| *n)
}

/// Mints non-zero 64-bit trace ids from the crate's seeded PRNG — the
/// same reproducibility spine as everything else, so a test run mints
/// the same id sequence every time. 0 is reserved as the wire's
/// "untraced" sentinel and is never produced.
#[derive(Debug)]
pub struct TraceIdGen {
    rng: Rng,
}

impl TraceIdGen {
    pub fn new(seed: u64) -> Self {
        TraceIdGen { rng: Rng::new(seed) }
    }

    /// The next trace id (never 0).
    pub fn next_id(&mut self) -> u64 {
        loop {
            let id = self.rng.next_u64();
            if id != 0 {
                return id;
            }
        }
    }
}

/// Cross-layer stamp cell riding a request from the network edge into
/// the fleet dispatcher and WorkPool: the dispatcher cannot see the
/// connection and the writer thread cannot see the batch, so both write
/// their stage durations (nanoseconds, relaxed atomics) into this
/// shared cell and the writer assembles the final [`TraceRecord`].
#[derive(Debug, Default)]
pub struct TraceStamps {
    /// `fleet/batch_wait` duration in nanoseconds (enqueue at the
    /// dispatcher → batch collected onto a WorkPool job).
    pub batch_wait_nanos: AtomicU64,
    /// `pool/score` duration in nanoseconds (the batch compute).
    pub score_nanos: AtomicU64,
}

impl TraceStamps {
    /// `(batch_wait, score)` in seconds.
    pub fn load(&self) -> (f64, f64) {
        (
            self.batch_wait_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            self.score_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// One request's assembled trace: stage durations in hop order.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// The client-minted trace id (nonzero for traced requests; sheds
    /// and slow-log captures may record untraced requests as 0).
    pub trace: u64,
    pub req_id: u64,
    pub model: String,
    /// True when the ingress queue shed this request — such records are
    /// terminal at `net/queue` (no later stages exist).
    pub shed: bool,
    /// `(stage id, seconds)` in hop order; sheds stop at `net/queue`.
    pub stages: Vec<(u8, f64)>,
}

impl TraceRecord {
    /// Sum of all stage durations, seconds — the server-side residency.
    pub fn total_s(&self) -> f64 {
        self.stages.iter().map(|(_, s)| s).sum()
    }

    /// The `akda-trace/1` JSON document for one JSONL line. Trace ids
    /// are hex strings (a u64 does not survive JSON's f64 numbers).
    pub fn to_json(&self, unix_time: u64) -> Json {
        let mut stages = std::collections::BTreeMap::new();
        for &(id, secs) in &self.stages {
            let name = stage_name(id).map(str::to_string).unwrap_or_else(|| format!("stage/{id}"));
            stages.insert(name, Json::Num(secs * 1e3));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
        doc.insert("unix_time".to_string(), Json::Num(unix_time as f64));
        doc.insert("trace".to_string(), Json::Str(format!("{:016x}", self.trace)));
        doc.insert("req_id".to_string(), Json::Num(self.req_id as f64));
        doc.insert("model".to_string(), Json::Str(self.model.clone()));
        doc.insert("shed".to_string(), Json::Bool(self.shed));
        doc.insert("total_ms".to_string(), Json::Num(self.total_s() * 1e3));
        doc.insert("stages".to_string(), Json::Obj(stages));
        Json::Obj(doc)
    }
}

/// Sampling JSONL sink for trace records — the `--trace-out FILE`
/// target. Two independent capture policies, OR-ed together:
///
/// * **sampling** — every `sample`-th request is recorded (`sample` 1 =
///   all, 0 = sampling off);
/// * **slow log** — any request whose server residency is ≥ `slow_ms`
///   is always recorded (`slow_ms` 0 therefore captures everything).
///
/// Sheds are always recorded when any policy is active: a shed is
/// precisely the event an operator reads traces to understand.
#[derive(Debug)]
pub struct TraceSink {
    path: PathBuf,
    out: Mutex<std::fs::File>,
    sample: u64,
    slow_ms: Option<f64>,
    seq: AtomicU64,
    written: AtomicU64,
}

impl TraceSink {
    /// Create (truncating) the sink file. `sample` records every Nth
    /// request (0 disables sampling); `slow_ms` always records requests
    /// at or above the threshold (`Some(0.0)` captures every request).
    pub fn create(
        path: impl Into<PathBuf>,
        sample: u64,
        slow_ms: Option<f64>,
    ) -> Result<TraceSink> {
        let path = path.into();
        let out = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("creating trace sink {path:?}"))?;
        Ok(TraceSink {
            path,
            out: Mutex::new(out),
            sample,
            slow_ms,
            seq: AtomicU64::new(0),
            written: AtomicU64::new(0),
        })
    }

    /// The sink file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Offer one assembled record; the sink applies its policies and
    /// appends a JSONL line when any of them captures it. Never fails —
    /// a full disk loses trace lines, not requests.
    pub fn offer(&self, rec: &TraceRecord) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let sampled = self.sample > 0 && seq % self.sample == 0;
        let slow = self.slow_ms.is_some_and(|ms| rec.total_s() * 1e3 >= ms);
        let captured = rec.shed && (self.sample > 0 || self.slow_ms.is_some());
        if !(sampled || slow || captured) {
            return;
        }
        let line = format!("{}\n", rec.to_json(super::unix_now()));
        if let Ok(mut f) = self.out.lock() {
            if f.write_all(line.as_bytes()).is_ok() {
                self.written.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Records actually written so far (after sampling).
    pub fn written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Analyzer (`akda trace FILE`)
// ---------------------------------------------------------------------------

/// One parsed trace line, as [`analyze`] consumes it.
#[derive(Debug, Clone)]
pub struct ParsedTrace {
    pub trace: u64,
    pub model: String,
    pub shed: bool,
    pub total_ms: f64,
    /// `(stage name, milliseconds)`.
    pub stages: Vec<(String, f64)>,
}

/// Aggregate view over a trace file — render with `{}` (`Display`).
#[derive(Debug)]
pub struct TraceReport {
    pub records: usize,
    pub sheds: usize,
    /// Per stage, in hop order: `(name, p50 ms, p99 ms, share of all
    /// stage time, share within the p99 tail)`.
    pub stages: Vec<(String, f64, f64, f64, f64)>,
    /// Slowest requests, descending: `(trace, model, total ms,
    /// dominant stage, dominant share)`.
    pub slowest: Vec<(u64, String, f64, String, f64)>,
    /// Requests making up the p99 tail the attribution is computed on.
    pub tail_len: usize,
}

impl TraceReport {
    /// The headline attribution: the stage owning the largest share of
    /// the p99 tail, e.g. `("fleet/batch_wait", 0.71)`.
    pub fn dominant_tail_stage(&self) -> Option<(&str, f64)> {
        self.stages
            .iter()
            .max_by(|a, b| a.4.total_cmp(&b.4))
            .filter(|s| s.4 > 0.0)
            .map(|s| (s.0.as_str(), s.4))
    }
}

/// Nearest-rank quantile over an ascending-sorted sample.
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Parse one `akda-trace/1` JSONL line.
pub fn parse_line(line: &str) -> Result<ParsedTrace> {
    let doc = json::parse(line).context("trace line is not JSON")?;
    let schema = doc.req("schema")?.as_str().context("schema must be a string")?;
    if schema != TRACE_SCHEMA {
        bail!("unexpected schema {schema:?} (want {TRACE_SCHEMA})");
    }
    let trace_hex = doc.req("trace")?.as_str().context("trace must be a hex string")?;
    let trace = u64::from_str_radix(trace_hex, 16)
        .with_context(|| format!("bad trace id {trace_hex:?}"))?;
    let model = doc.req("model")?.as_str().unwrap_or_default().to_string();
    let shed = matches!(doc.req("shed")?, Json::Bool(true));
    let total_ms = match doc.req("total_ms")? {
        Json::Num(n) => *n,
        _ => bail!("total_ms must be a number"),
    };
    let mut stages = Vec::new();
    if let Json::Obj(map) = doc.req("stages")? {
        for (name, v) in map {
            match v {
                Json::Num(ms) => stages.push((name.clone(), *ms)),
                _ => bail!("stage {name:?} must be a number"),
            }
        }
    } else {
        bail!("stages must be an object");
    }
    Ok(ParsedTrace { trace, model, shed, total_ms, stages })
}

/// Analyze a whole `akda-trace/1` JSONL document: per-stage quantiles,
/// stage-share attribution over the full set and over the p99 latency
/// tail, and the top-`top_k` slowest requests.
pub fn analyze(text: &str, top_k: usize) -> Result<TraceReport> {
    let mut parsed = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        parsed.push(parse_line(line).with_context(|| format!("trace line {}", i + 1))?);
    }
    if parsed.is_empty() {
        bail!("no trace records in input");
    }
    let sheds = parsed.iter().filter(|p| p.shed).count();

    // p99 tail: everything at or above the p99 of total_ms
    let mut totals: Vec<f64> = parsed.iter().map(|p| p.total_ms).collect();
    totals.sort_by(|a, b| a.total_cmp(b));
    let p99_total = quantile_sorted(&totals, 0.99);
    let tail: Vec<&ParsedTrace> =
        parsed.iter().filter(|p| p.total_ms >= p99_total).collect();

    // stage rows in hop order first, then any unknown names (sorted)
    let mut names: Vec<String> = STAGES
        .iter()
        .map(|(_, n)| n.to_string())
        .filter(|n| parsed.iter().any(|p| p.stages.iter().any(|(s, _)| s == n)))
        .collect();
    let mut extra: Vec<String> = parsed
        .iter()
        .flat_map(|p| p.stages.iter().map(|(s, _)| s.clone()))
        .filter(|s| !names.contains(s))
        .collect();
    extra.sort();
    extra.dedup();
    names.extend(extra);

    let stage_ms = |p: &ParsedTrace, name: &str| -> f64 {
        p.stages.iter().find(|(s, _)| s == name).map(|(_, ms)| *ms).unwrap_or(0.0)
    };
    let all_time: f64 = parsed.iter().map(|p| p.total_ms).sum();
    let tail_time: f64 = tail.iter().map(|p| p.total_ms).sum();
    let mut stages = Vec::new();
    for name in &names {
        let mut sample: Vec<f64> =
            parsed.iter().map(|p| stage_ms(p, name)).collect();
        sample.sort_by(|a, b| a.total_cmp(b));
        let sum: f64 = sample.iter().sum();
        let tail_sum: f64 = tail.iter().map(|p| stage_ms(p, name)).sum();
        stages.push((
            name.clone(),
            quantile_sorted(&sample, 0.5),
            quantile_sorted(&sample, 0.99),
            if all_time > 0.0 { sum / all_time } else { 0.0 },
            if tail_time > 0.0 { tail_sum / tail_time } else { 0.0 },
        ));
    }

    let mut by_total: Vec<&ParsedTrace> = parsed.iter().collect();
    by_total.sort_by(|a, b| b.total_ms.total_cmp(&a.total_ms));
    let slowest = by_total
        .iter()
        .take(top_k)
        .map(|p| {
            let (dom, dom_ms) = p
                .stages
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(s, ms)| (s.clone(), *ms))
                .unwrap_or_default();
            let share = if p.total_ms > 0.0 { dom_ms / p.total_ms } else { 0.0 };
            (p.trace, p.model.clone(), p.total_ms, dom, share)
        })
        .collect();

    Ok(TraceReport { records: parsed.len(), sheds, stages, slowest, tail_len: tail.len() })
}

impl std::fmt::Display for TraceReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{TRACE_SCHEMA}: {} records ({} shed)", self.records, self.sheds)?;
        writeln!(
            f,
            "{:<18} {:>10} {:>10} {:>8} {:>10}",
            "stage", "p50 ms", "p99 ms", "share", "share@tail"
        )?;
        for (name, p50, p99, share, tail) in &self.stages {
            writeln!(
                f,
                "{name:<18} {p50:>10.3} {p99:>10.3} {:>7.1}% {:>9.1}%",
                share * 100.0,
                tail * 100.0
            )?;
        }
        if !self.slowest.is_empty() {
            writeln!(f, "top {} slowest:", self.slowest.len())?;
            for (i, (trace, model, ms, dom, share)) in self.slowest.iter().enumerate() {
                writeln!(
                    f,
                    "  {:>2}. {trace:016x} {model:<12} {ms:>9.3} ms  {:.0}% {dom}",
                    i + 1,
                    share * 100.0
                )?;
            }
        }
        if let Some((stage, share)) = self.dominant_tail_stage() {
            writeln!(
                f,
                "p99 is {:.0}% {stage} (tail of {} request{})",
                share * 100.0,
                self.tail_len,
                if self.tail_len == 1 { "" } else { "s" }
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace: u64, model: &str, shed: bool, stages: &[(u8, f64)]) -> TraceRecord {
        TraceRecord {
            trace,
            req_id: trace & 0xFF,
            model: model.to_string(),
            shed,
            stages: stages.to_vec(),
        }
    }

    #[test]
    fn record_round_trips_through_json_line() {
        let r = rec(
            0xDEAD_BEEF_1234_5678,
            "ta",
            false,
            &[(STAGE_NET_READ, 0.001), (STAGE_POOL_SCORE, 0.004)],
        );
        let line = r.to_json(1_700_000_000).to_string();
        let p = parse_line(&line).unwrap();
        assert_eq!(p.trace, r.trace);
        assert_eq!(p.model, "ta");
        assert!(!p.shed);
        assert!((p.total_ms - 5.0).abs() < 1e-9);
        assert!(p.stages.iter().any(|(s, ms)| s == "net/read" && (*ms - 1.0).abs() < 1e-9));
    }

    #[test]
    fn sink_sampling_and_slow_log_policies() {
        let dir = std::env::temp_dir().join(format!("akda_trace_sink_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        // sample every 3rd: 9 offers -> 3 lines
        let sink = TraceSink::create(dir.join("s3.jsonl"), 3, None).unwrap();
        for i in 0..9u64 {
            sink.offer(&rec(i + 1, "m", false, &[(STAGE_POOL_SCORE, 0.001)]));
        }
        assert_eq!(sink.written(), 3);

        // slow_ms 0 captures everything even with sampling off
        let sink = TraceSink::create(dir.join("slow0.jsonl"), 0, Some(0.0)).unwrap();
        for i in 0..5u64 {
            sink.offer(&rec(i + 1, "m", false, &[(STAGE_POOL_SCORE, 1e-6)]));
        }
        assert_eq!(sink.written(), 5);

        // slow_ms 10: only the one slow request is captured
        let sink = TraceSink::create(dir.join("slow10.jsonl"), 0, Some(10.0)).unwrap();
        sink.offer(&rec(1, "m", false, &[(STAGE_POOL_SCORE, 0.001)]));
        sink.offer(&rec(2, "m", false, &[(STAGE_POOL_SCORE, 0.020)]));
        assert_eq!(sink.written(), 1);

        // sheds are always captured while any policy is active
        let sink = TraceSink::create(dir.join("shed.jsonl"), 1000, None).unwrap();
        sink.offer(&rec(1, "m", false, &[(STAGE_POOL_SCORE, 0.001)])); // seq 0: sampled
        sink.offer(&rec(2, "m", true, &[(STAGE_NET_QUEUE, 0.002)])); // shed: captured
        sink.offer(&rec(3, "m", false, &[(STAGE_POOL_SCORE, 0.001)])); // dropped
        assert_eq!(sink.written(), 2);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn analyzer_attributes_the_tail() {
        // 20 fast requests dominated by score, one huge batch_wait outlier
        let mut text = String::new();
        for i in 0..20u64 {
            let r = rec(
                i + 1,
                "ta",
                false,
                &[(STAGE_NET_READ, 0.0001), (STAGE_POOL_SCORE, 0.001)],
            );
            text.push_str(&r.to_json(0).to_string());
            text.push('\n');
        }
        let outlier = rec(
            99,
            "tb",
            false,
            &[(STAGE_NET_READ, 0.0001), (STAGE_BATCH_WAIT, 0.080), (STAGE_POOL_SCORE, 0.002)],
        );
        text.push_str(&outlier.to_json(0).to_string());
        text.push('\n');

        let report = analyze(&text, 3).unwrap();
        assert_eq!(report.records, 21);
        assert_eq!(report.sheds, 0);
        let (stage, share) = report.dominant_tail_stage().unwrap();
        assert_eq!(stage, "fleet/batch_wait", "tail must be attributed to the outlier stage");
        assert!(share > 0.9, "share {share}");
        assert_eq!(report.slowest[0].0, 99, "slowest must be the outlier");
        let rendered = format!("{report}");
        assert!(rendered.contains("p99 is"), "{rendered}");
        assert!(rendered.contains("fleet/batch_wait"), "{rendered}");
    }

    #[test]
    fn id_gen_is_seeded_and_never_zero() {
        let mut a = TraceIdGen::new(7);
        let mut b = TraceIdGen::new(7);
        for _ in 0..100 {
            let id = a.next_id();
            assert_eq!(id, b.next_id(), "same seed, same ids");
            assert_ne!(id, 0);
        }
    }

    #[test]
    fn stage_names_are_stable() {
        assert_eq!(stage_name(STAGE_NET_READ), Some("net/read"));
        assert_eq!(stage_name(STAGE_NET_WRITE), Some("net/write"));
        assert_eq!(stage_name(99), None);
    }
}
