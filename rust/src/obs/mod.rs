//! L7 observability: a dependency-free metrics subsystem.
//!
//! * `metrics` — sharded atomic [`Counter`]s, f64 [`Gauge`]s,
//!   log-bucketed [`Histogram`]s with p50/p90/p99 estimation, all
//!   behind a process-global [`MetricsRegistry`].
//! * `span` — RAII phase timers with nested paths (`train/gram`,
//!   `train/chol`, ...) recording into `akda_phase_seconds`.
//! * `snapshot` — render the registry to Prometheus text exposition or
//!   to `akda-metrics/1` JSON (the CLI's `akda metrics` output).
//! * `writer` — the `--metrics-out FILE` periodic JSONL appender.
//! * `validate` — schema checks for the emitted JSONL and the
//!   `BENCH_train.json` / `BENCH_serve.json` bench artifacts.
//! * `trace` — L9 per-request distributed tracing across the
//!   `akda-wire/1` edge: stage stamps, the `akda-trace/1` JSONL sink,
//!   and the `akda trace` analyzer.
//! * `flight` — the training flight recorder: numerical-health facts
//!   (Cholesky pivots, ε applied, NZEP eigenvalue extremes, phase
//!   durations) captured during fit/update and persisted as `health.*`
//!   manifest keys.
//!
//! Design rule: the hot path never takes a lock. Call sites resolve an
//! instrument handle once (a `Mutex`-guarded `BTreeMap` lookup), cache
//! the returned `Arc`, and record through relaxed atomics afterwards.
//! An instrument that is never snapshotted costs one `fetch_add` per
//! event.

pub mod flight;
pub mod metrics;
pub mod snapshot;
pub mod span;
pub mod trace;
pub mod validate;
pub mod writer;

use std::sync::Arc;

pub use metrics::{global, Counter, Gauge, Histogram, Instrument, Key, MetricsRegistry};
pub use snapshot::{unix_now, Snapshot, Value, METRICS_SCHEMA};
pub use span::{span, Span};
pub use trace::{TraceIdGen, TraceRecord, TraceSink, TraceStamps, TRACE_SCHEMA};
pub use writer::MetricsWriter;

/// Global label-free counter handle.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name, &[])
}

/// Global labelled counter handle.
pub fn counter_with(name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
    global().counter(name, labels)
}

/// Global label-free gauge handle.
pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name, &[])
}

/// Global labelled gauge handle.
pub fn gauge_with(name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
    global().gauge(name, labels)
}

/// Global label-free histogram handle.
pub fn histogram(name: &str) -> Arc<Histogram> {
    global().histogram(name, &[])
}

/// Global labelled histogram handle.
pub fn histogram_with(name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
    global().histogram(name, labels)
}
