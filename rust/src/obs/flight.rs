//! Training flight recorder: numerical-health facts captured while a
//! model fits or updates.
//!
//! AKDA's speed claim rests on "very stable numerical algorithms" —
//! this module records the facts that would reveal the opposite before
//! accuracy does: the extreme Cholesky pivots (conditioning of the
//! regularized kernel system), the ε ridge actually applied, the
//! core-matrix NZEP count and eigenvalue extremes, and per-phase wall
//! durations. Each fact lands twice:
//!
//! * as an `akda_train_health{key="..."}` gauge, scrapeable live;
//! * in the global recorder map, which `akda train` / the update
//!   daemon snapshot into `health.*` keys of the model MANIFEST —
//!   `akda models --inspect` surfaces them and `models --diff` flags a
//!   republish that degrades conditioning before it serves.
//!
//! The recorder is process-global and phase-scoped by convention:
//! callers [`reset`] before a fit/update and [`snapshot`] right after.
//! Concurrent training in one process (only tests do this) may
//! interleave facts; consumers therefore assert key presence, not
//! exact values.

use std::collections::BTreeMap;
use std::sync::Mutex;

static RECORDER: Mutex<BTreeMap<String, f64>> = Mutex::new(BTreeMap::new());

/// Record one health fact under `key`, overwriting any previous value,
/// and mirror it to the `akda_train_health{key="..."}` gauge.
pub fn record(key: &str, value: f64) {
    super::gauge_with("akda_train_health", &[("key", key)]).set(value);
    if let Ok(mut map) = RECORDER.lock() {
        map.insert(key.to_string(), value);
    }
}

/// Clear the recorder — call at the start of a fit/update so the
/// following [`snapshot`] holds only facts from that run.
pub fn reset() {
    if let Ok(mut map) = RECORDER.lock() {
        map.clear();
    }
}

/// The facts recorded since the last [`reset`], keyed as they will
/// appear in the manifest (without the `health.` prefix).
pub fn snapshot() -> BTreeMap<String, f64> {
    RECORDER.lock().map(|m| m.clone()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_reset_snapshot_cycle() {
        reset();
        record("chol_pivot_min", 0.25);
        record("chol_pivot_max", 4.0);
        record("chol_pivot_min", 0.125); // overwrite wins
        let snap = snapshot();
        assert_eq!(snap.get("chol_pivot_min"), Some(&0.125));
        assert_eq!(snap.get("chol_pivot_max"), Some(&4.0));
        reset();
        // Concurrent tests may interleave records after our reset, but
        // the keys we wrote must be gone.
        let snap = snapshot();
        assert_ne!(snap.get("chol_pivot_min"), Some(&0.125));
    }

    #[test]
    fn record_mirrors_to_gauge() {
        record("flight_test_gauge_key", 7.5);
        let g = crate::obs::gauge_with("akda_train_health", &[("key", "flight_test_gauge_key")]);
        assert_eq!(g.get(), 7.5);
    }
}
