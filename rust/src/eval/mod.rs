//! Evaluation substrate: average precision / MAP (the paper's ϖ), timing
//! speedups over KDA (ϑ̃, φ̃), and the table printer that regenerates the
//! layout of Tables 2–7.

pub mod tables;

/// Average precision of a ranked list: `scores[i]` is the confidence for
/// observation i, `positive[i]` whether it is a true positive.
/// AP = mean over positive ranks of precision@rank (the TRECVID metric).
pub fn average_precision(scores: &[f64], positive: &[bool]) -> f64 {
    assert_eq!(scores.len(), positive.len());
    let n_pos = positive.iter().filter(|&&p| p).count();
    if n_pos == 0 {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    // descending by score; ties broken by index for determinism
    order.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
    });
    let mut hits = 0usize;
    let mut sum = 0.0;
    for (rank, &i) in order.iter().enumerate() {
        if positive[i] {
            hits += 1;
            sum += hits as f64 / (rank + 1) as f64;
        }
    }
    sum / n_pos as f64
}

/// Mean average precision over per-class APs (Sec. 6.3.1, ϖ_m).
pub fn mean_average_precision(aps: &[f64]) -> f64 {
    if aps.is_empty() {
        return 0.0;
    }
    aps.iter().sum::<f64>() / aps.len() as f64
}

/// Per-method evaluation record for one dataset/condition experiment.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub method: String,
    pub map: f64,
    pub train_s: f64,
    pub test_s: f64,
    /// Peak resident f64 count of the training accumulator when the method
    /// ran through the out-of-core tiled path (`da::akda_stream`);
    /// `None` for fully in-memory runs.
    pub peak_f64: Option<usize>,
    /// Landmark / random-feature budget m the run used — `Some` for the
    /// approximate methods (reports the CV-selected budget when
    /// `select_hyper` searched `m_grid`), `None` for exact methods.
    pub budget: Option<usize>,
}

impl MethodResult {
    /// Speedups over a reference (KDA) result: ϑ̃ = ϑ_KDA/ϑ_m, φ̃ likewise.
    pub fn speedup_over(&self, kda: &MethodResult) -> (f64, f64) {
        (kda.train_s / self.train_s.max(1e-12), kda.test_s / self.test_s.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_gives_ap_1() {
        let scores = [0.9, 0.8, 0.3, 0.2];
        let pos = [true, true, false, false];
        assert!((average_precision(&scores, &pos) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn worst_ranking_ap() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let pos = [true, true, false, false];
        // positives at ranks 3,4 → AP = (1/3 + 2/4)/2
        let want = (1.0 / 3.0 + 0.5) / 2.0;
        assert!((average_precision(&scores, &pos) - want).abs() < 1e-12);
    }

    #[test]
    fn interleaved_ranking() {
        let scores = [0.9, 0.8, 0.7, 0.6];
        let pos = [true, false, true, false];
        let want = (1.0 + 2.0 / 3.0) / 2.0;
        assert!((average_precision(&scores, &pos) - want).abs() < 1e-12);
    }

    #[test]
    fn no_positives_is_zero() {
        assert_eq!(average_precision(&[0.1, 0.2], &[false, false]), 0.0);
    }

    #[test]
    fn ties_are_deterministic() {
        let scores = [0.5, 0.5, 0.5];
        let pos = [false, true, false];
        let a = average_precision(&scores, &pos);
        let b = average_precision(&scores, &pos);
        assert_eq!(a, b);
    }

    #[test]
    fn map_averages() {
        assert!((mean_average_precision(&[1.0, 0.5]) - 0.75).abs() < 1e-12);
        assert_eq!(mean_average_precision(&[]), 0.0);
    }

    #[test]
    fn speedup_ratios() {
        let kda = MethodResult {
            method: "kda".into(), map: 0.5, train_s: 10.0, test_s: 2.0,
            peak_f64: None, budget: None };
        let akda = MethodResult {
            method: "akda".into(), map: 0.6, train_s: 1.0, test_s: 2.0,
            peak_f64: None, budget: None };
        let (t, p) = akda.speedup_over(&kda);
        assert!((t - 10.0).abs() < 1e-12);
        assert!((p - 1.0).abs() < 1e-12);
    }
}
